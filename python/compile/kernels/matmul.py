"""Blocked matmul Pallas kernel — the transformer MLP hot loop.

MXU-shaped: the grid tiles M×N into 128×128 output blocks (the systolic
array's native shape); each grid cell streams an (bm, K) row-panel and a
(K, bn) column-panel into VMEM and issues one `jnp.dot` that the TPU
compiler maps onto MXU passes. K is kept un-tiled because every workload
here has K ≤ 1024: the panels fit VMEM comfortably
(128×1024×4 B × 2 ≈ 1 MiB), so no accumulation loop or scratch is needed —
fewer HBM round trips than a K-tiled variant at these sizes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    # One (bm, K) × (K, bn) → (bm, bn) MXU pass per grid cell, f32
    # accumulation.
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pick(dim: int, pref: int) -> int:
    for b in (pref, 128, 64, 32, 16, 8, 4, 2, 1):
        if b <= pref and dim % b == 0:
            return b
    return 1


@functools.partial(jax.named_call, name="pallas_matmul")
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) → (M, N) with 128×128 output tiling."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick(m, 128)
    bn = _pick(n, 128)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, y)
