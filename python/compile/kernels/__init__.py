"""Layer-1 Pallas kernels (build-time only; lowered into the AOT artifacts).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness + interchange
path; real-TPU viability is argued from BlockSpec/VMEM analysis in
DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf.
"""

from .grayscale import grayscale, grayscale_video
from .matmul import matmul
from .attention import attention

__all__ = ["grayscale", "grayscale_video", "matmul", "attention"]
