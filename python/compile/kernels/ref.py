"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest + hypothesis sweep shapes and assert the kernels match these to
float32 tolerance; the AOT artifacts embed the kernels, so this is the
core numerical signal for the whole stack.
"""

import jax
import jax.numpy as jnp

from .grayscale import LUMA_B, LUMA_G, LUMA_R


def grayscale_ref(img: jax.Array) -> jax.Array:
    return img[..., 0] * LUMA_R + img[..., 1] * LUMA_G + img[..., 2] * LUMA_B


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    d = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q, k) / (d**0.5)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v)
