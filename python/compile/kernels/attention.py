"""Fused attention Pallas kernel — softmax(QKᵀ/√d)·V per (batch·head) cell.

The GPU-paper idiom (one threadblock per head, shared-memory tiles) maps to
TPU as: one grid cell per (batch·head), the whole (T, d) Q/K/V panels
staged in VMEM, QKᵀ and PV as MXU passes, and the softmax row-reductions on
the VPU between them — no HBM round trip for the (T, T) score matrix, which
is the entire point of fusing. VMEM per cell at T=64, d=64:
3·(64×64) + (64×64) scores + output ≈ 80 KiB.

Sequence lengths here (≤ 128) fit a single block; longer sequences would
tile T with an online-softmax accumulator (FlashAttention-style), which the
same BlockSpec structure extends to.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]  # (T, d) — leading block dim is the (batch·head) cell
    k = k_ref[0]
    v = v_ref[0]
    d = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * (1.0 / (d**0.5))
    # Numerically stable softmax on the VPU.
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """(BH, T, d) × 3 → (BH, T, d): fused per-cell attention."""
    bh, t, d = q.shape
    assert k.shape == (bh, t, d) and v.shape == (bh, t, d)
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _attention_kernel,
        out_shape=jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
        grid=(bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=True,
    )(q, k, v)
