"""RGB→luma grayscale as a Pallas kernel — the video/image hot loop.

The paper's video-processing workload "applies grayscale effect from the
OpenCV library to a video input"; this is that effect as a TPU-tiled
kernel. The grid streams row-blocks HBM→VMEM (BlockSpec), computes the
BT.709 luma as fused multiply-adds on the VPU, and writes the single-channel
block back. VMEM per block: bh×W×3 + bh×W floats = (64×256×4)·4 B ≈ 256 KiB,
far under the ~16 MiB VMEM budget, leaving room to raise bh on real TPUs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# BT.709 luma weights (what OpenCV's COLOR_RGB2GRAY uses, rounded).
LUMA_R = 0.2126
LUMA_G = 0.7152
LUMA_B = 0.0722


def _grayscale_kernel(rgb_ref, out_ref):
    rgb = rgb_ref[...]  # (bh, W, 3) block in VMEM
    out_ref[...] = (
        rgb[..., 0] * LUMA_R + rgb[..., 1] * LUMA_G + rgb[..., 2] * LUMA_B
    )


def _pick_block(h: int) -> int:
    """Largest power-of-two row-block ≤ 64 that divides H."""
    for bh in (64, 32, 16, 8, 4, 2, 1):
        if h % bh == 0:
            return bh
    return 1


def grayscale(img: jax.Array) -> jax.Array:
    """(H, W, 3) f32 → (H, W) luma, tiled over row blocks."""
    h, w, c = img.shape
    assert c == 3, f"expected RGB, got {c} channels"
    bh = _pick_block(h)
    return pl.pallas_call(
        _grayscale_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        grid=(h // bh,),
        in_specs=[pl.BlockSpec((bh, w, 3), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bh, w), lambda i: (i, 0)),
        interpret=True,
    )(img)


def grayscale_video(frames: jax.Array) -> jax.Array:
    """(F, H, W, 3) → (F, H, W): the kernel vmapped over frames."""
    return jax.vmap(grayscale)(frames)
