"""Layer-2 JAX compute graphs: the guest functions' real work.

Each entry point is the compute a serverless request performs in the
paper's evaluation workloads, expressed over the Layer-1 Pallas kernels:

* ``float_operation``   — FunctionBench's float arithmetic loop;
* ``image_processing``  — grayscale (Pallas) → normalize → rotate →
  downsample, FunctionBench's Pillow pipeline analog;
* ``video_processing``  — the Pallas grayscale kernel vmapped over a frame
  stack + temporal motion energy, the OpenCV analog;
* ``tiny_lm``           — a small transformer block stack (Pallas attention
  + Pallas matmul MLP), the E2E serving demo model.

These are lowered once by ``aot.py`` to HLO text and executed from Rust via
PJRT; Python never serves requests.
"""

import jax
import jax.numpy as jnp

from .kernels import attention, grayscale, grayscale_video, matmul

# ---------------------------------------------------------------------------
# float_operation
# ---------------------------------------------------------------------------


def float_operation(x: jax.Array) -> jax.Array:
    """FunctionBench float-operation: sqrt/sin/mul chain, 16 rounds.

    The input is mixed back in every round so the result depends on the
    request payload (a pure sqrt/sin chain would converge to an
    input-independent fixed point).
    """

    def body(_, acc):
        acc = jnp.sqrt(jnp.abs(acc) + 1.0) + 0.25 * x
        acc = acc * 1.000001 + jnp.sin(acc) * 0.5
        return acc

    return jax.lax.fori_loop(0, 16, body, x)


# ---------------------------------------------------------------------------
# image_processing
# ---------------------------------------------------------------------------


def _downsample2(img: jax.Array) -> jax.Array:
    """2× average-pool downsample of a (H, W) image."""
    h, w = img.shape
    return img.reshape(h // 2, 2, w // 2, 2).mean(axis=(1, 3))


def image_processing(img: jax.Array) -> jax.Array:
    """Grayscale → contrast normalize → rotate 90° → 2× downsample.

    Mirrors FunctionBench's Pillow transform set. Output (H/2, W/2).
    """
    g = grayscale(img)  # Pallas kernel
    mean = jnp.mean(g)
    std = jnp.std(g) + 1e-6
    norm = (g - mean) / std
    rot = jnp.rot90(norm)  # "image transformation"
    return _downsample2(rot)


# ---------------------------------------------------------------------------
# video_processing
# ---------------------------------------------------------------------------


def video_processing(frames: jax.Array) -> jax.Array:
    """Grayscale every frame (Pallas, vmapped) + motion energy.

    frames: (F, H, W, 3) → (F, H, W) grayscale with the last frame replaced
    by the temporal |diff| sum (a cheap motion map) so the output depends on
    every frame.
    """
    g = grayscale_video(frames)  # (F, H, W)
    motion = jnp.sum(jnp.abs(jnp.diff(g, axis=0)), axis=0)
    return g.at[-1].set(motion)


# ---------------------------------------------------------------------------
# tiny_lm — a small transformer (the serve-demo model)
# ---------------------------------------------------------------------------

LM_LAYERS = 2
LM_HEADS = 4
LM_DIM = 256
LM_MLP = 512
LM_VOCAB = 512


def _lm_params(key: jax.Array):
    """Deterministic parameters (constant-folded into the artifact)."""
    ks = jax.random.split(key, 4 + LM_LAYERS * 6)
    scale = 0.02
    params = {
        "out": jax.random.normal(ks[0], (LM_DIM, LM_VOCAB)) * scale,
    }
    layers = []
    for i in range(LM_LAYERS):
        base = 4 + i * 6
        layers.append(
            {
                "wq": jax.random.normal(ks[base + 0], (LM_DIM, LM_DIM)) * scale,
                "wk": jax.random.normal(ks[base + 1], (LM_DIM, LM_DIM)) * scale,
                "wv": jax.random.normal(ks[base + 2], (LM_DIM, LM_DIM)) * scale,
                "wo": jax.random.normal(ks[base + 3], (LM_DIM, LM_DIM)) * scale,
                "w1": jax.random.normal(ks[base + 4], (LM_DIM, LM_MLP)) * scale,
                "w2": jax.random.normal(ks[base + 5], (LM_MLP, LM_DIM)) * scale,
            }
        )
    params["layers"] = layers
    return params


def _layernorm(x: jax.Array) -> jax.Array:
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-5)


def _block(x: jax.Array, p) -> jax.Array:
    """One pre-LN transformer block over (B, T, D)."""
    b, t, d = x.shape
    h = _layernorm(x)
    flat = h.reshape(b * t, d)
    q = matmul(flat, p["wq"]).reshape(b, t, LM_HEADS, d // LM_HEADS)
    k = matmul(flat, p["wk"]).reshape(b, t, LM_HEADS, d // LM_HEADS)
    v = matmul(flat, p["wv"]).reshape(b, t, LM_HEADS, d // LM_HEADS)
    # (B, T, H, dh) → (B·H, T, dh) for the fused attention kernel.
    def to_cells(a):
        return a.transpose(0, 2, 1, 3).reshape(b * LM_HEADS, t, d // LM_HEADS)

    o = attention(to_cells(q), to_cells(k), to_cells(v))
    o = o.reshape(b, LM_HEADS, t, d // LM_HEADS).transpose(0, 2, 1, 3).reshape(b * t, d)
    x = x + matmul(o, p["wo"]).reshape(b, t, d)
    h = _layernorm(x).reshape(b * t, d)
    mlp = matmul(jax.nn.gelu(matmul(h, p["w1"])), p["w2"]).reshape(b, t, d)
    return x + mlp


def tiny_lm(embedded: jax.Array) -> jax.Array:
    """(B, T, D) embeddings → (B, T, V) logits.

    The embedding lookup stays outside (the Rust side feeds embedded
    activations) so the artifact's interface is pure f32 tensors.
    """
    params = _lm_params(jax.random.PRNGKey(42))
    x = embedded
    for p in params["layers"]:
        x = _block(x, p)
    b, t, d = x.shape
    logits = matmul(_layernorm(x).reshape(b * t, d), params["out"])
    return logits.reshape(b, t, LM_VOCAB)


# ---------------------------------------------------------------------------
# Reference (kernel-free) variants for L2-level parity tests
# ---------------------------------------------------------------------------


def image_processing_ref(img: jax.Array) -> jax.Array:
    from .kernels.ref import grayscale_ref

    g = grayscale_ref(img)
    norm = (g - jnp.mean(g)) / (jnp.std(g) + 1e-6)
    return _downsample2(jnp.rot90(norm))


def video_processing_ref(frames: jax.Array) -> jax.Array:
    from .kernels.ref import grayscale_ref

    g = jax.vmap(grayscale_ref)(frames)
    motion = jnp.sum(jnp.abs(jnp.diff(g, axis=0)), axis=0)
    return g.at[-1].set(motion)
