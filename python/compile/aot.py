"""AOT compile path: lower every Layer-2 entry point to **HLO text** and
write ``artifacts/manifest.json`` for the Rust runtime.

HLO *text* — not ``lowered.compile().serialize()`` — is the interchange
format: the image's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos
(64-bit instruction ids, ``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts`` (what `make artifacts`
runs). Idempotent; Python never runs after this step.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Entry points and their example input shapes (all f32).
ENTRY_POINTS = {
    "float_operation": (model.float_operation, [(256, 256)]),
    "image_processing": (model.image_processing, [(256, 256, 3)]),
    "video_processing": (model.video_processing, [(8, 128, 128, 3)]),
    "tiny_lm": (model.tiny_lm, [(4, 64, model.LM_DIM)]),
    # Kernel-level artifacts (used by runtime integration tests).
    "grayscale": (lambda x: __import__(
        "compile.kernels", fromlist=["grayscale"]
    ).grayscale(x), [(128, 128, 3)]),
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for the loader)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str):
    """Lower one entry point; returns (hlo_text, input_shapes, out_shapes)."""
    fn, shapes = ENTRY_POINTS[name]
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    out = jax.eval_shape(fn, *specs)
    out_shapes = [list(out.shape)]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered), [list(s) for s in shapes], out_shapes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of entry points"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = args.only or list(ENTRY_POINTS)
    manifest = {"format": "hlo-text-v1", "artifacts": []}
    for name in names:
        print(f"lowering {name} ...", flush=True)
        hlo, in_shapes, out_shapes = lower_entry(name)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(hlo)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": in_shapes,
                "outputs": out_shapes,
            }
        )
        print(f"  wrote {fname} ({len(hlo)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {args.out}")


if __name__ == "__main__":
    main()
