"""AOT path tests: every entry point lowers to parseable HLO text with the
declared shapes, and the manifest is consistent."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


@pytest.mark.parametrize("name", list(aot.ENTRY_POINTS))
def test_entry_point_lowers_to_hlo_text(name):
    hlo, in_shapes, out_shapes = aot.lower_entry(name)
    assert "HloModule" in hlo, "must be HLO text, not a serialized proto"
    assert "ENTRY" in hlo
    assert len(in_shapes) == len(aot.ENTRY_POINTS[name][1])
    assert len(out_shapes) == 1
    assert all(d > 0 for s in out_shapes for d in s)


def test_hlo_text_is_ascii():
    hlo, _, _ = aot.lower_entry("float_operation")
    hlo.encode("ascii")  # raises on non-ascii — the Rust parser expects text


def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--only", "float_operation"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text-v1"
    (entry,) = manifest["artifacts"]
    assert entry["name"] == "float_operation"
    assert (out / entry["file"]).exists()
    assert entry["inputs"] == [[256, 256]]
    assert entry["outputs"] == [[256, 256]]
