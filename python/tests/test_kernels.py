"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; assert_allclose at float32 tolerance. This is the
core numerical signal for everything the Rust side executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, grayscale, grayscale_video, matmul
from compile.kernels.ref import attention_ref, grayscale_ref, matmul_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, -1, 1)


# ---------------------------------------------------------------------------
# grayscale
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(1, 24).map(lambda k: k * 8),
    w=st.sampled_from([16, 64, 100, 256]),
    seed=st.integers(0, 2**31),
)
def test_grayscale_matches_ref(h, w, seed):
    img = rand(seed, (h, w, 3))
    np.testing.assert_allclose(
        grayscale(img), grayscale_ref(img), rtol=1e-6, atol=1e-6
    )


def test_grayscale_odd_height_uses_unit_block():
    img = rand(0, (7, 16, 3))  # H=7: only block 1 divides it
    np.testing.assert_allclose(grayscale(img), grayscale_ref(img), rtol=1e-6, atol=1e-6)


def test_grayscale_video_vmaps():
    frames = rand(1, (4, 32, 32, 3))
    got = grayscale_video(frames)
    want = jax.vmap(grayscale_ref)(frames)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_grayscale_luma_weights_sum_to_one():
    # A constant-gray image must map to itself.
    img = jnp.full((8, 8, 3), 0.5, jnp.float32)
    np.testing.assert_allclose(grayscale(img), jnp.full((8, 8), 0.5), rtol=1e-5)


def test_grayscale_rejects_non_rgb():
    with pytest.raises(AssertionError):
        grayscale(jnp.zeros((8, 8, 4), jnp.float32))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([8, 32, 128, 192]),
    k=st.sampled_from([16, 64, 256]),
    n=st.sampled_from([8, 128, 160]),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k))
    y = rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        matmul(x, y), matmul_ref(x, y), rtol=2e-5, atol=2e-5
    )


def test_matmul_identity():
    x = rand(3, (64, 64))
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(matmul(x, eye), x, rtol=1e-5, atol=1e-6)


def test_matmul_shape_mismatch_rejected():
    with pytest.raises(AssertionError):
        matmul(jnp.zeros((8, 16), jnp.float32), jnp.zeros((8, 16), jnp.float32))


def test_matmul_prime_dims_fall_back_to_small_blocks():
    # 13 and 7 are coprime to every preferred block: forces bm=bn=1 path.
    x = rand(5, (13, 32))
    y = rand(6, (32, 7))
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 8]),
    t=st.sampled_from([4, 16, 64]),
    d=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31),
)
def test_attention_matches_ref(bh, t, d, seed):
    q = rand(seed, (bh, t, d))
    k = rand(seed + 1, (bh, t, d))
    v = rand(seed + 2, (bh, t, d))
    np.testing.assert_allclose(
        attention(q, k, v), attention_ref(q, k, v), rtol=2e-5, atol=2e-5
    )


def test_attention_rows_are_convex_combinations():
    # Softmax rows sum to 1 → output is within [min(v), max(v)] per dim.
    q = rand(7, (2, 8, 16))
    k = rand(8, (2, 8, 16))
    v = rand(9, (2, 8, 16))
    out = np.asarray(attention(q, k, v))
    v_np = np.asarray(v)
    assert out.max() <= v_np.max() + 1e-5
    assert out.min() >= v_np.min() - 1e-5


def test_attention_uniform_when_q_zero():
    # q = 0 → uniform attention → output is the mean of v.
    t = 8
    q = jnp.zeros((1, t, 16), jnp.float32)
    k = rand(10, (1, t, 16))
    v = rand(11, (1, t, 16))
    out = attention(q, k, v)
    np.testing.assert_allclose(
        out, jnp.broadcast_to(v.mean(axis=1, keepdims=True), v.shape), rtol=1e-5, atol=1e-5
    )
