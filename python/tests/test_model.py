"""Layer-2 model tests: shapes, determinism, kernel/ref parity at the graph
level, and numerical sanity of the transformer."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, -1, 1)


def test_float_operation_shape_and_finite():
    x = rand(0, (64, 64))
    y = model.float_operation(x)
    assert y.shape == (64, 64)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_float_operation_deterministic():
    x = rand(1, (32, 32))
    np.testing.assert_array_equal(model.float_operation(x), model.float_operation(x))


def test_image_processing_matches_ref():
    img = rand(2, (64, 64, 3))
    np.testing.assert_allclose(
        model.image_processing(img),
        model.image_processing_ref(img),
        rtol=1e-5,
        atol=1e-5,
    )


def test_image_processing_halves_resolution():
    img = rand(3, (128, 96, 3))
    out = model.image_processing(img)
    # rot90 of (128, 96) → (96, 128), then downsample → (48, 64)
    assert out.shape == (48, 64)


def test_video_processing_matches_ref():
    frames = rand(4, (4, 32, 32, 3))
    np.testing.assert_allclose(
        model.video_processing(frames),
        model.video_processing_ref(frames),
        rtol=1e-5,
        atol=1e-5,
    )


def test_video_processing_motion_map_nonnegative():
    frames = rand(5, (4, 16, 16, 3))
    out = model.video_processing(frames)
    assert out.shape == (4, 16, 16)
    assert bool(jnp.all(out[-1] >= 0)), "motion energy is a sum of |diffs|"


def test_tiny_lm_shapes_and_finite():
    x = rand(6, (2, 16, model.LM_DIM))
    logits = model.tiny_lm(x)
    assert logits.shape == (2, 16, model.LM_VOCAB)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_tiny_lm_deterministic_params():
    x = rand(7, (1, 8, model.LM_DIM))
    np.testing.assert_array_equal(model.tiny_lm(x), model.tiny_lm(x))


def test_tiny_lm_input_sensitivity():
    a = rand(8, (1, 8, model.LM_DIM))
    b = a.at[0, 0, 0].add(1.0)
    assert not np.allclose(model.tiny_lm(a), model.tiny_lm(b)), (
        "logits must depend on the input"
    )
