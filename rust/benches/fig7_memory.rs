//! `cargo bench --bench fig7_memory` — regenerates **Figure 7**: PSS of
//! Warm / Hibernate / WokenUp, 10 instances per workload.
//!
//! Expected shape (paper §4.2): hibernate at 7–25% of warm; woken-up at
//! 28–90% of warm. Set QH_QUICK=1 for the scaled-down run.

fn main() {
    let quick = std::env::var("QH_QUICK").is_ok();
    let rows = quark_hibernate::bench_support::fig7::run(quick);
    let mut violations = Vec::new();
    for (name, r) in &rows {
        let hib_ratio = r.hibernate as f64 / r.warm as f64;
        let wok_ratio = r.wokenup as f64 / r.warm as f64;
        if hib_ratio > 0.40 {
            violations.push(format!(
                "{name}: hibernate at {:.0}% of warm (paper band 7-25%)",
                hib_ratio * 100.0
            ));
        }
        if wok_ratio >= 1.0 {
            violations.push(format!("{name}: woken-up not below warm"));
        }
        if r.hibernate >= r.wokenup {
            violations.push(format!("{name}: hibernate not below woken-up"));
        }
    }
    if !violations.is_empty() {
        eprintln!("SHAPE VIOLATIONS:\n  {}", violations.join("\n  "));
        std::process::exit(1);
    }
    println!("fig7 shape OK");
}
