//! `cargo bench --bench fig6_latency` — regenerates **Figure 6**:
//! request-response latency of Cold / Warm / Hibernate(page-fault) /
//! Hibernate(REAP) / WokenUp for all eight evaluation workloads.
//!
//! Expected shape (paper §4.1): warm ≈ woken-up < hib-reap ≤ hib-fault ≪
//! cold; REAP at 3–67% of cold. Set QH_QUICK=1 for the scaled-down run.

fn main() {
    let quick = std::env::var("QH_QUICK").is_ok();
    let rows = quark_hibernate::bench_support::fig6::run(quick);
    // Assert the paper's shape so `cargo bench` is also a regression gate.
    let mut violations = Vec::new();
    for (name, r) in &rows {
        if r.warm_ns >= r.cold_ns {
            violations.push(format!("{name}: warm ≥ cold"));
        }
        if r.hib_reap_ns >= r.cold_ns {
            violations.push(format!("{name}: hib-reap ≥ cold"));
        }
        if r.hib_fault_ns >= r.cold_ns {
            violations.push(format!("{name}: hib-fault ≥ cold"));
        }
    }
    // REAP/cold band check across the suite (3%–67% in the paper; allow
    // a generous band since our compute substrate differs).
    let ratios: Vec<f64> = rows
        .iter()
        .map(|(_, r)| r.hib_reap_ns as f64 / r.cold_ns as f64)
        .collect();
    let min = ratios.iter().cloned().fold(f64::MAX, f64::min);
    let max = ratios.iter().cloned().fold(0.0f64, f64::max);
    println!("REAP/cold ratio across workloads: {:.0}%..{:.0}% (paper: 3%..67%)",
        min * 100.0, max * 100.0);
    if !violations.is_empty() {
        eprintln!("SHAPE VIOLATIONS:\n  {}", violations.join("\n  "));
        std::process::exit(1);
    }
    println!("fig6 shape OK");
}
