//! `cargo bench --bench ablation_policy` — design-choice ablations the
//! DESIGN.md experiment index calls out, on one fixed trace:
//!
//! 1. **REAP on/off**: hibernate wakes with batch prefetch vs pure
//!    page-fault swap-in (platform-level version of the §3.4 micro
//!    comparison);
//! 2. **predictive wake-up on/off**: Fig. 3 ⑤'s anticipatory SIGCONT vs
//!    demand-only wakes, on strictly periodic traffic where prediction is
//!    easy (the best case the mechanism is designed for).

use quark_hibernate::config::PlatformConfig;
use quark_hibernate::container::NoopRunner;
use quark_hibernate::platform::metrics::ServedFrom;
use quark_hibernate::platform::trace::{Arrival, TraceSpec};
use quark_hibernate::platform::{trace, Platform};
use quark_hibernate::util::human_ns;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn base_cfg(tag: &str) -> PlatformConfig {
    let mut cfg = PlatformConfig::default();
    cfg.host_memory = 8 << 30;
    cfg.policy.hibernate_idle_ms = 100;
    cfg.swap_dir = std::env::temp_dir()
        .join(format!("qh-ablpolicy-{tag}-{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    cfg
}

fn periodic_trace(gap_ms: u64, duration_ms: u64) -> Vec<trace::TraceEvent> {
    trace::generate(
        &[TraceSpec {
            workload: "nodejs-hello".into(),
            arrival: Arrival::Uniform {
                gap_ns: gap_ms * 1_000_000,
            },
        }],
        duration_ms * 1_000_000,
        7,
    )
}

fn run(reap: bool, predictive: bool, tag: &str) -> (f64, u64, u64) {
    let mut cfg = base_cfg(tag);
    cfg.policy.reap_enabled = reap;
    cfg.policy.predictive_wakeup = predictive;
    let p = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
    p.deploy(quark_hibernate::workloads::functionbench::nodejs_hello())
        .unwrap();
    let events = periodic_trace(500, 20_000);
    p.run_trace(&events).unwrap();
    let hib_mean = p
        .metrics
        .mean_latency("nodejs-hello", ServedFrom::Hibernate)
        .unwrap_or(0.0);
    let wok_serves = p.metrics.sample_count("nodejs-hello", ServedFrom::WokenUp) as u64;
    let anticipatory = p
        .metrics
        .counters
        .anticipatory_wakes
        .load(Ordering::Relaxed);
    (hib_mean, wok_serves, anticipatory)
}

fn main() {
    println!("== ablation: REAP batch swap-in (predictive wake off) ==");
    let (fault_mean, _, _) = run(false, false, "noreap");
    let (reap_mean, _, _) = run(true, false, "reap");
    println!(
        "hibernate-wake mean: page-fault {} vs REAP {}  ({:.2}x)",
        human_ns(fault_mean as u64),
        human_ns(reap_mean as u64),
        fault_mean / reap_mean.max(1.0)
    );
    assert!(
        reap_mean < fault_mean,
        "REAP must cut platform-level hibernate-wake latency"
    );

    println!("\n== ablation: anticipatory wake-up (REAP on) ==");
    let (_, wok_off, ant_off) = run(true, false, "nopred");
    let (_, wok_on, ant_on) = run(true, true, "pred");
    println!(
        "woken-up serves: {wok_off} → {wok_on}; anticipatory wakes: {ant_off} → {ant_on}"
    );
    assert!(ant_on > ant_off, "predictor must fire on periodic traffic");
    assert!(
        wok_on > wok_off,
        "anticipatory wakes must convert hibernate serves into woken-up serves"
    );
    println!("\nablation_policy OK");
}
