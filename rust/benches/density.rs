//! `cargo bench --bench density` — the deployment-density experiment
//! (§1/§4.2): instances packed into a fixed committed-memory budget, parked
//! Warm vs WokenUp vs Hibernate.

/// Router micro-measurement (the L3 "should not be the bottleneck" check):
/// routing decisions/s over a mixed-state 16-instance pool.
fn bench_router() {
    use quark_hibernate::config::SharingConfig;
    use quark_hibernate::container::sandbox::{Sandbox, SandboxServices};
    use quark_hibernate::container::NoopRunner;
    use quark_hibernate::platform::pool::FunctionPool;
    use quark_hibernate::platform::router::route;
    use quark_hibernate::simtime::{Clock, CostModel};
    use quark_hibernate::workloads::functionbench::{golang_hello, scaled_for_test};
    use std::sync::Arc;

    let svc = SandboxServices::new_local(
        2 << 30,
        CostModel::free(),
        SharingConfig::default(),
        Arc::new(NoopRunner),
        "router-bench",
    )
    .unwrap();
    let clock = Clock::new();
    let mut pool = FunctionPool::new();
    for i in 0..16u64 {
        let mut sb = Sandbox::cold_start(
            i,
            scaled_for_test(golang_hello(), 32),
            svc.clone(),
            &clock,
        )
        .unwrap();
        if i % 3 == 0 {
            sb.hibernate(&clock).unwrap();
        }
        pool.add(sb, i);
    }
    let n = 200_000u64;
    let t0 = std::time::Instant::now();
    let mut acc = 0usize;
    for _ in 0..n {
        if let quark_hibernate::platform::router::Route::Existing { idx, .. } = route(&pool) {
            acc = acc.wrapping_add(idx);
        }
    }
    let dt = t0.elapsed();
    println!(
        "router: {:.2}M decisions/s over a 16-instance mixed pool (acc {acc})",
        n as f64 / dt.as_secs_f64() / 1e6
    );
}

fn main() {
    bench_router();
    let quick = std::env::var("QH_QUICK").is_ok();
    let budget: u64 = if quick { 64 << 20 } else { 256 << 20 };
    let results = quark_hibernate::bench_support::density_exp::run(budget, quick);
    let warm = &results[0];
    let hib = &results[2];
    assert!(
        hib.instances > warm.instances,
        "hibernate must pack more instances ({} vs {})",
        hib.instances,
        warm.instances
    );
    println!(
        "density gain (hibernate/warm): {:.1}x",
        hib.instances as f64 / warm.instances.max(1) as f64
    );
}
