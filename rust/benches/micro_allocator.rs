//! `cargo bench --bench micro_allocator` — Fig. 4 / §3.3 allocator
//! measurements:
//!
//! 1. Bitmap Page Allocator alloc/free throughput (the page-fault-handler
//!    hot path) and lock-free refcount throughput;
//! 2. buddy-allocator baseline throughput;
//! 3. the reclamation argument, executed: naive madvise reclaim corrupts
//!    the buddy's intrusive free list, while the Bitmap allocator reclaims
//!    and keeps working;
//! 4. reclaim bandwidth (pages returned to the host per second).

use quark_hibernate::mem::bitmap_alloc::BitmapPageAllocator;
use quark_hibernate::mem::buddy::{BuddyAllocator, BuddyError};
use quark_hibernate::mem::host::HostMemory;
use quark_hibernate::util::human_ns;
use std::sync::Arc;
use std::time::Instant;

fn ops_per_sec(n: u64, elapsed: std::time::Duration) -> String {
    format!("{:.1}M ops/s", n as f64 / elapsed.as_secs_f64() / 1e6)
}

fn main() {
    let quick = std::env::var("QH_QUICK").is_ok();
    let n: u64 = if quick { 100_000 } else { 1_000_000 };

    let host = Arc::new(HostMemory::new(6 << 30).unwrap());
    let heap = Arc::new(BuddyAllocator::new(host.clone(), 0, host.size() as u64).unwrap());
    let alloc = BitmapPageAllocator::new(host.clone(), heap.clone());

    // --- 1. bitmap alloc/free ---
    println!("== Bitmap Page Allocator (Fig. 4) ==");
    let mut pages = Vec::with_capacity(n as usize);
    let t0 = Instant::now();
    for _ in 0..n {
        pages.push(alloc.alloc_page().unwrap());
    }
    let alloc_t = t0.elapsed();
    println!(
        "alloc_page x{n}: {} ({}, {} per op)",
        ops_per_sec(n, alloc_t),
        human_ns(alloc_t.as_nanos() as u64),
        human_ns(alloc_t.as_nanos() as u64 / n),
    );

    let t0 = Instant::now();
    for &g in &pages {
        alloc.inc_ref(g);
    }
    for &g in &pages {
        alloc.dec_ref(g); // back to 1, lock-free
    }
    let rc_t = t0.elapsed();
    println!("inc_ref+dec_ref x{n}: {} (lock-free)", ops_per_sec(2 * n, rc_t));

    let t0 = Instant::now();
    for &g in &pages {
        alloc.dec_ref(g); // frees
    }
    let free_t = t0.elapsed();
    println!("free x{n}: {}", ops_per_sec(n, free_t));

    // --- 2. buddy baseline ---
    println!("\n== binary buddy baseline ==");
    let m = n.min(200_000);
    let mut chunks = Vec::with_capacity(m as usize);
    let t0 = Instant::now();
    for _ in 0..m {
        chunks.push(heap.alloc_order(0).unwrap());
    }
    let buddy_alloc_t = t0.elapsed();
    let t0 = Instant::now();
    for g in chunks {
        heap.free(g).unwrap();
    }
    let buddy_free_t = t0.elapsed();
    println!(
        "buddy alloc x{m}: {}; free x{m}: {}",
        ops_per_sec(m, buddy_alloc_t),
        ops_per_sec(m, buddy_free_t)
    );

    // --- 3. the §3.3 reclamation argument, executed ---
    println!("\n== zero-fill reclamation: buddy breaks, bitmap survives ==");
    {
        let host2 = Arc::new(HostMemory::new(64 << 20).unwrap());
        let buddy = BuddyAllocator::new(host2.clone(), 0, host2.size() as u64).unwrap();
        let a = buddy.alloc_order(0).unwrap();
        buddy.free(a).unwrap();
        let free_chunks: Vec<_> = buddy.free_chunks().iter().map(|&(g, _)| g).collect();
        host2.discard_pages(&free_chunks).unwrap();
        match buddy.validate_free_lists() {
            Err(BuddyError::Corrupted { .. }) => {
                println!("buddy: free list CORRUPTED after madvise reclaim (as §3.3 predicts)")
            }
            other => panic!("buddy should have been corrupted, got {other:?}"),
        }
    }
    {
        let host2 = Arc::new(HostMemory::new(64 << 20).unwrap());
        let heap2 = Arc::new(BuddyAllocator::new(host2.clone(), 0, host2.size() as u64).unwrap());
        let alloc2 = BitmapPageAllocator::new(host2.clone(), heap2);
        let keep = alloc2.alloc_page().unwrap();
        host2.fill_page(keep, 1).unwrap();
        let pages: Vec<_> = (0..1000).map(|_| alloc2.alloc_page().unwrap()).collect();
        for &g in &pages {
            host2.fill_page(g, 2).unwrap();
        }
        for &g in &pages {
            alloc2.dec_ref(g);
        }
        let t0 = Instant::now();
        let reclaimed = alloc2.reclaim_free_pages().unwrap();
        let t = t0.elapsed();
        alloc2.check_invariants().unwrap();
        // Still fully functional afterwards.
        for _ in 0..1000 {
            alloc2.alloc_page().unwrap();
        }
        alloc2.check_invariants().unwrap();
        println!(
            "bitmap: reclaimed {reclaimed} pages in {} ({:.1}M pages/s), allocator intact",
            human_ns(t.as_nanos() as u64),
            reclaimed as f64 / t.as_secs_f64() / 1e6
        );
    }

    // --- 4. O(2) lookup claim ---
    println!("\n== O(2) free-page lookup: per-alloc cost vs occupancy ==");
    let host3 = Arc::new(HostMemory::new(1 << 30).unwrap());
    let heap3 = Arc::new(BuddyAllocator::new(host3.clone(), 0, host3.size() as u64).unwrap());
    let alloc3 = BitmapPageAllocator::new(host3, heap3);
    for fill in [0u64, 50_000, 150_000] {
        for _ in 0..fill.saturating_sub(alloc3.stats().allocated_pages) {
            alloc3.alloc_page().unwrap();
        }
        let k = 10_000;
        let t0 = Instant::now();
        let mut tmp = Vec::with_capacity(k);
        for _ in 0..k {
            tmp.push(alloc3.alloc_page().unwrap());
        }
        let per = t0.elapsed().as_nanos() as u64 / k as u64;
        for g in tmp {
            alloc3.dec_ref(g);
        }
        println!("occupancy {:>7}: {} per alloc (flat = O(2) holds)", fill, human_ns(per));
    }
    println!("\nmicro_allocator OK");
}
