//! `cargo bench --bench server_scaling` — threaded-server throughput vs
//! worker count on a multi-function workload (the sharded-control-plane
//! acceptance measurement). Each request spins a fixed real compute time,
//! so ideal scaling is linear in workers until the machine runs out of
//! cores. `QH_QUICK=1` shrinks the sweep.

use quark_hibernate::bench_support::server_scaling;

fn main() {
    let quick = std::env::var("QH_QUICK").is_ok();
    let (funcs, per_fn, spin_ns) = if quick {
        (8, 10, 500_000) // 0.5 ms/request
    } else {
        (8, 50, 2_000_000) // 2 ms/request
    };
    let worker_counts = [1usize, 2, 4, 8];
    let results = server_scaling::run(&worker_counts, funcs, per_fn, spin_ns);
    println!("workers  requests      wall         req/s   speedup");
    let base_rps = results.first().map(|r| r.rps()).unwrap_or(0.0);
    for r in &results {
        println!(
            "{:>7} {:>9} {:>9.1} ms {:>9.0} {:>8.2}x",
            r.workers,
            r.requests,
            r.wall_ns as f64 / 1e6,
            r.rps(),
            if base_rps > 0.0 { r.rps() / base_rps } else { 0.0 },
        );
    }
    // Tick-induced stall on co-sharded functions: how long the policy
    // tick runs when it hibernates a ~10 MB sandbox, synchronously vs
    // through the off-lock deflation pool. The stalled tick is what used
    // to delay every other function's hibernate/wake decision.
    println!();
    println!("== policy-tick stall while deflating a fat sandbox ==");
    println!("{:<18} {:>12} {:>12}", "deflation", "max tick", "mean tick");
    let cycles = if quick { 3 } else { 10 };
    let sync = server_scaling::tick_stall(0, cycles);
    let pooled = server_scaling::tick_stall(2, cycles);
    for r in [&sync, &pooled] {
        println!(
            "{:<18} {:>9.2} ms {:>9.2} ms",
            if r.pipeline_workers == 0 {
                "sync (old path)".to_string()
            } else {
                format!("pool ({} workers)", r.pipeline_workers)
            },
            r.max_tick_ns as f64 / 1e6,
            r.mean_tick_ns as f64 / 1e6,
        );
    }
    if pooled.max_tick_ns > 0 {
        println!(
            "tick-stall reduction: {:.1}x",
            sync.max_tick_ns as f64 / pooled.max_tick_ns as f64
        );
    }

    // The point of the sharded control plane: more workers, more
    // throughput. Allow generous slack for small or loaded machines.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 4 {
        let rps_at = |workers: usize| {
            results
                .iter()
                .find(|r| r.workers == workers)
                .map(|r| r.rps())
                .expect("worker count missing from sweep")
        };
        let r1 = rps_at(1);
        let r4 = rps_at(4);
        assert!(
            r4 > 1.5 * r1,
            "4 workers must out-serve 1 worker: {r4:.0} vs {r1:.0} req/s"
        );
    }
}
