//! `cargo bench --bench micro_swap` — the §3.4 micro-measurements:
//!
//! 1. device model: random-4K vs sequential batched read time across
//!    working-set sizes (the 100 MB/s vs >1 GB/s asymmetry REAP exploits);
//! 2. page-fault swap-in vs REAP batch swap-in over the *real* mechanism
//!    (real swap files, real page contents), charged + CPU time separately;
//! 3. **delta swap-out**: bytes written per hibernate cycle — cycle 2 on
//!    an untouched working set must write 0 bytes, a cycle after K faults
//!    writes exactly K pages (the O(dirty) contract, asserted here);
//! 4. **delta REAP**: bytes written per REAP hibernate cycle — same
//!    contract on the inflation side (untouched wake → 0 bytes; K dirtied
//!    working-set pages → exactly K), plus **wake-to-first-byte** before
//!    and after the wake_begin/wake_finish split;
//! 5. the §3.4.1 working-set table: bytes swapped out vs bytes a request
//!    reloads (Node.js hello: ~10 MB out, ~4 MB back);
//! 6. real-file I/O throughput of the swap path (CPU-side cost that the
//!    §Perf pass optimizes);
//! 7. **batched I/O under storm**: wake-to-first-byte through the batched
//!    backend while a deflation storm saturates its one worker (the
//!    Latency read must stay within a small factor of the idle wake — the
//!    priority-class contract), plus storm throughput in coalesced
//!    runs/sec;
//! 8. **flight-recorder overhead**: the same hibernate→wake cycle with the
//!    recorder disabled (the local-rig default) and enabled at
//!    platform-sized rings — check_baseline gates the self-relative ratio
//!    so tracing can never silently tax the wake path.
//!
//! Set `QH_BENCH_OUT=dir` to also write `micro_swap.csv` (the CI
//! bench-smoke artifact).

use quark_hibernate::bench_support::rig;
use quark_hibernate::config::SharingConfig;
use quark_hibernate::container::sandbox::{Sandbox, SandboxServices};
use quark_hibernate::container::NoopRunner;
use quark_hibernate::mem::page_table::{PageTable, Pte};
use quark_hibernate::mem::{Gpa, Gva};
use quark_hibernate::obs::Recorder;
use quark_hibernate::platform::io_backend::{BatchedBackend, IoBackend};
use quark_hibernate::platform::metrics::IoStats;
use quark_hibernate::simtime::{Clock, CostModel};
use quark_hibernate::swap::file::{test_pattern, SwapFileSet, SwapSlot};
use quark_hibernate::swap::SwapMgr;
use quark_hibernate::util::{human_bytes, human_ns};
use quark_hibernate::workloads::functionbench::{all_workloads, nodejs_hello, scaled_for_test};
use quark_hibernate::PAGE_SIZE;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn device_model_table() {
    println!("== §3.4 device model: random vs sequential (charged time) ==");
    println!("{:<12} {:>14} {:>14} {:>8}", "working set", "random(fault)", "seq(REAP)", "ratio");
    let m = CostModel::paper();
    for mib in [1u64, 4, 10, 32, 64, 128] {
        let bytes = mib << 20;
        let pages = bytes / PAGE_SIZE as u64;
        let random = pages * m.pagefault_swapin_ns();
        let seq = m.seq_read_ns(bytes);
        println!(
            "{:<12} {:>14} {:>14} {:>7.1}x",
            human_bytes(bytes),
            human_ns(random),
            human_ns(seq),
            random as f64 / seq as f64
        );
    }
    println!();
}

fn mechanism_comparison(pages: u64) {
    println!("== page-fault vs REAP swap-in over the real mechanism ({pages} pages) ==");
    let quick = std::env::var("QH_QUICK").is_ok();
    let pages = if quick { pages.min(512) } else { pages };
    let svc = rig(
        1 << 30,
        SharingConfig::default(),
        true,
        Arc::new(NoopRunner),
        "micro-swap",
    );
    let dir = svc.swap_dir.join("micro");
    let files = SwapFileSet::create(&dir, 99).unwrap();
    let mut mgr = SwapMgr::new(files, CostModel::paper());
    let clock = Clock::new();

    // Build one big page table with filled pages.
    let alloc = quark_hibernate::mem::bitmap_alloc::BitmapPageAllocator::new(
        svc.host.clone(),
        svc.heap.clone(),
    );
    let mut pt = PageTable::new();
    for i in 0..pages {
        let gpa = alloc.alloc_page().unwrap();
        svc.host.fill_page(gpa, i).unwrap();
        pt.map(Gva(i * 0x1000), Pte::new_present(gpa, Pte::WRITABLE));
    }

    // Swap out (measures the real CPU cost of walk+dedup+write+madvise).
    let t0 = Instant::now();
    let rpt = mgr.swap_out(&mut [&mut pt], &svc.host, &clock).unwrap();
    let swapout_cpu = t0.elapsed();
    println!(
        "swap-out: {} pages, charged {}, cpu {} ({:.0}k pages/s cpu)",
        rpt.unique_pages,
        human_ns(clock.take().0),
        human_ns(swapout_cpu.as_nanos() as u64),
        pages as f64 / swapout_cpu.as_secs_f64() / 1e3,
    );

    // Fault path: every page back one by one.
    let t0 = Instant::now();
    for i in 0..pages {
        mgr.fault_swap_in(&mut pt, Gva(i * 0x1000), &svc.host, &clock)
            .unwrap();
    }
    let fault_cpu = t0.elapsed();
    let fault_charged = clock.take().0;
    println!(
        "fault swap-in: charged {}, cpu {} ({:.0}k pages/s cpu)",
        human_ns(fault_charged),
        human_ns(fault_cpu.as_nanos() as u64),
        pages as f64 / fault_cpu.as_secs_f64() / 1e3,
    );

    // REAP path: hibernate again (REAP write) + batched prefetch.
    mgr.reap_swap_out(&mut [&mut pt], &svc.host, &clock).unwrap();
    let reap_out_charged = clock.take().0;
    let t0 = Instant::now();
    mgr.reap_swap_in(&svc.host, &clock).unwrap();
    let reap_cpu = t0.elapsed();
    let reap_charged = clock.take().0;
    println!(
        "REAP swap-out: charged {}; swap-in: charged {}, cpu {}",
        human_ns(reap_out_charged),
        human_ns(reap_charged),
        human_ns(reap_cpu.as_nanos() as u64),
    );
    println!(
        "charged speedup fault→REAP: {:.1}x (paper: ~10x at 10 MB)",
        fault_charged as f64 / reap_charged as f64
    );
    assert!(
        fault_charged > 5 * reap_charged,
        "REAP must be ≫ faster in charged device+switch time"
    );
    println!();
}

/// One CSV row per measurement for the CI artifact (`QH_BENCH_OUT`).
struct CsvOut {
    rows: Vec<String>,
}

impl CsvOut {
    fn new() -> Self {
        Self {
            rows: vec!["section,label,pages,bytes_written,charged_ns,cpu_ns".into()],
        }
    }

    fn row(&mut self, section: &str, label: &str, pages: u64, bytes: u64, charged: u64, cpu: u64) {
        self.rows
            .push(format!("{section},{label},{pages},{bytes},{charged},{cpu}"));
    }

    fn save(&self) {
        let Ok(dir) = std::env::var("QH_BENCH_OUT") else {
            return;
        };
        let _ = std::fs::create_dir_all(&dir);
        let path = std::path::Path::new(&dir).join("micro_swap.csv");
        if let Err(e) = std::fs::write(&path, self.rows.join("\n") + "\n") {
            eprintln!("micro_swap: failed to write {}: {e}", path.display());
        } else {
            println!("csv written to {}", path.display());
        }
    }
}

/// §3 above: the delta swap-out per-cycle bytes, with the acceptance
/// assertions inline — this is the before/after number for the tentpole
/// (the old path wrote `pages` images on *every* cycle).
fn delta_swapout_cycles(pages: u64, csv: &mut CsvOut) {
    println!("== delta swap-out: bytes written per hibernate cycle ({pages} pages) ==");
    let quick = std::env::var("QH_QUICK").is_ok();
    let pages = if quick { pages.min(512) } else { pages };
    let svc = rig(
        1 << 30,
        SharingConfig::default(),
        true,
        Arc::new(NoopRunner),
        "micro-swap-delta",
    );
    let dir = svc.swap_dir.join("micro-delta");
    let files = SwapFileSet::create(&dir, 98).unwrap();
    let mut mgr = SwapMgr::new(files, CostModel::paper());
    let clock = Clock::new();
    let alloc = quark_hibernate::mem::bitmap_alloc::BitmapPageAllocator::new(
        svc.host.clone(),
        svc.heap.clone(),
    );
    let mut pt = PageTable::new();
    let mut gpas = Vec::new();
    for i in 0..pages {
        let gpa = alloc.alloc_page().unwrap();
        svc.host.fill_page(gpa, i).unwrap();
        pt.map(Gva(i * 0x1000), Pte::new_present(gpa, Pte::WRITABLE | Pte::DIRTY));
        gpas.push(gpa);
    }

    let mut cycle = |label: &str, mgr: &mut SwapMgr, pt: &mut PageTable, csv: &mut CsvOut| {
        let t0 = Instant::now();
        let rpt = mgr.swap_out(&mut [pt], &svc.host, &clock).unwrap();
        let cpu = t0.elapsed().as_nanos() as u64;
        let (charged, _) = clock.take();
        println!(
            "{label:<34} wrote {:>7} ({:>4} pages), charged {}, cpu {}",
            human_bytes(rpt.bytes_written),
            rpt.unique_pages,
            human_ns(charged),
            human_ns(cpu),
        );
        csv.row("delta_swapout", label, rpt.unique_pages, rpt.bytes_written, charged, cpu);
        rpt
    };

    // Cycle 1: everything is new — the full working set goes out.
    let c1 = cycle("cycle 1 (cold, all pages new)", &mut mgr, &mut pt, csv);
    assert_eq!(c1.bytes_written, pages * PAGE_SIZE as u64);

    // Cycle 2: wake-no-touch — the delta is empty.
    let c2 = cycle("cycle 2 (untouched working set)", &mut mgr, &mut pt, csv);
    assert_eq!(
        c2.bytes_written, 0,
        "an untouched cycle must write zero page images"
    );

    // Cycle 3: fault K pages back, hibernate again — exactly K written.
    let k = pages / 4;
    for i in 0..k {
        mgr.fault_swap_in(&mut pt, Gva(i * 0x1000), &svc.host, &clock)
            .unwrap();
    }
    clock.take();
    let c3 = cycle(
        &format!("cycle 3 ({k} pages faulted back)"),
        &mut mgr,
        &mut pt,
        csv,
    );
    assert_eq!(
        c3.bytes_written,
        k * PAGE_SIZE as u64,
        "a cycle after K faults must write exactly K pages"
    );
    println!(
        "old path would have written {} per cycle; delta wrote {} then {}",
        human_bytes(pages * PAGE_SIZE as u64),
        human_bytes(c2.bytes_written),
        human_bytes(c3.bytes_written),
    );
    println!();
}

/// Delta-aware REAP: bytes written per REAP hibernate cycle, with the
/// acceptance assertions inline — a steady-state REAP hibernate after an
/// untouched wake writes 0 bytes, and after K dirtying faults writes
/// exactly K pages (the old path re-copied the whole recorded working set
/// every cycle).
fn reap_cycle_bytes(pages: u64, csv: &mut CsvOut) {
    println!("== delta REAP: bytes written per REAP hibernate cycle ({pages} pages) ==");
    let quick = std::env::var("QH_QUICK").is_ok();
    let pages = if quick { pages.min(512) } else { pages };
    let svc = rig(
        1 << 30,
        SharingConfig::default(),
        true,
        Arc::new(NoopRunner),
        "micro-swap-reap",
    );
    let dir = svc.swap_dir.join("micro-reap");
    let files = SwapFileSet::create(&dir, 97).unwrap();
    let mut mgr = SwapMgr::new(files, CostModel::paper());
    let clock = Clock::new();
    let alloc = quark_hibernate::mem::bitmap_alloc::BitmapPageAllocator::new(
        svc.host.clone(),
        svc.heap.clone(),
    );
    let mut pt = PageTable::new();
    let mut gpas = Vec::new();
    for i in 0..pages {
        let gpa = alloc.alloc_page().unwrap();
        svc.host.fill_page(gpa, i).unwrap();
        pt.map(Gva(i * 0x1000), Pte::new_present(gpa, Pte::WRITABLE | Pte::DIRTY));
        gpas.push(gpa);
    }
    // Full swap-out, then the working set (half the pages) faults back —
    // the REAP record pass.
    mgr.swap_out(&mut [&mut pt], &svc.host, &clock).unwrap();
    let ws = pages / 2;
    for i in 0..ws {
        mgr.fault_swap_in(&mut pt, Gva(i * 0x1000), &svc.host, &clock)
            .unwrap();
    }
    clock.take();

    let mut cycle = |label: &str, mgr: &mut SwapMgr, pt: &mut PageTable, csv: &mut CsvOut| {
        let t0 = Instant::now();
        let rpt = mgr.reap_swap_out(&mut [pt], &svc.host, &clock).unwrap();
        let cpu = t0.elapsed().as_nanos() as u64;
        let (charged, _) = clock.take();
        println!(
            "{label:<34} wrote {:>7} ({:>4} pages), charged {}, cpu {}",
            human_bytes(rpt.bytes_written),
            rpt.unique_pages,
            human_ns(charged),
            human_ns(cpu),
        );
        csv.row("reap_cycle", label, rpt.unique_pages, rpt.bytes_written, charged, cpu);
        let back = mgr.reap_swap_in(&svc.host, &clock).unwrap();
        assert_eq!(back, ws, "every wake prefetches the full working set");
        clock.take();
        rpt
    };

    // Cycle 1: the record pass — the whole working set is new to the REAP
    // image.
    let c1 = cycle("cycle 1 (record, all WS new)", &mut mgr, &mut pt, csv);
    assert_eq!(c1.bytes_written, ws * PAGE_SIZE as u64);

    // Cycle 2: wake-no-touch — steady state is free.
    let c2 = cycle("cycle 2 (untouched wake)", &mut mgr, &mut pt, csv);
    assert_eq!(
        c2.bytes_written, 0,
        "a steady-state REAP hibernate must write zero page images"
    );

    // Cycle 3: dirty K working-set pages — exactly K go out, in place.
    let k = ws / 4;
    for i in 0..k {
        svc.host.fill_page(gpas[i as usize], 0x4EA9 ^ i).unwrap();
        pt.update(Gva(i * 0x1000), |p| p.with(Pte::DIRTY)).unwrap();
    }
    let c3 = cycle(
        &format!("cycle 3 ({k} WS pages dirtied)"),
        &mut mgr,
        &mut pt,
        csv,
    );
    assert_eq!(
        c3.bytes_written,
        k * PAGE_SIZE as u64,
        "a REAP cycle after K dirtying writes must write exactly K pages"
    );
    println!(
        "old path would have written {} per cycle; delta wrote {} then {}",
        human_bytes(ws * PAGE_SIZE as u64),
        human_bytes(c2.bytes_written),
        human_bytes(c3.bytes_written),
    );
    println!();
}

/// Wake-to-first-byte: how long after SIGCONT the router can hand the
/// instance a request — the whole wake (flip + REAP prefetch) before the
/// wake_begin/wake_finish split, the flip alone after it (the prefetch
/// runs on the platform's pipeline, off the control path).
fn wake_to_first_byte(csv: &mut CsvOut) {
    println!("== wake-to-first-byte: serial wake vs wake_begin split ==");
    let quick = std::env::var("QH_QUICK").is_ok();
    let spec = if quick {
        scaled_for_test(nodejs_hello(), 16)
    } else {
        nodejs_hello()
    };
    let svc = rig(
        1 << 30,
        SharingConfig::default(),
        true,
        Arc::new(NoopRunner),
        "micro-swap-wake",
    );
    let clock = Clock::new();
    let mut sb = Sandbox::cold_start(2, spec, svc, &clock).unwrap();
    sb.handle_request(&clock).unwrap();
    sb.hibernate(&clock).unwrap(); // full
    sb.handle_request(&clock).unwrap(); // sample request records the WS
    sb.hibernate(&clock).unwrap(); // REAP image exists now
    clock.take();

    // Before the split: SIGCONT pays the flip *and* the batch prefetch
    // before the instance is serviceable.
    let prefetched = sb.wake(&clock).unwrap();
    let (serial_ns, _) = clock.take();
    assert!(prefetched > 0, "the serial wake must include the prefetch");
    sb.hibernate(&clock).unwrap(); // steady-state: 0 bytes through REAP
    clock.take();

    // After the split: the router ranks the instance WokenUp after the
    // flip alone; the prefetch happens off-path.
    sb.wake_begin(&clock).unwrap();
    let (split_ns, _) = clock.take();
    let finish_prefetched = sb.wake_finish(&clock).unwrap();
    let (finish_ns, _) = clock.take();
    assert!(finish_prefetched > 0);
    assert!(
        split_ns < serial_ns,
        "wake_begin must be cheaper than the full wake: {split_ns} vs {serial_ns}"
    );
    println!(
        "serial wake (flip+prefetch): {}   wake_begin only: {}   off-path finish: {}",
        human_ns(serial_ns),
        human_ns(split_ns),
        human_ns(finish_ns),
    );
    csv.row("wake_latency", "serial wake (pre-split)", prefetched, 0, serial_ns, 0);
    csv.row("wake_latency", "wake_begin (post-split)", 0, 0, split_ns, 0);
    csv.row("wake_latency", "wake_finish (off-path)", finish_prefetched, 0, finish_ns, 0);
    sb.terminate().unwrap();
    println!();
}

/// §7 above: wake-to-first-byte through the batched backend while a
/// deflation storm saturates its single worker, and the storm's own
/// throughput in coalesced runs/sec.
///
/// The wake read is Latency class, so it overtakes the queued deflation
/// chunks at a batch boundary instead of waiting out the whole storm —
/// check_baseline gates the *self-relative* ratio (storm wake ≤ factor ×
/// idle wake), which is robust to runner speed. The throughput row
/// carries the coalesced-run count in the CSV `pages` column and the
/// measurement window in `cpu_ns`; the checker derives runs/sec from the
/// two.
fn io_storm_section(csv: &mut CsvOut) {
    println!("== batched I/O: wake-to-first-byte under a deflation storm ==");
    let quick = std::env::var("QH_QUICK").is_ok();
    let attempts = if quick { 16usize } else { 64 };
    let stats = Arc::new(IoStats::default());
    let io: Arc<dyn IoBackend> = Arc::new(BatchedBackend::new(1, 1 << 30, 8, stats.clone()));
    let dir = std::env::temp_dir().join(format!("qh-micro-io-storm-{}", std::process::id()));

    // Victim: 32 REAP page images — the wake working set.
    let wake_pages: u64 = 32;
    let mut victim = SwapFileSet::create_with_backend(&dir, 50, io.clone()).unwrap();
    let slots: Vec<SwapSlot> = (0..wake_pages).map(|_| victim.alloc_reap_slot()).collect();
    let images: Vec<Vec<u8>> = (0..wake_pages)
        .map(|i| test_pattern(Gpa(i * PAGE_SIZE as u64)))
        .collect();
    let writes: Vec<(SwapSlot, &[u8])> = slots
        .iter()
        .zip(images.iter())
        .map(|(&s, p)| (s, p.as_slice()))
        .collect();
    victim.write_reap_pages_at(&writes).unwrap();

    let wake_median = |victim: &SwapFileSet| -> u64 {
        let mut samples = Vec::with_capacity(attempts);
        for _ in 0..attempts {
            let mut bufs: Vec<Vec<u8>> = vec![vec![0u8; PAGE_SIZE]; wake_pages as usize];
            let mut reads: Vec<(SwapSlot, &mut [u8])> = slots
                .iter()
                .zip(bufs.iter_mut())
                .map(|(&s, b)| (s, b.as_mut_slice()))
                .collect();
            let t0 = Instant::now();
            victim.read_reap_pages_at(&mut reads).unwrap();
            samples.push(t0.elapsed().as_nanos() as u64);
        }
        samples.sort_unstable();
        samples[samples.len() / 2]
    };

    let idle_ns = wake_median(&victim);

    // Storm: two writers, each rewriting 256 contiguous REAP slots in a
    // loop — one coalesced run per call, chopped into 8-page chunks that
    // keep the single worker's throughput queue full.
    let stop = Arc::new(AtomicBool::new(false));
    let storms: Vec<_> = (0..2u64)
        .map(|k| {
            let dir = dir.clone();
            let io = io.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut files = SwapFileSet::create_with_backend(&dir, 51 + k, io).unwrap();
                let slots: Vec<SwapSlot> = (0..256).map(|_| files.alloc_reap_slot()).collect();
                let pages: Vec<Vec<u8>> = (0..256u64)
                    .map(|i| test_pattern(Gpa((k * 1000 + i) * PAGE_SIZE as u64)))
                    .collect();
                let writes: Vec<(SwapSlot, &[u8])> = slots
                    .iter()
                    .zip(pages.iter())
                    .map(|(&s, p)| (s, p.as_slice()))
                    .collect();
                while !stop.load(Ordering::Relaxed) {
                    files.write_reap_pages_at(&writes).unwrap();
                }
            })
        })
        .collect();

    // Wait until the storm demonstrably flows before measuring.
    let runs0 = stats.runs_submitted.load(Ordering::Relaxed);
    let t0 = Instant::now();
    while stats.runs_submitted.load(Ordering::Relaxed) < runs0 + 4 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "storm writers never got going"
        );
        std::thread::sleep(Duration::from_millis(1));
    }

    let storm_ns = wake_median(&victim);

    // Storm throughput window: coalesced runs submitted per second while
    // nothing but the storm uses the backend.
    let runs_a = stats.runs_submitted.load(Ordering::Relaxed);
    let pages_a = stats.pages_submitted.load(Ordering::Relaxed);
    let t0 = Instant::now();
    std::thread::sleep(Duration::from_millis(if quick { 300 } else { 1000 }));
    let window_ns = t0.elapsed().as_nanos() as u64;
    let window_runs = stats.runs_submitted.load(Ordering::Relaxed) - runs_a;
    let window_pages = stats.pages_submitted.load(Ordering::Relaxed) - pages_a;

    stop.store(true, Ordering::Relaxed);
    for t in storms {
        t.join().unwrap();
    }

    let runs_per_sec = window_runs as f64 / (window_ns as f64 / 1e9);
    println!(
        "wake-to-first-byte: idle {} / under storm {} ({:.1}x); bypasses {}",
        human_ns(idle_ns),
        human_ns(storm_ns),
        storm_ns as f64 / idle_ns.max(1) as f64,
        stats.priority_bypasses.load(Ordering::Relaxed),
    );
    println!(
        "storm throughput: {window_runs} coalesced runs ({} pages/run) in {} = {runs_per_sec:.0} runs/s",
        if window_runs > 0 { window_pages / window_runs } else { 0 },
        human_ns(window_ns),
    );
    let wake_bytes = wake_pages * PAGE_SIZE as u64;
    csv.row("io_storm", "wake idle (median)", wake_pages, wake_bytes, 0, idle_ns);
    csv.row("io_storm", "wake under storm (median)", wake_pages, wake_bytes, 0, storm_ns);
    csv.row(
        "io_storm",
        "storm throughput (coalesced runs)",
        window_runs,
        window_pages * PAGE_SIZE as u64,
        0,
        window_ns,
    );
    std::fs::remove_dir_all(&dir).ok();
    println!();
}

/// §8 above: flight-recorder overhead on the wake path. The hibernate and
/// wake seams emit into the recorder unconditionally when it is enabled,
/// so this measures the true per-cycle tracing tax: same workload, same
/// steady-state REAP wake, recorder off vs on. check_baseline gates the
/// self-relative median ratio — robust to runner speed, sensitive only to
/// the recorder's own cost.
fn obs_overhead_section(csv: &mut CsvOut) {
    println!("== flight recorder: steady-state wake median, recorder off vs on ==");
    let quick = std::env::var("QH_QUICK").is_ok();
    let attempts = if quick { 16usize } else { 64 };

    let wake_median = |recorder: Arc<Recorder>, tag: &str| -> u64 {
        let base = rig(
            1 << 30,
            SharingConfig::default(),
            true,
            Arc::new(NoopRunner),
            tag,
        );
        // Same rig, different recorder: the only variable is tracing.
        let svc = Arc::new(SandboxServices {
            host: base.host.clone(),
            heap: base.heap.clone(),
            cache: base.cache.clone(),
            registry: base.registry.clone(),
            cost: base.cost.clone(),
            sharing: base.sharing.clone(),
            swap_dir: base.swap_dir.clone(),
            runner: base.runner.clone(),
            reap_enabled: true,
            hostenv: base.hostenv.clone(),
            io: base.io.clone(),
            recorder,
        });
        let spec = if quick {
            scaled_for_test(nodejs_hello(), 16)
        } else {
            nodejs_hello()
        };
        let clock = Clock::new();
        let mut sb = Sandbox::cold_start(7, spec, svc, &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        sb.hibernate(&clock).unwrap(); // full
        sb.handle_request(&clock).unwrap(); // sample request records the WS
        sb.hibernate(&clock).unwrap(); // REAP image exists now
        let mut samples = Vec::with_capacity(attempts);
        for _ in 0..attempts {
            let t0 = Instant::now();
            sb.wake(&clock).unwrap();
            samples.push(t0.elapsed().as_nanos() as u64);
            sb.hibernate(&clock).unwrap(); // steady state: 0 bytes out
        }
        clock.take();
        sb.terminate().unwrap();
        samples.sort_unstable();
        samples[samples.len() / 2]
    };

    let off_ns = wake_median(Recorder::disabled(), "obs-off");
    let on_ns = wake_median(Recorder::new(1, 64 << 10, true), "obs-on");
    println!(
        "steady-state wake median: recorder off {} / on {} ({:.2}x)",
        human_ns(off_ns),
        human_ns(on_ns),
        on_ns as f64 / off_ns.max(1) as f64,
    );
    csv.row("obs_overhead", "wake median (recorder off)", 0, 0, 0, off_ns);
    csv.row("obs_overhead", "wake median (recorder on)", 0, 0, 0, on_ns);
    println!();
}

fn working_set_table() {
    println!("== §3.4.1 working set: swapped-out vs reloaded per request ==");
    println!(
        "{:<18} {:>12} {:>12} {:>8}",
        "workload", "swapped out", "reloaded", "frac"
    );
    let quick = std::env::var("QH_QUICK").is_ok();
    for spec in all_workloads() {
        let spec = if quick { scaled_for_test(spec, 16) } else { spec };
        let svc = rig(
            2 << 30,
            SharingConfig::default(),
            true,
            Arc::new(NoopRunner),
            &format!("ws-{}", spec.name),
        );
        let clock = Clock::new();
        let mut sb = Sandbox::cold_start(1, spec.clone(), svc, &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        sb.hibernate(&clock).unwrap();
        sb.handle_request(&clock).unwrap(); // sample request
        let r = sb.reap_recorder();
        println!(
            "{:<18} {:>12} {:>12} {:>7.0}%",
            spec.name,
            human_bytes(r.swapped_out_bytes()),
            human_bytes(r.recorded_bytes()),
            r.working_set_fraction().unwrap_or(0.0) * 100.0
        );
        sb.terminate().unwrap();
    }
    println!("(paper: requests reload 30–90% of swapped pages; nodejs ~10MB out/~4MB back)");
    println!();
}

fn main() {
    let mut csv = CsvOut::new();
    device_model_table();
    mechanism_comparison(2560); // 10 MB — the paper's Node.js example size
    delta_swapout_cycles(2560, &mut csv);
    reap_cycle_bytes(2560, &mut csv);
    wake_to_first_byte(&mut csv);
    io_storm_section(&mut csv);
    obs_overhead_section(&mut csv);
    working_set_table();
    csv.save();
    // Shape check for the nodejs claim.
    let quick = std::env::var("QH_QUICK").is_ok();
    let spec = if quick {
        scaled_for_test(nodejs_hello(), 16)
    } else {
        nodejs_hello()
    };
    let svc = rig(
        1 << 30,
        SharingConfig::default(),
        true,
        Arc::new(NoopRunner),
        "ws-check",
    );
    let clock = Clock::new();
    let mut sb = Sandbox::cold_start(1, spec, svc, &clock).unwrap();
    sb.handle_request(&clock).unwrap();
    sb.hibernate(&clock).unwrap();
    sb.handle_request(&clock).unwrap();
    let frac = sb.reap_recorder().working_set_fraction().unwrap();
    assert!(
        (0.25..=0.95).contains(&frac),
        "nodejs working-set fraction {frac} outside the paper band"
    );
    println!("micro_swap shape OK (nodejs ws frac {:.0}%)", frac * 100.0);
}
