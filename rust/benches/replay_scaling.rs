//! `cargo bench --bench replay_scaling` — parallel trace-replay wall-clock
//! vs worker count, with the determinism contract asserted: every worker
//! count must produce the same report fingerprint. Two legs:
//!
//! * `azure-heavy-tail` under the default hibernate policy (the classic
//!   thousand-function scaling measurement);
//! * `tenant-skewed` under `tenant-fair` with per-shard budget leases on —
//!   the multi-tenant pressure machinery at scale.
//!
//! `QH_QUICK=1` shrinks both scenarios; `QH_BENCH_OUT` writes one CSV per
//! leg (`replay_scaling.csv`, `replay_scaling_tenant.csv`) for the CI
//! baseline gate.

use quark_hibernate::bench_support::replay_scaling::{self, ReplayScalingResult};

fn report_leg(tag: &str, results: &[ReplayScalingResult], csv_name: &str) {
    println!("== {tag} ==");
    println!("workers    events      wall      events/s   speedup   fingerprint");
    let base = results.first().map(|r| r.events_per_sec()).unwrap_or(0.0);
    for r in results {
        println!(
            "{:>7} {:>9} {:>9.1} ms {:>9.0} {:>8.2}x   {:016x}",
            r.workers,
            r.events,
            r.wall_ns as f64 / 1e6,
            r.events_per_sec(),
            if base > 0.0 {
                r.events_per_sec() / base
            } else {
                0.0
            },
            r.fingerprint,
        );
    }

    // The determinism contract: worker count changes wall-clock, never
    // results.
    let f0 = results[0].fingerprint;
    for r in results {
        assert_eq!(
            r.fingerprint, f0,
            "{tag}: replay results must be bit-identical at any worker count"
        );
    }

    // CI artifact: per-worker-count rows plus the shared fingerprint, so
    // the bench-smoke job can diff fingerprints across commits and gate
    // the throughput floor.
    if let Ok(dir) = std::env::var("QH_BENCH_OUT") {
        let _ = std::fs::create_dir_all(&dir);
        let mut csv = String::from("workers,events,wall_ns,events_per_sec,fingerprint\n");
        for r in results {
            csv.push_str(&format!(
                "{},{},{},{:.0},{:016x}\n",
                r.workers,
                r.events,
                r.wall_ns,
                r.events_per_sec(),
                r.fingerprint
            ));
        }
        let path = std::path::Path::new(&dir).join(csv_name);
        match std::fs::write(&path, csv) {
            Ok(()) => println!("csv written to {}", path.display()),
            Err(e) => eprintln!("replay_scaling: failed to write {}: {e}", path.display()),
        }
    }
}

fn main() {
    let quick = std::env::var("QH_QUICK").is_ok();
    let (funcs, duration_ms) = if quick {
        (200usize, 30_000u64)
    } else {
        (1000usize, 300_000u64)
    };
    let worker_counts = [1usize, 2, 4, 8];
    let results = replay_scaling::run(&worker_counts, funcs, duration_ms * 1_000_000, 0xA21);
    report_leg("azure-heavy-tail / hibernate", &results, "replay_scaling.csv");

    // The tenant leg is lighter on events (one dominant tenant) but every
    // tick pays tenant accounting + lease reconciliation — the regression
    // this leg exists to catch.
    let (t_funcs, t_duration_ms) = if quick {
        (200usize, 30_000u64)
    } else {
        (1000usize, 120_000u64)
    };
    let tenant_results = replay_scaling::run_policy(
        "tenant-skewed",
        "tenant-fair",
        true,
        &worker_counts,
        t_funcs,
        t_duration_ms * 1_000_000,
        0xA22,
    );
    report_leg(
        "tenant-skewed / tenant-fair (leases)",
        &tenant_results,
        "replay_scaling_tenant.csv",
    );

    // The scaling claim, with generous slack for small or loaded machines.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 4 && !quick {
        let eps = |workers: usize| {
            results
                .iter()
                .find(|r| r.workers == workers)
                .map(|r| r.events_per_sec())
                .expect("worker count missing from sweep")
        };
        assert!(
            eps(4) > 1.1 * eps(1),
            "4 replay workers must out-pace 1: {:.0} vs {:.0} events/s",
            eps(4),
            eps(1)
        );
    }
}
