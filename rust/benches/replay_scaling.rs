//! `cargo bench --bench replay_scaling` — parallel trace-replay wall-clock
//! vs worker count on an Azure-shaped thousand-function scenario, with the
//! determinism contract asserted: every worker count must produce the same
//! report fingerprint. `QH_QUICK=1` shrinks the scenario.

use quark_hibernate::bench_support::replay_scaling;

fn main() {
    let quick = std::env::var("QH_QUICK").is_ok();
    let (funcs, duration_ms) = if quick {
        (200usize, 30_000u64)
    } else {
        (1000usize, 300_000u64)
    };
    let worker_counts = [1usize, 2, 4, 8];
    let results = replay_scaling::run(&worker_counts, funcs, duration_ms * 1_000_000, 0xA21);
    println!("workers    events      wall      events/s   speedup   fingerprint");
    let base = results.first().map(|r| r.events_per_sec()).unwrap_or(0.0);
    for r in &results {
        println!(
            "{:>7} {:>9} {:>9.1} ms {:>9.0} {:>8.2}x   {:016x}",
            r.workers,
            r.events,
            r.wall_ns as f64 / 1e6,
            r.events_per_sec(),
            if base > 0.0 {
                r.events_per_sec() / base
            } else {
                0.0
            },
            r.fingerprint,
        );
    }

    // The determinism contract: worker count changes wall-clock, never
    // results.
    let f0 = results[0].fingerprint;
    for r in &results {
        assert_eq!(
            r.fingerprint, f0,
            "replay results must be bit-identical at any worker count"
        );
    }

    // CI artifact: per-worker-count rows plus the shared fingerprint, so
    // the bench-smoke job can diff fingerprints across commits (the first
    // step of the throughput regression gate).
    if let Ok(dir) = std::env::var("QH_BENCH_OUT") {
        let _ = std::fs::create_dir_all(&dir);
        let mut csv = String::from("workers,events,wall_ns,events_per_sec,fingerprint\n");
        for r in &results {
            csv.push_str(&format!(
                "{},{},{},{:.0},{:016x}\n",
                r.workers,
                r.events,
                r.wall_ns,
                r.events_per_sec(),
                r.fingerprint
            ));
        }
        let path = std::path::Path::new(&dir).join("replay_scaling.csv");
        match std::fs::write(&path, csv) {
            Ok(()) => println!("csv written to {}", path.display()),
            Err(e) => eprintln!("replay_scaling: failed to write {}: {e}", path.display()),
        }
    }

    // The scaling claim, with generous slack for small or loaded machines.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 4 && !quick {
        let eps = |workers: usize| {
            results
                .iter()
                .find(|r| r.workers == workers)
                .map(|r| r.events_per_sec())
                .expect("worker count missing from sweep")
        };
        assert!(
            eps(4) > 1.1 * eps(1),
            "4 replay workers must out-pace 1: {:.0} vs {:.0} events/s",
            eps(4),
            eps(1)
        );
    }
}
