//! `cargo bench --bench ablation_sharing` — the §3.5 ablation: Node.js
//! hello hibernate-wake latency with language-runtime binary sharing off
//! (production default, side-channel-safe) vs on (Cloudflare-style
//! mitigated multi-tenancy).
//!
//! Paper measurement: 25 ms → 11 ms. Our shape target: sharing cuts the
//! hibernate-wake latency ≈ 2× because the binary working set re-faults as
//! page-cache hits instead of device reads. 10 instances run per mode, as
//! in §4.2, so shared pages actually have co-tenants.

use quark_hibernate::bench_support::{ms, rig};
use quark_hibernate::config::SharingConfig;
use quark_hibernate::container::sandbox::Sandbox;
use quark_hibernate::container::NoopRunner;
use quark_hibernate::simtime::Clock;
use quark_hibernate::util::human_bytes;
use quark_hibernate::workloads::functionbench::{nodejs_hello, scaled_for_test};
use std::sync::Arc;

struct ModeResult {
    wake_ns: u64,
    mean_pss: u64,
}

fn run_mode(share_language: bool, instances: usize, quick: bool) -> ModeResult {
    let sharing = SharingConfig {
        share_runtime_binary: true,
        share_language_runtime: share_language,
    };
    let spec = if quick {
        scaled_for_test(nodejs_hello(), 16)
    } else {
        nodejs_hello()
    };
    let svc = rig(
        4 << 30,
        sharing,
        true,
        Arc::new(NoopRunner),
        &format!("sharing-{share_language}"),
    );
    let clock = Clock::new();
    let mut sbs: Vec<Sandbox> = (0..instances)
        .map(|i| {
            let mut sb =
                Sandbox::cold_start(i as u64 + 1, spec.clone(), svc.clone(), &clock).unwrap();
            sb.handle_request(&clock).unwrap();
            sb
        })
        .collect();
    // Half the fleet hibernates (with REAP images); the other half stays
    // Warm — those co-tenants are what keep shared binary pages alive in
    // the page cache, which is the entire point of the §3.5 policy.
    let sleepers = instances / 2;
    for sb in sbs.iter_mut().take(sleepers) {
        sb.hibernate(&clock).unwrap();
        sb.handle_request(&clock).unwrap(); // sample request
        sb.hibernate(&clock).unwrap(); // REAP hibernate
    }
    let mean_pss =
        sbs.iter().map(|s| s.footprint().total_bytes()).sum::<u64>() / instances as u64;
    // Wake instance 0 with a request; the other 9 stay hibernated but (in
    // sharing mode) keep the binary pages alive in the page cache.
    let before = clock.total_ns();
    sbs[0].handle_request(&clock).unwrap();
    let wake_ns = clock.total_ns() - before;
    for sb in &mut sbs {
        let _ = sb.terminate();
    }
    ModeResult { wake_ns, mean_pss }
}

fn main() {
    let quick = std::env::var("QH_QUICK").is_ok();
    let instances = if quick { 4 } else { 10 };
    println!("== §3.5 ablation: nodejs-hello hibernate wake, 10 instances ==");
    let off = run_mode(false, instances, quick);
    let on = run_mode(true, instances, quick);
    println!(
        "sharing OFF: wake {}   mean PSS {}",
        ms(off.wake_ns),
        human_bytes(off.mean_pss)
    );
    println!(
        "sharing ON:  wake {}   mean PSS {}",
        ms(on.wake_ns),
        human_bytes(on.mean_pss)
    );
    println!(
        "reduction: {:.1}x (paper: 25 ms → 11 ms ≈ 2.3x)",
        off.wake_ns as f64 / on.wake_ns as f64
    );
    assert!(
        off.wake_ns as f64 > 1.5 * on.wake_ns as f64,
        "sharing must cut hibernate-wake latency ≥1.5x ({} vs {})",
        off.wake_ns,
        on.wake_ns
    );
    assert!(on.mean_pss < off.mean_pss, "sharing must also reduce PSS");
    println!("ablation_sharing shape OK");
}
