//! Trace file I/O: load and save request traces in a simple CSV format so
//! experiments can replay recorded/production-shaped traces instead of
//! synthetic generators.
//!
//! Format (header required, `#` comments allowed):
//!
//! ```csv
//! timestamp_ms,workload
//! 0.000,nodejs-hello
//! 12.500,video-processing
//! ```
//!
//! This mirrors the Azure Functions trace release's (invocation time,
//! function) essence, which the paper's motivation leans on.

use super::trace::TraceEvent;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Parse trace text. Events are sorted by timestamp on return.
pub fn parse(text: &str) -> Result<Vec<TraceEvent>> {
    let mut lines = text
        .lines()
        .map(str::trim)
        .enumerate()
        .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'));
    let (_, header) = lines.next().context("empty trace file")?;
    let cols: Vec<&str> = header.split(',').map(str::trim).collect();
    if cols != ["timestamp_ms", "workload"] {
        bail!("bad header {header:?} (expected `timestamp_ms,workload`)");
    }
    let mut events = Vec::new();
    for (no, line) in lines {
        let Some((ts, workload)) = line.split_once(',') else {
            bail!("line {}: expected `timestamp_ms,workload`", no + 1);
        };
        let ts_ms: f64 = ts
            .trim()
            .parse()
            .with_context(|| format!("line {}: bad timestamp `{ts}`", no + 1))?;
        if ts_ms < 0.0 {
            bail!("line {}: negative timestamp", no + 1);
        }
        let workload = workload.trim();
        if workload.is_empty() {
            bail!("line {}: empty workload", no + 1);
        }
        events.push(TraceEvent {
            at_ns: (ts_ms * 1e6) as u64,
            workload: workload.to_string(),
        });
    }
    events.sort_by_key(|e| e.at_ns);
    Ok(events)
}

/// Load a trace from a file.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<TraceEvent>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading trace {}", path.as_ref().display()))?;
    parse(&text)
}

/// Save a trace (e.g. a generated one, for reproducible replays elsewhere).
pub fn save(path: impl AsRef<Path>, events: &[TraceEvent]) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    writeln!(f, "timestamp_ms,workload")?;
    for e in events {
        writeln!(f, "{:.3},{}", e.at_ns as f64 / 1e6, e.workload)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let t = parse(
            "# comment\ntimestamp_ms,workload\n0.0,a\n12.5,b\n3,a\n",
        )
        .unwrap();
        assert_eq!(t.len(), 3);
        // Sorted by time.
        assert_eq!(t[0].workload, "a");
        assert_eq!(t[1].at_ns, 3_000_000);
        assert_eq!(t[2].at_ns, 12_500_000);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("").is_err());
        assert!(parse("wrong,header\n1,a\n").is_err());
        assert!(parse("timestamp_ms,workload\nnotanumber,a\n").is_err());
        assert!(parse("timestamp_ms,workload\n-5,a\n").is_err());
        assert!(parse("timestamp_ms,workload\n5,\n").is_err());
        assert!(parse("timestamp_ms,workload\nmissing-comma\n").is_err());
    }

    #[test]
    fn round_trip_through_file() {
        let events = crate::platform::trace::paper_mix(500_000_000, 50, 9);
        let path = std::env::temp_dir().join(format!(
            "qh-trace-{}.csv",
            std::process::id()
        ));
        save(&path, &events).unwrap();
        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(events.len(), back.len());
        for (a, b) in events.iter().zip(&back) {
            assert_eq!(a.workload, b.workload);
            // ms-precision round trip.
            assert!(a.at_ns.abs_diff(b.at_ns) < 1_000_000);
        }
    }
}
