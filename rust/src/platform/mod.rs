//! The serverless platform around Hibernate Container: router, per-function
//! pools, deflate-instead-of-evict policy, anticipatory wake-up, trace
//! replay and metrics — the control plane of §3.1/§3.2.
//!
//! Two driving modes share all the machinery:
//! * **virtual-time replay** ([`Platform::run_trace`]) — deterministic
//!   discrete-event execution of a generated trace; what the figure benches
//!   use;
//! * **threaded serving** ([`server`]) — real worker threads and a policy
//!   thread, used by the end-to-end serve demo.
//!
//! # Sharded control plane
//!
//! The platform's mutable state is partitioned across a fixed array of
//! [`shard`]s (default: one per CPU) keyed by a deterministic hash of the
//! function name. Each shard owns the [`pool::FunctionPool`]s and
//! [`crate::workloads::WorkloadSpec`]s of the functions hashed to it behind
//! its own lock, so the request hot path for function A never blocks on a
//! lock held for function B, and [`Platform::policy_tick`] walks shards
//! incrementally instead of freezing the whole control plane.
//!
//! Within a shard, *instance reservations* keep critical sections short:
//! the router marks the chosen instance busy under the shard lock, the
//! shard lock is dropped, and the slow work (cold start, request
//! execution, swap I/O) runs against the sandbox alone. Routing and policy
//! decisions skip reserved instances instead of blocking on their sandbox
//! mutexes, which is what lets concurrent requests for the *same* function
//! scale out to more instances (the paper's model: one in-flight request
//! per container; concurrency comes from more containers).

pub mod density;
pub mod health;
pub mod io_backend;
pub mod metrics;
pub mod pipeline;
pub mod policy;
pub mod pool;
pub mod predictor;
pub mod predictor_store;
pub mod router;
pub mod server;
pub mod shard;
pub mod trace;
pub mod trace_file;

use crate::config::PlatformConfig;
use crate::container::sandbox::{PendingIo, RequestOutcome, Sandbox, SandboxServices};
use crate::container::state::ContainerState;
use crate::container::PayloadRunner;
use crate::obs::{pack_decision, EventKind, Recorder};
use crate::replay::chaos::{self, ChaosPlan, RequestFault};
use crate::simtime::Clock;
use crate::swap::file::SwapFileSet;
use crate::swap::{is_integrity, ImageManifest};
use crate::workloads::WorkloadSpec;
use anyhow::{bail, Context, Result};
use health::{Admission, HealthRegistry, Quarantined, Transition};
use metrics::{Metrics, ServedFrom};
use policy::{tenant_of, AppliedAction, BudgetFrame, Decision, Policy, Verb, WakeLeads};
use predictor::Predictor;
use shard::ShardSet;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use trace::TraceEvent;

/// Report for one served request.
#[derive(Debug, Clone)]
pub struct RequestReport {
    pub workload: String,
    pub served_from: ServedFrom,
    /// End-to-end virtual latency (charged model time + real compute).
    pub latency_ns: u64,
    pub charged_ns: u64,
    pub measured_ns: u64,
    pub outcome: RequestOutcome,
}

/// The platform.
pub struct Platform {
    pub cfg: PlatformConfig,
    svc: Arc<SandboxServices>,
    shards: ShardSet,
    /// The pluggable keep-alive policy ([`policy::Policy`]), resolved from
    /// `policy.kind` (or injected via [`Platform::with_policy`]).
    policy: Box<dyn Policy>,
    /// Learned per-function anticipatory wake leads: seeded at the classic
    /// 50 ms constant, updated by the pipeline from measured inflation
    /// durations, read by the policy every tick.
    wake_leads: Arc<WakeLeads>,
    /// One predictor per shard: arrival tracks are keyed by workload and
    /// workloads are shard-partitioned, so prediction state needs no
    /// cross-shard lock either.
    predictors: Vec<Predictor>,
    pub metrics: Arc<Metrics>,
    /// Off-tick instance-I/O pipeline: the policy tick flips state, this
    /// pool runs the deflations, anticipatory inflations and eviction
    /// teardowns ([`pipeline`]).
    pipeline: pipeline::InstancePipeline,
    /// Per-shard instance-id sequences. Cold starts allocate
    /// `(shard + 1) << 32 | seq`: within a shard, cold-start order is
    /// deterministic under the replay engine's shard-affine workers, so
    /// the ids — which appear in flight-recorder events and swap file
    /// names — are stable at any worker count, where one global counter
    /// would hand them out in racy cross-shard arrival order.
    next_ids: Vec<AtomicU64>,
    /// Round-robin cursor for the staggered policy cadence
    /// (`policy.tick_stride` > 1): the shard index the next
    /// [`Platform::policy_tick`] starts from.
    tick_cursor: AtomicUsize,
    /// Monotone count of [`Self::policy_tick_nowait`] calls — the phase
    /// within a `tick_stride` round (see [`Self::stride_budget_frame`]).
    nowait_calls: AtomicU64,
    /// Budget frame reused across one stride round by nowait ticks.
    budget_cache: Mutex<Arc<BudgetFrame>>,
    /// Diagnostic: how many times a nowait tick actually rebuilt the
    /// budget frame (pinned by the stride-reconciliation test).
    budget_rebuilds: AtomicU64,
    /// Hibernated images a previous process left under the swap dir
    /// (validated manifests found by the construction scan, keyed by
    /// workload), awaiting their workload's [`Self::deploy`] to be
    /// adopted into its pool. Empty when `durability.adopt_on_start` is
    /// off or nothing survived.
    adoptable: Mutex<HashMap<String, Vec<ImageManifest>>>,
    /// Deterministic fault plan (`[chaos]` config), `None` when chaos is
    /// off. Faults are drawn per (workload, domain) — see
    /// [`crate::replay::chaos`] for the determinism contract.
    chaos: Option<Arc<ChaosPlan>>,
    /// Per-function circuit breakers (`[resilience]` config): quarantine
    /// after repeated failures, half-open probes, typed rejects.
    health: HealthRegistry,
}

/// Is `err` one of the self-healing layer's *typed rejects* — a
/// quarantined function ([`health::Quarantined`]), a shed deadline
/// ([`health::TimedOut`]) or a chaos-poisoned invocation
/// ([`chaos::Poisoned`])? These are deterministic per-request outcomes the
/// platform already counted, not platform failures: the replay engine
/// drops the event's report instead of aborting the run, and the server
/// forwards them to the submitter.
pub fn is_resilience_reject(err: &anyhow::Error) -> bool {
    err.chain().any(|c| {
        c.downcast_ref::<Quarantined>().is_some()
            || c.downcast_ref::<health::TimedOut>().is_some()
            || c.downcast_ref::<chaos::Poisoned>().is_some()
    })
}

impl Platform {
    /// Build a platform with the policy `policy.kind` names (`hibernate`
    /// by default). `runner` executes payloads (PJRT in production,
    /// [`crate::container::NoopRunner`] in memory-only experiments).
    pub fn new(cfg: PlatformConfig, runner: Arc<dyn PayloadRunner>) -> Result<Self> {
        let policy = policy::build_policy(&cfg.policy)?;
        Self::with_policy(cfg, runner, policy)
    }

    /// Build with an explicitly injected [`Policy`] — how out-of-tree
    /// policies (replay-driven policy search, tests) plug in without a
    /// registry entry.
    pub fn with_policy(
        cfg: PlatformConfig,
        runner: Arc<dyn PayloadRunner>,
        policy: Box<dyn Policy>,
    ) -> Result<Self> {
        let svc = SandboxServices::new_local(
            cfg.host_memory as usize,
            cfg.cost.clone(),
            cfg.sharing.clone(),
            runner,
            "platform",
        )?;
        let shard_count = if cfg.shards > 0 {
            cfg.shards
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        };
        // The flight recorder mirrors the control plane's shard layout
        // (one ring per shard + one global ring) and is deliberately
        // created *before* Metrics: everything observable hangs off it,
        // but none of it enters `Counters::snapshot()` or the replay
        // fingerprint (see docs/observability.md).
        let recorder = Recorder::new(shard_count, cfg.obs.ring_events as usize, cfg.obs.enabled);
        // Metrics exist before the services so the I/O backend can report
        // into this platform's stats block.
        let metrics = Arc::new(Metrics::with_recorder(recorder.clone()));
        let io: Arc<dyn io_backend::IoBackend> = match cfg.io.backend.as_str() {
            "batched" => Arc::new(io_backend::BatchedBackend::with_observability(
                cfg.io.workers,
                cfg.io.max_inflight_bytes,
                cfg.io.batch_pages as usize,
                metrics.io.clone(),
                recorder.clone(),
            )),
            // Config validation admits only sync|batched.
            _ => Arc::new(io_backend::SyncBackend::with_observability(
                metrics.io.clone(),
                recorder.clone(),
            )),
        };
        // new_local defaults reap on + a private sync backend; honor config.
        let svc = Arc::new(SandboxServices {
            host: svc.host.clone(),
            heap: svc.heap.clone(),
            cache: svc.cache.clone(),
            registry: svc.registry.clone(),
            cost: cfg.cost.clone(),
            sharing: cfg.sharing.clone(),
            swap_dir: std::path::PathBuf::from(&cfg.swap_dir),
            runner: svc.runner.clone(),
            reap_enabled: cfg.policy.reap_enabled,
            hostenv: svc.hostenv.clone(),
            io,
            durability: cfg.durability.clone(),
            durability_stats: metrics.durability.clone(),
            recorder,
        });
        let wake_leads = Arc::new(WakeLeads::new(cfg.policy.adaptive_wake_lead));
        let p = Self {
            policy,
            predictors: (0..shard_count).map(|_| Predictor::new(0.3)).collect(),
            pipeline: pipeline::InstancePipeline::new(
                cfg.policy.pipeline_workers,
                metrics.clone(),
                wake_leads.clone(),
                cfg.resilience.watchdog_budget_ms.saturating_mul(1_000_000),
            ),
            wake_leads,
            chaos: ChaosPlan::from_cfg(&cfg.chaos),
            health: HealthRegistry::new(&cfg.resilience),
            metrics,
            svc,
            cfg,
            shards: ShardSet::new(shard_count),
            next_ids: (0..shard_count).map(|_| AtomicU64::new(0)).collect(),
            tick_cursor: AtomicUsize::new(0),
            nowait_calls: AtomicU64::new(0),
            budget_cache: Mutex::new(Arc::new(BudgetFrame {
                host_used: 0,
                shard_committed: Vec::new(),
                leases: None,
                tenants: Vec::new(),
            })),
            budget_rebuilds: AtomicU64::new(0),
            adoptable: Mutex::new(HashMap::new()),
        };
        // Scan the swap dir for images a previous process hibernated and
        // left behind. Valid manifests queue for adoption at deploy;
        // anything torn or corrupt is rejected loudly and deleted. A
        // failed scan degrades to cold starts, never a failed startup.
        if p.cfg.durability.adopt_on_start {
            match p.scan_adoptable() {
                Ok(n) if n > 0 => eprintln!(
                    "durability: {n} hibernated image(s) under {} await adoption",
                    p.cfg.swap_dir
                ),
                Ok(_) => {}
                Err(e) => eprintln!(
                    "durability: adoption scan of {} failed ({e:#}); cold starts only",
                    p.cfg.swap_dir
                ),
            }
        }
        // Restore persisted arrival tracks so anticipatory wake-up resumes
        // across restarts. A corrupt sidecar degrades to a cold predictor
        // (with a warning), never a failed startup.
        match p.load_predictor_state() {
            Ok(n) if n > 0 => eprintln!(
                "predictor: restored {n} arrival tracks from {}",
                p.cfg.predictor_state_file
            ),
            Ok(_) => {}
            Err(e) => eprintln!("predictor: ignoring saved state ({e:#})"),
        }
        Ok(p)
    }

    pub fn services(&self) -> &Arc<SandboxServices> {
        &self.svc
    }

    /// Number of control-plane shards (fixed at construction).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Register a function (workload) with the platform. The function's
    /// pool and spec land on the shard its name hashes to. Hibernated
    /// images a previous process persisted for this workload are adopted
    /// into the pool now — the restarted host *wakes* them instead of
    /// cold-starting (an adoption that fails validation is discarded
    /// loudly and the deploy proceeds on cold starts).
    pub fn deploy(&self, spec: WorkloadSpec) -> Result<()> {
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        {
            let mut guard = self.shards.shard_for(&spec.name).lock();
            guard.pools.entry(spec.name.clone()).or_default();
            guard.specs.insert(spec.name.clone(), spec.clone());
        }
        let pending = self
            .adoptable
            .lock()
            .unwrap()
            .remove(&spec.name)
            .unwrap_or_default();
        for m in pending {
            if let Err(e) = self.adopt_one(&spec, &m) {
                eprintln!(
                    "durability: discarding image {} of `{}` ({e:#}); \
                     the workload cold-starts instead",
                    m.file_id, spec.name
                );
                self.metrics
                    .durability
                    .manifests_rejected
                    .fetch_add(1, Ordering::Relaxed);
                if self.metrics.recorder.is_enabled() {
                    self.metrics.recorder.emit_workload(
                        EventKind::ManifestReject,
                        m.file_id,
                        crate::util::fnv1a(&spec.name),
                        m.generation,
                        0,
                    );
                }
                Self::discard_image_files(std::path::Path::new(&self.cfg.swap_dir), m.file_id);
            }
        }
        Ok(())
    }

    /// Construction-time scan of the swap dir: queue every loadable
    /// manifest for adoption at its workload's deploy, reject (and
    /// delete) torn or corrupt ones. Also reserves the id space under
    /// each pending image's file name, so a cold start in this process
    /// can never be handed an id whose swap-file names would truncate an
    /// image awaiting adoption.
    fn scan_adoptable(&self) -> Result<usize> {
        let dir = std::path::Path::new(&self.cfg.swap_dir);
        if !dir.exists() {
            return Ok(0);
        }
        let mut found = 0usize;
        let mut entries: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("scanning swap dir {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("manifest"))
            .collect();
        entries.sort(); // deterministic adoption order
        for path in entries {
            match ImageManifest::load(&path) {
                Ok(m) => {
                    let shard = (m.file_id >> 32).wrapping_sub(1) as usize;
                    if shard < self.next_ids.len() {
                        self.next_ids[shard]
                            .fetch_max((m.file_id & 0xffff_ffff) + 1, Ordering::Relaxed);
                    }
                    self.adoptable
                        .lock()
                        .unwrap()
                        .entry(m.workload.clone())
                        .or_default()
                        .push(m);
                    found += 1;
                }
                Err(e) => {
                    eprintln!(
                        "durability: rejecting manifest {} ({e:#}); discarding image",
                        path.display()
                    );
                    self.metrics
                        .durability
                        .manifests_rejected
                        .fetch_add(1, Ordering::Relaxed);
                    if self.metrics.recorder.is_enabled() {
                        self.metrics.recorder.emit_workload(
                            EventKind::ManifestReject,
                            0,
                            0,
                            0,
                            0,
                        );
                    }
                    let _ = std::fs::remove_file(&path);
                    let _ = std::fs::remove_file(path.with_extension("swap"));
                    let _ = std::fs::remove_file(path.with_extension("reap"));
                }
            }
        }
        Ok(found)
    }

    /// Adopt one pending image into `spec`'s pool: re-open the slot files
    /// against the manifest, rebuild the hibernated sandbox, register it.
    fn adopt_one(&self, spec: &WorkloadSpec, m: &ImageManifest) -> Result<()> {
        let dir = std::path::Path::new(&self.cfg.swap_dir);
        let swap_sums: Vec<(u64, u64)> =
            m.swap_pages.iter().map(|p| (p.offset, p.sum)).collect();
        let reap_sums: Vec<(u64, u64)> =
            m.reap_pages.iter().map(|p| (p.offset, p.sum)).collect();
        let files = SwapFileSet::adopt_with_backend(
            dir,
            m.file_id,
            self.svc.io.clone(),
            m.swap_len,
            &swap_sums,
            m.reap_len,
            &reap_sums,
        )?;
        let shard_idx = self.shards.index_for(&spec.name);
        let id = self.alloc_instance_id(shard_idx);
        let sb = Sandbox::adopt_hibernated(id, spec.clone(), self.svc.clone(), m, files)?;
        {
            let mut guard = self.shards.get(shard_idx).lock();
            let pool = guard
                .pools
                .get_mut(&spec.name)
                .expect("deployed workload must have a pool");
            pool.add(sb, 0);
        }
        self.metrics
            .durability
            .manifests_adopted
            .fetch_add(1, Ordering::Relaxed);
        if self.metrics.recorder.is_enabled() {
            self.metrics.recorder.emit_workload(
                EventKind::ManifestAdopt,
                id,
                crate::util::fnv1a(&spec.name),
                m.generation,
                0,
            );
        }
        Ok(())
    }

    /// Delete a discarded image's three files (manifest + slot pair).
    fn discard_image_files(dir: &std::path::Path, file_id: u64) {
        let _ = std::fs::remove_file(ImageManifest::path_for(dir, file_id));
        let _ = std::fs::remove_file(dir.join(format!("sandbox-{file_id}.swap")));
        let _ = std::fs::remove_file(dir.join(format!("sandbox-{file_id}.reap")));
    }

    /// All deployed workload names (sorted — shard iteration order is not
    /// meaningful).
    pub fn deployed(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().specs.keys().cloned().collect::<Vec<_>>())
            .collect();
        names.sort();
        names
    }

    /// Host memory currently committed (the pressure signal).
    pub fn memory_used(&self) -> u64 {
        self.svc.host.committed_bytes()
    }

    /// Serve one request at virtual time `now_vns`. Synchronous: routes,
    /// cold-starts if needed, executes, records metrics. Only the target
    /// function's shard lock is taken, and only for the route/insert steps
    /// — never across the cold start or the request execution.
    pub fn request_at(&self, workload: &str, now_vns: u64) -> Result<RequestReport> {
        self.request_at_impl(workload, now_vns, true)
    }

    /// [`Self::request_at`] with the chaos consultation explicit:
    /// internal retries (crash recovery, the integrity degrade ladder)
    /// pass `consult_chaos = false` so one arrival draws at most one
    /// request-domain fault — the retry is plumbing, not a new arrival.
    fn request_at_impl(
        &self,
        workload: &str,
        now_vns: u64,
        consult_chaos: bool,
    ) -> Result<RequestReport> {
        // Circuit breaker first: a quarantined function is rejected before
        // it touches the router, the predictor or the chaos plan — an
        // arrival the platform refuses to serve must not shape anticipation
        // or advance fault counters.
        match self.health.admit(workload, now_vns) {
            Admission::Reject { until_ns } => {
                self.metrics
                    .resilience
                    .requests_quarantined
                    .fetch_add(1, Ordering::Relaxed);
                return Err(anyhow::Error::new(Quarantined {
                    workload: workload.to_string(),
                    until_ns,
                }));
            }
            Admission::Probe { entered: true } => {
                // Open → half-open: announce once, then serve as a probe.
                if self.metrics.recorder.is_enabled() {
                    self.metrics.recorder.emit_workload(
                        EventKind::Quarantine,
                        0,
                        crate::util::fnv1a(workload),
                        2,
                        now_vns,
                    );
                }
            }
            Admission::Probe { entered: false } | Admission::Allow => {}
        }
        let fault = if consult_chaos {
            self.chaos.as_ref().and_then(|c| c.request_fault(workload))
        } else {
            None
        };
        match fault {
            // The sandbox process dies out from under the request — before
            // any of its memory mutates, so a hibernated victim's persisted
            // image is still manifest-exact and recovery can re-adopt it
            // instead of cold-starting. The retried request then serves
            // from whatever recovery produced.
            Some(RequestFault::Crash) => {
                if self.crash_routed_instance(workload, now_vns)? {
                    return self.request_at_impl(workload, now_vns, false);
                }
                // Nothing running to crash: the fault has no target.
            }
            // The invocation itself fails (a modeled function bug): typed
            // error to the caller, a failure into the breaker window.
            Some(RequestFault::Poison) => {
                let r = &self.metrics.resilience;
                r.count_fault(&r.injected_poison);
                if self.metrics.recorder.is_enabled() {
                    self.metrics.recorder.emit_workload(
                        EventKind::FaultInject,
                        0,
                        crate::util::fnv1a(workload),
                        chaos::FAULT_POISON,
                        now_vns,
                    );
                }
                self.note_health(workload, self.health.record(workload, now_vns, false));
                return Err(anyhow::Error::new(chaos::Poisoned {
                    workload: workload.to_string(),
                }));
            }
            Some(RequestFault::SlowIo { .. }) | None => {}
        }

        let shard_idx = self.shards.index_for(workload);
        let shard = self.shards.get(shard_idx);

        let clock = Clock::new();
        // Anchor the request clock at the arrival's virtual time so every
        // flight-recorder event emitted under it stamps absolute virtual
        // nanoseconds (deterministic across replay worker counts).
        clock.set_base(now_vns);
        if let Some(RequestFault::SlowIo { ns }) = fault {
            // Degraded storage under this request: the extra latency is
            // charged virtual time, so it lands in the report, the latency
            // histograms and the idleness bookkeeping identically at any
            // worker count.
            clock.charge(ns);
            let r = &self.metrics.resilience;
            r.count_fault(&r.injected_slow_io);
            if self.metrics.recorder.is_enabled() {
                self.metrics.recorder.emit_workload(
                    EventKind::FaultInject,
                    0,
                    crate::util::fnv1a(workload),
                    chaos::FAULT_SLOW_IO,
                    now_vns,
                );
            }
        }
        // Route — and reserve the chosen instance — under the shard lock;
        // run outside it. The warm path allocates nothing under the lock;
        // the spec is cloned only when a cold start actually needs it.
        let (sandbox, last_active, live_gauge, reservation, served_from) = {
            let mut guard = shard.lock();
            let pool = guard
                .pools
                .get_mut(workload)
                .with_context(|| format!("workload `{workload}` not deployed"))?;
            // Feed the arrival into this shard's predictor now that the
            // workload is known to be deployed — even if the serve below
            // fails, the arrival happened and must shape the EWMA.
            self.predictors[shard_idx].observe(workload, now_vns);
            match router::route(pool) {
                router::Route::Existing { idx, state } => {
                    let inst = &pool.instances[idx];
                    let reservation = inst
                        .try_reserve()
                        .expect("routed instance must be reservable under the shard lock");
                    (
                        inst.sandbox.clone(),
                        inst.last_active.clone(),
                        inst.live_gauge.clone(),
                        reservation,
                        ServedFrom::from_state(state),
                    )
                }
                router::Route::ColdStart => {
                    let spec = guard
                        .specs
                        .get(workload)
                        .cloned()
                        .expect("deployed workload must have a spec");
                    let id = self.alloc_instance_id(shard_idx);
                    drop(guard); // cold start is slow; don't hold the lock
                    let sb = Sandbox::cold_start(id, spec, self.svc.clone(), &clock)?;
                    self.metrics
                        .counters
                        .cold_starts
                        .fetch_add(1, Ordering::Relaxed);
                    let mut guard = shard.lock();
                    let pool = guard
                        .pools
                        .get_mut(workload)
                        .expect("deployed workload must have a pool");
                    let inst = pool.add(sb, now_vns);
                    let reservation = inst
                        .try_reserve()
                        .expect("fresh instance must be reservable");
                    (
                        inst.sandbox.clone(),
                        inst.last_active.clone(),
                        inst.live_gauge.clone(),
                        reservation,
                        ServedFrom::ColdStart,
                    )
                }
            }
        };

        let result = self.execute_request(&sandbox, &clock);

        let charged_ns = clock.charged_ns();
        let measured_ns = clock.measured_ns();
        let latency_ns = charged_ns + measured_ns;
        // Bump last-activity — only for served requests, so a persistently
        // failing instance still ages toward hibernation/eviction — before
        // releasing the reservation, so the policy loop never sees a
        // just-served instance with stale idleness. The live-byte gauge
        // refreshes at the same settled point (faults and demand wakes
        // during the request changed the footprint).
        if let Ok((_, live, _)) = &result {
            last_active.fetch_max(now_vns + latency_ns, Ordering::Relaxed);
            live_gauge.store(*live, Ordering::Relaxed);
        }
        drop(reservation); // panic-safe: would also release on unwind
        let (outcome, _, instance_id) = match result {
            Ok(ok) => ok,
            // Degrade ladder, last rung: the image failed integrity checks
            // mid-request (checksum mismatch the swap layer could not
            // rescue). Never serve corrupt memory — retire the instance
            // permanently, count the degraded start, and re-route: the
            // retried request cold-starts a replacement. Recursion is
            // bounded because each retirement removes the broken instance
            // for good.
            Err(e) if is_integrity(&e) => {
                {
                    let mut sb = sandbox.lock().unwrap();
                    eprintln!(
                        "platform: instance {} of `{workload}` failed image \
                         integrity ({e:#}); retiring it and cold-starting a \
                         replacement",
                        sb.id
                    );
                    sb.retire()?;
                }
                self.metrics
                    .durability
                    .degraded_cold_starts
                    .fetch_add(1, Ordering::Relaxed);
                if self.metrics.recorder.is_enabled() {
                    self.metrics.recorder.emit_workload(
                        EventKind::DegradeRung,
                        0,
                        crate::util::fnv1a(workload),
                        3,
                        now_vns,
                    );
                }
                return self.request_at_impl(workload, now_vns, false);
            }
            Err(e) => {
                // A terminal serve failure is a breaker-window failure; the
                // internal integrity retry above is not (it self-heals).
                self.note_health(workload, self.health.record(workload, now_vns, false));
                return Err(e);
            }
        };

        self.note_health(workload, self.health.record(workload, now_vns, true));
        self.metrics.record_latency(workload, served_from, latency_ns);
        if outcome.admission_ns > 0 {
            self.metrics.record_admission(outcome.admission_ns);
        }
        if self.metrics.recorder.is_enabled() {
            self.metrics.recorder.emit_workload(
                EventKind::Request,
                instance_id,
                crate::util::fnv1a(workload),
                latency_ns,
                clock.stamp_ns(),
            );
        }
        Ok(RequestReport {
            workload: workload.to_string(),
            served_from,
            latency_ns,
            charged_ns,
            measured_ns,
            outcome,
        })
    }

    /// Allocate a fresh instance id for a cold start landing on shard
    /// `shard_idx` (see the [`Self::next_ids`] field for the encoding and
    /// why it is per-shard).
    fn alloc_instance_id(&self, shard_idx: usize) -> u64 {
        let seq = self.next_ids[shard_idx].fetch_add(1, Ordering::Relaxed);
        ((shard_idx as u64 + 1) << 32) | seq
    }

    /// Run a routed request against its reserved sandbox. The caller holds
    /// the reservation and releases it afterwards. Returns the outcome
    /// plus the sandbox's post-request live-byte charge (for the
    /// instance's gauge) and its instance id (for the trace event).
    fn execute_request(
        &self,
        sandbox: &Arc<Mutex<Sandbox>>,
        clock: &Clock,
    ) -> Result<(RequestOutcome, u64, u64)> {
        let mut sb = sandbox.lock().unwrap();
        if !sb.state().accepts_requests() {
            bail!(
                "routed to non-accepting container in state {}",
                sb.state()
            );
        }
        if sb.state() == ContainerState::Hibernate {
            self.metrics
                .counters
                .demand_wakes
                .fetch_add(1, Ordering::Relaxed);
        }
        let outcome = sb.handle_request(clock)?;
        Ok((outcome, sb.live_bytes(), sb.id))
    }

    /// Fold a breaker transition into counters + the flight recorder.
    fn note_health(&self, workload: &str, transition: Option<Transition>) {
        let (arg, hint) = match transition {
            Some(Transition::Opened { until_ns }) => {
                self.metrics
                    .resilience
                    .breaker_opens
                    .fetch_add(1, Ordering::Relaxed);
                (1, until_ns)
            }
            Some(Transition::Closed) => {
                self.metrics
                    .resilience
                    .breaker_closes
                    .fetch_add(1, Ordering::Relaxed);
                (0, 0)
            }
            None => return,
        };
        if self.metrics.recorder.is_enabled() {
            self.metrics.recorder.emit_workload(
                EventKind::Quarantine,
                0,
                crate::util::fnv1a(workload),
                arg,
                hint,
            );
        }
    }

    /// Chaos `Crash`: kill the instance the router would have served this
    /// request from, then recover it — by re-adopting its still-valid
    /// hibernated image when the victim was deflated (its on-disk image is
    /// exactly what the manifest describes until a wake mutates memory),
    /// by leaving the retried request to cold-start otherwise. Returns
    /// `false` when the pool has no routable instance (nothing to crash).
    fn crash_routed_instance(&self, workload: &str, now_vns: u64) -> Result<bool> {
        let shard = self.shards.shard_for(workload);
        let (sandbox, reservation, spec) = {
            let guard = shard.lock();
            let Some(pool) = guard.pools.get(workload) else {
                // Not deployed: let the normal path produce its error.
                return Ok(false);
            };
            match router::route(pool) {
                router::Route::Existing { idx, .. } => {
                    let inst = &pool.instances[idx];
                    let reservation = inst
                        .try_reserve()
                        .expect("routed instance must be reservable under the shard lock");
                    (
                        inst.sandbox.clone(),
                        reservation,
                        guard.specs.get(workload).cloned(),
                    )
                }
                router::Route::ColdStart => return Ok(false),
            }
        };
        let (salvaged, victim_id) = {
            let mut sb = sandbox.lock().unwrap();
            let id = sb.id;
            (sb.crash()?, id)
        };
        drop(reservation); // the Dead victim is swept at the next tick
        let r = &self.metrics.resilience;
        r.count_fault(&r.injected_crashes);
        if self.metrics.recorder.is_enabled() {
            self.metrics.recorder.emit_workload(
                EventKind::FaultInject,
                victim_id,
                crate::util::fnv1a(workload),
                chaos::FAULT_CRASH,
                now_vns,
            );
        }
        // The crash is a failure of this function in the breaker's eyes.
        self.note_health(workload, self.health.record(workload, now_vns, false));
        let readopted = match salvaged {
            Some(m) => {
                let spec = spec.expect("deployed workload must have a spec");
                match self.adopt_one(&spec, &m) {
                    Ok(()) => true,
                    Err(e) => {
                        eprintln!(
                            "resilience: crashed instance {victim_id} of \
                             `{workload}` left image {} but re-adoption \
                             failed ({e:#}); recovering via cold start",
                            m.file_id
                        );
                        Self::discard_image_files(
                            std::path::Path::new(&self.cfg.swap_dir),
                            m.file_id,
                        );
                        false
                    }
                }
            }
            None => false,
        };
        if readopted {
            r.recovered_readopt.fetch_add(1, Ordering::Relaxed);
        } else {
            r.recovered_cold.fetch_add(1, Ordering::Relaxed);
        }
        if self.metrics.recorder.is_enabled() {
            self.metrics.recorder.emit_workload(
                EventKind::InstanceRecover,
                victim_id,
                crate::util::fnv1a(workload),
                u64::from(readopted),
                now_vns,
            );
        }
        Ok(true)
    }

    /// Draw (and announce) a pipeline-domain chaos fault for a job being
    /// dispatched for `workload`. Called from the policy apply path — on
    /// the shard owner's worker under replay — so the per-(workload,
    /// domain) draw sequence is deterministic at any worker count.
    fn assign_job_fault(
        &self,
        workload: &str,
        inflate: bool,
        instance_id: u64,
        now_vns: u64,
    ) -> Option<chaos::JobFault> {
        let fault = self.chaos.as_ref()?.job_fault(workload, inflate)?;
        let r = &self.metrics.resilience;
        match fault {
            chaos::JobFault::Hang { .. } if inflate => r.count_fault(&r.injected_hangs),
            chaos::JobFault::Hang { .. } => r.count_fault(&r.injected_stalls),
            chaos::JobFault::Panic => r.count_fault(&r.injected_panics),
        }
        if self.metrics.recorder.is_enabled() {
            self.metrics.recorder.emit_workload(
                EventKind::FaultInject,
                instance_id,
                crate::util::fnv1a(workload),
                fault.code(inflate),
                now_vns,
            );
        }
        Some(fault)
    }

    /// Reservations still held across all pools. At quiescence (no request
    /// in flight, pipeline drained) every one of these is a leak — a
    /// self-healing path that released an instance's resources without
    /// releasing its reservation would strand it unroutable forever. The
    /// chaos-smoke CI gate pins this at zero after a fault-riddled replay.
    pub fn leaked_reservations(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                let guard = s.lock();
                // lint:allow(map-iteration): commutative count over all pools
                guard
                    .pools
                    .values()
                    .flat_map(|p| p.instances.iter())
                    .filter(|i| i.is_reserved())
                    .count() as u64
            })
            .sum()
    }

    /// Run one policy tick at virtual time `now_vns`: hibernate idle
    /// containers, evict stale ones, anticipatorily wake predicted ones.
    /// Shards are walked incrementally — each decide/apply/sweep step takes
    /// only the one shard's lock, so a tick never freezes the whole
    /// control plane.
    ///
    /// With `policy.tick_stride` > 1 the walk is additionally *staggered*:
    /// each call covers only `ceil(shards / stride)` shards, rotating
    /// round-robin across calls, which bounds a single tick's tail latency
    /// at high function counts (every shard is still visited once per
    /// `stride` calls).
    ///
    /// Ticks are meant to be driven by a single policy thread (plus
    /// explicit calls in replay/tests): actions carry pool indices, so two
    /// ticks racing each other's `sweep_dead` could retarget an action.
    /// Concurrent *requests* are always safe — they only append instances
    /// and reservations re-validate state before any action applies.
    ///
    /// Deflations, inflations and teardowns submitted by this tick run on
    /// the [`pipeline`] pool — concurrently with each other — and are
    /// **drained before this returns**, so callers observe the synchronous
    /// contract (memory freed, wakes prefetched, instances routable) while
    /// the I/O itself parallelizes and never runs under a shard lock. The
    /// threaded server uses [`Self::policy_tick_nowait`] instead, which
    /// leaves jobs in flight and reaps them at its next tick.
    pub fn policy_tick(&self, now_vns: u64) -> Result<Vec<AppliedAction>> {
        let applied = self.policy_tick_nowait(now_vns)?;
        self.drain_pipeline()?;
        Ok(applied)
    }

    /// The active policy's stable name (`policy.kind` spelling).
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// The learned anticipatory wake lead for `workload` (clamped EWMA of
    /// measured inflation durations; the 50 ms seed before any sample).
    pub fn wake_lead_ns(&self, workload: &str) -> u64 {
        self.wake_leads.lead_ns(workload)
    }

    /// Reconcile the budget hierarchy: per-shard committed live bytes
    /// (the lease basis), per-shard leases when `policy.pressure_leases`
    /// is on, the per-tenant ledger when the config tracks tenants, and
    /// the host committed-bytes pressure figure. Called once per live
    /// tick, and once per replay epoch by the epoch leader — every policy
    /// decision until the next reconciliation sees this frame
    /// ([`crate::replay`]'s determinism model).
    pub fn reconcile_budget(&self) -> BudgetFrame {
        let track_tenants = self.cfg.policy.tracks_tenants();
        // The classic configuration (no leases, no tenants) needs nothing
        // but the host figure — don't sweep every shard's gauges per tick
        // just to throw the sums away.
        if !track_tenants && !self.cfg.policy.pressure_leases {
            return BudgetFrame {
                host_used: self.memory_used(),
                shard_committed: Vec::new(),
                leases: None,
                tenants: Vec::new(),
            };
        }
        let n = self.shards.len();
        let mut shard_committed = vec![0u64; n];
        let mut tenant_used: BTreeMap<String, Vec<u64>> = BTreeMap::new();
        for si in 0..n {
            let guard = self.shards.get(si).lock();
            // lint:allow(map-iteration): commutative sums into a BTreeMap
            for (w, pool) in guard.pools.iter() {
                let bytes: u64 = pool.instances.iter().map(|i| i.live_bytes()).sum();
                shard_committed[si] += bytes;
                if track_tenants {
                    if let Some(t) = tenant_of(w) {
                        tenant_used
                            .entry(t.to_string())
                            .or_insert_with(|| vec![0u64; n])[si] += bytes;
                    }
                }
            }
        }
        let leases = self.cfg.policy.pressure_leases.then(|| {
            BudgetFrame::split_leases(self.cfg.policy.memory_budget, &shard_committed)
        });
        let tenants = policy::resolve_tenants(&self.cfg.policy, &tenant_used);
        BudgetFrame {
            host_used: self.memory_used(),
            shard_committed,
            leases,
            tenants,
        }
    }

    /// Shard `si`'s *live* usage figures (gauge sums — no sandbox locks):
    /// committed bytes, plus per-tenant bytes when tenants are tracked.
    /// Under leases/tenants these are the figures the shard decides
    /// against: its own state is single-owner between epoch barriers, so
    /// the live read is deterministic and sharper than the frame-time
    /// snapshot.
    fn shard_live(&self, si: usize) -> policy::ShardLive {
        let track_tenants = self.cfg.policy.tracks_tenants();
        let guard = self.shards.get(si).lock();
        let mut committed = 0u64;
        let mut tenant_used: Vec<(String, u64)> = Vec::new();
        // lint:allow(map-iteration): commutative sums; tenant list sorted below
        for (w, pool) in guard.pools.iter() {
            let bytes: u64 = pool.instances.iter().map(|i| i.live_bytes()).sum();
            committed += bytes;
            if track_tenants {
                if let Some(t) = tenant_of(w) {
                    match tenant_used.iter_mut().find(|(n, _)| n == t) {
                        Some((_, b)) => *b += bytes,
                        None => tenant_used.push((t.to_string(), bytes)),
                    }
                }
            }
        }
        tenant_used.sort_by(|a, b| a.0.cmp(&b.0));
        policy::ShardLive {
            si,
            committed,
            tenant_used,
        }
    }

    /// [`Self::policy_tick`] without the trailing drain: pipeline jobs stay
    /// in flight (their reservations keep requests off the instances) and
    /// completions — including any errors — are reaped at the *next* tick.
    /// This is what bounds tick latency for the live policy thread: neither
    /// a 10 GB sandbox deflating nor an anticipatory wake's batch prefetch
    /// can stall the control loop anymore.
    pub fn policy_tick_nowait(&self, now_vns: u64) -> Result<Vec<AppliedAction>> {
        // Reap first, but don't let a stashed error from a *previous*
        // tick's job cancel this tick's decisions — run the walk, then
        // surface the error.
        let reaped = self.reap_pipeline();
        let n = self.shards.len();
        let stride = self.cfg.policy.tick_stride.max(1);
        let per_round = n.div_ceil(stride);
        let start = if stride == 1 {
            0
        } else {
            self.tick_cursor.fetch_add(per_round, Ordering::Relaxed) % n
        };
        let frame = self.stride_budget_frame(stride);
        let mut applied = Vec::new();
        for k in 0..per_round {
            let si = (start + k) % n;
            applied.extend(self.policy_tick_shard(si, now_vns, &frame)?);
        }
        reaped?;
        Ok(applied)
    }

    /// The budget frame one nowait tick decides against.
    ///
    /// The *expensive* frame (leases or tenant ledgers — an all-shards
    /// gauge sweep) is rebuilt on the first call of each stride round and
    /// reused by the round's remaining `stride - 1` calls: a round visits
    /// every shard exactly once, so within it each shard decides against
    /// one consistent hierarchy — the same once-per-round reconciliation
    /// the parallel replay engine's epoch frame provides. The *cheap*
    /// frame (classic config: host figure only) is O(1) and must stay
    /// fresh — it is the pressure signal — so it is rebuilt every call.
    fn stride_budget_frame(&self, stride: usize) -> Arc<BudgetFrame> {
        let expensive = self.cfg.policy.tracks_tenants() || self.cfg.policy.pressure_leases;
        let call = self.nowait_calls.fetch_add(1, Ordering::Relaxed);
        if !expensive || stride <= 1 || call % stride as u64 == 0 {
            let frame = Arc::new(self.reconcile_budget());
            self.budget_rebuilds.fetch_add(1, Ordering::Relaxed);
            *self.budget_cache.lock().unwrap() = frame.clone();
            return frame;
        }
        self.budget_cache.lock().unwrap().clone()
    }

    /// How many nowait ticks actually rebuilt the budget frame (the rest
    /// reused the stride round's cached frame — see
    /// [`Self::stride_budget_frame`]).
    pub fn budget_rebuilds(&self) -> u64 {
        self.budget_rebuilds.load(Ordering::Relaxed)
    }

    /// The shard-scoped policy step: decide/apply/sweep for shard `si`
    /// only, against a reconciled [`BudgetFrame`]. This is the unit the
    /// parallel replay engine drives — each replay worker ticks its own
    /// shards against the epoch's frame, so policy decisions are
    /// reproducible no matter how shards are spread over workers
    /// ([`crate::replay`]).
    ///
    /// Structure: one shard-lock pass snapshots every unreserved
    /// instance into [`policy::InstanceView`]s and collects the policy's
    /// [`Decision`]s — pools in sorted name order, so the budget's
    /// cross-pool deflation ledger is deterministic — then the decisions
    /// are applied (each apply re-validates under the shard lock and
    /// reserves its instance), then Dead instances are swept. Decisions
    /// carry only pool indices; the workload string is cloned exactly
    /// once per pool *with* decisions, so a steady-state tick over a
    /// thousand idle functions allocates nothing per instance.
    pub fn policy_tick_shard(
        &self,
        si: usize,
        now_vns: u64,
        frame: &BudgetFrame,
    ) -> Result<Vec<AppliedAction>> {
        let shard = self.shards.get(si);
        let live = (frame.leases.is_some() || self.cfg.policy.tracks_tenants())
            .then(|| self.shard_live(si));
        let budget = frame.mem_budget(si, &self.cfg.policy, live.as_ref());
        let ctx = policy::TickCtx {
            now_vns,
            cfg: &self.cfg.policy,
            budget: &budget,
            predictor: Some(&self.predictors[si]),
            wake_leads: &self.wake_leads,
        };
        let mut decided: Vec<(String, Vec<Decision>)> = Vec::new();
        {
            let guard = shard.lock();
            let mut sorted: Vec<(&String, &pool::FunctionPool)> = guard.pools.iter().collect();
            sorted.sort_by(|a, b| a.0.cmp(b.0));
            let mut views: Vec<policy::InstanceView> = Vec::new();
            for (w, fp) in sorted {
                views.clear();
                for (idx, inst) in fp.instances.iter().enumerate() {
                    // Reserved = request/policy action in flight: not
                    // decidable, and reading `state()` would block on the
                    // sandbox mutex.
                    if inst.is_reserved() {
                        continue;
                    }
                    views.push(policy::InstanceView {
                        idx,
                        state: inst.state(),
                        idle_ns: inst.idle_ns(now_vns),
                        live_bytes: inst.live_bytes(),
                    });
                }
                if views.is_empty() {
                    continue;
                }
                let view = policy::PoolView {
                    workload: w,
                    tenant: tenant_of(w),
                    instances: &views,
                };
                let decisions = self.policy.decide(&ctx, &view);
                if !decisions.is_empty() {
                    decided.push(((*w).clone(), decisions));
                }
            }
        }
        let mut applied = Vec::new();
        for (w, decisions) in decided {
            for d in decisions {
                // Quarantined (or probing) functions get no anticipatory
                // wakes: the breaker already judged their requests failing,
                // so prefetching their images only burns memory and I/O.
                // Deflations and evictions still apply — reclaiming a sick
                // function's instances is exactly right.
                if d.verb == Verb::Wake && self.health.is_unhealthy(&w) {
                    continue;
                }
                if self.apply(&w, d, now_vns)? {
                    self.metrics.record_decision(d.reason);
                    if self.metrics.recorder.is_enabled() {
                        self.metrics.recorder.emit(
                            si as u32,
                            EventKind::Decision,
                            0,
                            crate::util::fnv1a(&w),
                            pack_decision(d.verb.code(), d.reason.code()),
                            now_vns,
                        );
                    }
                    applied.push(AppliedAction {
                        workload: w.clone(),
                        idx: d.idx,
                        verb: d.verb,
                        reason: d.reason,
                    });
                }
            }
        }
        {
            let mut guard = shard.lock();
            // lint:allow(map-iteration): per-pool sweep; order is unobservable
            for p in guard.pools.values_mut() {
                p.sweep_dead();
            }
        }
        Ok(applied)
    }

    fn apply(&self, workload: &str, d: Decision, now_vns: u64) -> Result<bool> {
        let clock = Clock::new();
        // Anchor at tick time so the state-flip trace events
        // (hibernate_begin, wake_begin) stamp absolute virtual time.
        clock.set_base(now_vns);
        let (sandbox, last_active, live_gauge, reservation) = {
            let guard = self.shards.shard_for(workload).lock();
            let Some(pool) = guard.pools.get(workload) else {
                return Ok(false);
            };
            let Some(inst) = pool.instances.get(d.idx) else {
                return Ok(false);
            };
            let Some(reservation) = inst.try_reserve() else {
                return Ok(false); // raced with a request
            };
            (
                inst.sandbox.clone(),
                inst.last_active.clone(),
                inst.live_gauge.clone(),
                reservation,
            )
        };
        // Every action is a cheap in-tick step (a state flip, or nothing
        // at all for evictions) plus expensive I/O shipped to the
        // instance pipeline with the reservation riding along. With
        // `pipeline_workers = 0` the I/O runs inline — the pre-pipeline
        // behavior.
        match d.verb {
            Verb::Hibernate => self.apply_hibernate(
                workload,
                sandbox,
                live_gauge,
                reservation,
                now_vns,
                &clock,
            ),
            Verb::Wake => self.apply_wake(
                workload,
                sandbox,
                &last_active,
                live_gauge,
                reservation,
                now_vns,
                &clock,
            ),
            Verb::Evict => {
                self.apply_evict(workload, sandbox, live_gauge, reservation, now_vns)
            }
        }
    }

    /// The Hibernate action: the cheap SIGSTOP flip runs here (inside the
    /// tick, under nothing but the sandbox mutex — the shard lock was
    /// already released by the caller), the expensive
    /// [`Sandbox::hibernate_finish`] goes down the pipeline.
    fn apply_hibernate(
        &self,
        workload: &str,
        sandbox: Arc<Mutex<Sandbox>>,
        live_gauge: Arc<AtomicU64>,
        reservation: pool::Reservation,
        now_vns: u64,
        clock: &Clock,
    ) -> Result<bool> {
        // Size the deferred I/O from the *warm* charge, before the flip
        // below rewrites the gauge to the hibernated estimate.
        let est_bytes = live_gauge.load(Ordering::Relaxed);
        let instance_id = {
            let mut sb = sandbox.lock().unwrap();
            if !matches!(
                sb.state(),
                ContainerState::Warm | ContainerState::WokenUp
            ) {
                return Ok(false); // raced with a request
            }
            // Note: an instance served between decide() and here is still
            // deflated (its state is back to Warm/WokenUp). That race is
            // benign — the next request demand-wakes it — and an idleness
            // re-check can't be applied here because pressure-driven
            // deflation legitimately targets non-idle instances. Deliver
            // SIGSTOP through the signal queue (§3.1); only the state
            // flip happens at this safe point.
            sb.signals.send(crate::container::signal::ControlSignal::Stop);
            if sb.drain_signals_deferred(clock)? != Some(PendingIo::Deflate) {
                return Ok(false);
            }
            // Re-charge the instance as hibernated *now* (O(1): the
            // carried swap-slot image), not at finish completion: a
            // nowait tick whose deflation is still in flight must not
            // see the stale warm charge and deflate further instances
            // for overage already on its way out. The completing job
            // refines the figure; replay never observes the estimate
            // (views snapshot before applies, drains before reads).
            live_gauge.store(sb.live_bytes(), Ordering::Relaxed);
            sb.id
        };
        self.metrics
            .counters
            .hibernations
            .fetch_add(1, Ordering::Relaxed);
        self.dispatch(pipeline::PipelineJob {
            workload: workload.to_string(),
            sandbox,
            reservation,
            kind: pipeline::JobKind::Deflate,
            live_gauge,
            est_bytes,
            instance_id,
            submitted_vns: now_vns,
            // lint:allow(wall-clock): queue-wait telemetry only (IoStats wall domain)
            enqueued_wall: std::time::Instant::now(),
            chaos_fault: self.assign_job_fault(workload, false, instance_id, now_vns),
        })?;
        Ok(true)
    }

    /// The Wake action: the cheap SIGCONT flip runs here — the router
    /// immediately ranks the instance WokenUp — and the REAP batch
    /// prefetch ([`Sandbox::wake_finish`]) goes down the pipeline, so
    /// anticipatory-wake I/O no longer bounds policy-tick latency.
    #[allow(clippy::too_many_arguments)]
    fn apply_wake(
        &self,
        workload: &str,
        sandbox: Arc<Mutex<Sandbox>>,
        last_active: &AtomicU64,
        live_gauge: Arc<AtomicU64>,
        reservation: pool::Reservation,
        now_vns: u64,
        clock: &Clock,
    ) -> Result<bool> {
        let instance_id = {
            let mut sb = sandbox.lock().unwrap();
            if sb.state() != ContainerState::Hibernate {
                return Ok(false);
            }
            // Backpressure: shedding an anticipatory inflation is benign —
            // the predicted request simply demand-wakes — so a full queue
            // skips the wake *before* any state flips.
            if self.pipeline.is_async() {
                let cap = self.cfg.policy.pipeline_queue_cap;
                if cap > 0 && self.pipeline.pending() >= cap {
                    self.metrics
                        .counters
                        .pipeline_sheds
                        .fetch_add(1, Ordering::Relaxed);
                    return Ok(false);
                }
            }
            // SIGCONT through the signal queue (Fig. 3 ⑤).
            sb.signals.send(crate::container::signal::ControlSignal::Cont);
            if sb.drain_signals_deferred(clock)? != Some(PendingIo::Inflate) {
                return Ok(false);
            }
            // Mirror of the deflate-side eager re-charge: count the
            // inflating instance at its post-wake estimate (image +
            // recorded working set, O(1)) so a nowait tick with the
            // inflation still in flight doesn't read the small
            // hibernated charge as tenant/lease headroom and wake yet
            // more instances past the budget. The completing job stores
            // the real footprint; replay never observes the estimate.
            live_gauge.store(sb.wake_estimate_bytes(), Ordering::Relaxed);
            sb.id
        };
        // Waking resets idleness: the wake is in anticipation of an
        // imminent request, so the instance must not be re-deflated by the
        // very next tick.
        last_active.fetch_max(now_vns, Ordering::Relaxed);
        self.metrics
            .counters
            .anticipatory_wakes
            .fetch_add(1, Ordering::Relaxed);
        let est_bytes = live_gauge.load(Ordering::Relaxed);
        self.dispatch(pipeline::PipelineJob {
            workload: workload.to_string(),
            sandbox,
            reservation,
            kind: pipeline::JobKind::Inflate,
            live_gauge,
            est_bytes,
            instance_id,
            submitted_vns: now_vns,
            // lint:allow(wall-clock): queue-wait telemetry only (IoStats wall domain)
            enqueued_wall: std::time::Instant::now(),
            chaos_fault: self.assign_job_fault(workload, true, instance_id, now_vns),
        })?;
        Ok(true)
    }

    /// The Evict action: no state flips in-tick — the reservation alone
    /// fences the instance — and [`Sandbox::terminate`]'s page/host-object
    /// release goes down the pipeline. The Dead instance is swept at a
    /// later tick, exactly like deflation completions are reaped.
    fn apply_evict(
        &self,
        workload: &str,
        sandbox: Arc<Mutex<Sandbox>>,
        live_gauge: Arc<AtomicU64>,
        reservation: pool::Reservation,
        now_vns: u64,
    ) -> Result<bool> {
        let instance_id = {
            let sb = sandbox.lock().unwrap();
            if !sb.state().accepts_requests() {
                return Ok(false);
            }
            sb.id
        };
        let est_bytes = live_gauge.load(Ordering::Relaxed);
        self.dispatch(pipeline::PipelineJob {
            workload: workload.to_string(),
            sandbox,
            reservation,
            kind: pipeline::JobKind::Teardown,
            live_gauge,
            est_bytes,
            instance_id,
            submitted_vns: now_vns,
            // lint:allow(wall-clock): queue-wait telemetry only (IoStats wall domain)
            enqueued_wall: std::time::Instant::now(),
            chaos_fault: self.assign_job_fault(workload, false, instance_id, now_vns),
        })?;
        Ok(true)
    }

    /// Ship a job to the pipeline, honoring the backpressure cap
    /// (`policy.pipeline_queue_cap`, 0 = unbounded): on overflow a job is
    /// shed — run inline on the tick, which self-throttles the control
    /// loop instead of letting the queue grow without bound under a
    /// pressure storm. *Which* job pays is size-aware: when the incoming
    /// job is a deflation and a strictly larger deflation is still
    /// queued, the larger one is pulled and run inline (most deferred I/O
    /// retired per shed slot — `pipeline_sheds_largest`) and the incoming
    /// job queues in its place; otherwise the incoming job runs inline
    /// (`pipeline_sheds`). Inflations are shed earlier, in
    /// [`Self::apply_wake`], before any state flips.
    fn dispatch(&self, job: pipeline::PipelineJob) -> Result<()> {
        if !self.pipeline.is_async() {
            return self.pipeline.run_sync(job);
        }
        let cap = self.cfg.policy.pipeline_queue_cap;
        if cap > 0
            && job.kind != pipeline::JobKind::Inflate
            && self.pipeline.pending() >= cap
        {
            if job.kind == pipeline::JobKind::Deflate {
                if let Some(victim) = self.pipeline.steal_largest_deflation(job.est_bytes) {
                    self.metrics
                        .counters
                        .pipeline_sheds_largest
                        .fetch_add(1, Ordering::Relaxed);
                    self.pipeline.submit(job);
                    return self.pipeline.run_inline(victim);
                }
            }
            self.metrics
                .counters
                .pipeline_sheds
                .fetch_add(1, Ordering::Relaxed);
            return self.pipeline.run_sync(job);
        }
        self.pipeline.submit(job);
        Ok(())
    }

    /// Pipeline jobs (deflations, inflations, teardowns) queued or in
    /// flight right now.
    pub fn pending_pipeline(&self) -> usize {
        self.pipeline.pending()
    }

    /// Non-blocking: fold completed pipeline jobs (surfacing the first
    /// error stashed since the last reap). Called at the top of every tick.
    pub fn reap_pipeline(&self) -> Result<u64> {
        self.pipeline.reap()
    }

    /// Block until every in-flight pipeline job has completed, then reap.
    /// The replay engine calls this after each tick batch so policy
    /// decisions — and the memory they free or prefetch — are
    /// interleaving-independent.
    pub fn drain_pipeline(&self) -> Result<u64> {
        self.pipeline.drain()
    }

    /// Write the flight recorder's contents as Chrome trace-event JSON
    /// (loadable in Perfetto / `chrome://tracing`) to `path`. One track
    /// per control-plane shard plus an `io` track; see
    /// `docs/observability.md` for the event taxonomy.
    pub fn dump_trace(&self, path: &str) -> Result<()> {
        let json = crate::obs::chrome_trace::render(&self.metrics.recorder);
        std::fs::write(path, json).with_context(|| format!("writing trace to {path}"))?;
        Ok(())
    }

    /// Test hook: make pipeline workers block on `gate` before each job,
    /// so a test can hold a deflation or inflation in flight
    /// deterministically.
    #[doc(hidden)]
    pub fn set_pipeline_gate(&self, gate: Option<pipeline::PipelineGate>) {
        self.pipeline.set_gate(gate);
    }

    /// Deterministic virtual-time replay: process events in order, running
    /// a policy tick before each event and at a fixed cadence in gaps.
    ///
    /// This is the single-worker form of the parallel replay engine
    /// ([`crate::replay::ReplayEngine`]) — same epoch structure, same tick
    /// schedule, one worker — so a trace replayed here and a trace replayed
    /// with `workers = N` land on identical per-function results.
    pub fn run_trace(&self, events: &[TraceEvent]) -> Result<Vec<RequestReport>> {
        crate::replay::ReplayEngine::single_threaded(self)
            .run(events)
            .map(|o| o.reports)
    }

    /// Snapshot: per-workload learned wake lead plus instance states +
    /// PSS (the Fig. 7 data), sorted by workload name. Diagnostic — may
    /// wait on in-flight requests' sandboxes, but never while holding a
    /// shard lock, so a slow request can't stall routing for the rest of
    /// its shard.
    pub fn pool_snapshot(&self) -> Vec<(String, u64, Vec<(ContainerState, u64)>)> {
        let mut out: Vec<(String, u64, Vec<(ContainerState, u64)>)> = Vec::new();
        for shard in self.shards.iter() {
            // Clone sandbox handles under the shard lock; read them after
            // dropping it.
            let handles: Vec<(String, Vec<Arc<Mutex<Sandbox>>>)> = {
                let guard = shard.lock();
                // lint:allow(map-iteration): the snapshot is sorted by name below
                guard
                    .pools
                    .iter()
                    .map(|(w, pool)| {
                        let sandboxes =
                            pool.instances.iter().map(|i| i.sandbox.clone()).collect();
                        (w.clone(), sandboxes)
                    })
                    .collect()
            };
            for (w, sandboxes) in handles {
                let rows = sandboxes
                    .iter()
                    .map(|s| {
                        let sb = s.lock().unwrap();
                        (sb.state(), sb.footprint().total_bytes())
                    })
                    .collect();
                let lead = self.wake_leads.lead_ns(&w);
                out.push((w, lead, rows));
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Direct access for tests/benches that need a single sandbox.
    pub fn with_instance<T>(
        &self,
        workload: &str,
        idx: usize,
        f: impl FnOnce(&mut Sandbox) -> T,
    ) -> Option<T> {
        let sandbox = {
            let guard = self.shards.shard_for(workload).lock();
            guard
                .pools
                .get(workload)?
                .instances
                .get(idx)?
                .sandbox
                .clone()
        };
        let mut sb = sandbox.lock().unwrap();
        Some(f(&mut sb))
    }

    pub fn instance_count(&self, workload: &str) -> usize {
        self.shards
            .shard_for(workload)
            .lock()
            .pools
            .get(workload)
            .map(|p| p.len())
            .unwrap_or(0)
    }

    /// The control-plane shard index owning `workload` (stable for the
    /// platform's lifetime) — the placement the replay engine partitions
    /// trace events by.
    pub fn shard_index(&self, workload: &str) -> usize {
        self.shards.index_for(workload)
    }

    /// Predicted next arrival for `workload` from its shard's predictor
    /// (diagnostics / persistence tests).
    pub fn predicted_next_arrival(&self, workload: &str) -> Option<u64> {
        self.predictors[self.shards.index_for(workload)].predicted_next(workload)
    }

    /// Every shard predictor's arrival tracks, merged and sorted by
    /// workload. Stored flat: the workload → shard mapping is recomputed on
    /// load, so the file stays valid across shard-count changes.
    pub fn predictor_tracks(&self) -> Vec<predictor_store::TrackRow> {
        let mut rows: Vec<_> = self
            .predictors
            .iter()
            .flat_map(|p| p.export_tracks())
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }

    /// Route persisted tracks to their owning shards' predictors. Returns
    /// the number of tracks imported.
    ///
    /// The restored `last_arrival_ns` is **rebased to 0**: each process
    /// has its own virtual timeline starting at 0, so a raw timestamp from
    /// the previous run would place the predicted next arrival far in the
    /// future (silencing `should_wake` for the whole run) and corrupt the
    /// EWMA on the first new observation (a huge or zero apparent gap).
    /// What survives a restart is the *learned cadence* — the EWMA gap and
    /// sample count; rebasing treats the restart itself as an arrival at
    /// t = 0, so anticipation resumes after one learned gap.
    pub fn import_predictor_tracks(&self, rows: &[predictor_store::TrackRow]) -> usize {
        for (w, _last, ewma, n) in rows {
            self.predictors[self.shards.index_for(w)].import_track(w, 0, *ewma, *n);
        }
        rows.len()
    }

    /// Persist predictor state to `predictor_state_file`. Returns `false`
    /// (and does nothing) when persistence is not configured.
    pub fn save_predictor_state(&self) -> Result<bool> {
        if self.cfg.predictor_state_file.is_empty() {
            return Ok(false);
        }
        predictor_store::save(&self.cfg.predictor_state_file, &self.predictor_tracks())?;
        Ok(true)
    }

    /// Load predictor state from `predictor_state_file`, if configured and
    /// present. Returns the number of tracks restored (0 when persistence
    /// is off or the file does not exist yet).
    pub fn load_predictor_state(&self) -> Result<usize> {
        if self.cfg.predictor_state_file.is_empty() {
            return Ok(0);
        }
        let path = std::path::Path::new(&self.cfg.predictor_state_file);
        if !path.exists() {
            return Ok(0);
        }
        let rows = predictor_store::load(path)?;
        Ok(self.import_predictor_tracks(&rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::NoopRunner;
    use crate::simtime::CostModel;
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};

    // Each test gets its own swap dir (keyed by `tag`): adopt_on_start is
    // the default, so a shared dir would let one test's persisted
    // hibernated image be adopted by a concurrently-constructed platform
    // of another test.
    fn test_platform(tag: &str, hibernate_idle_ms: u64) -> Platform {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.cost = CostModel::paper();
        cfg.policy.hibernate_idle_ms = hibernate_idle_ms;
        cfg.policy.predictive_wakeup = false;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-platform-{tag}-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        p.deploy(scaled_for_test(golang_hello(), 16)).unwrap();
        p
    }

    #[test]
    fn first_request_cold_starts_then_warm() {
        let p = test_platform("warm", 1000);
        let r1 = p.request_at("golang-hello", 0).unwrap();
        assert_eq!(r1.served_from, ServedFrom::ColdStart);
        let r2 = p.request_at("golang-hello", r1.latency_ns + 1).unwrap();
        assert_eq!(r2.served_from, ServedFrom::Warm);
        assert!(
            r2.latency_ns < r1.latency_ns / 5,
            "warm {} vs cold {}",
            r2.latency_ns,
            r1.latency_ns
        );
        assert_eq!(p.instance_count("golang-hello"), 1);
    }

    #[test]
    fn idle_container_hibernates_then_serves() {
        let p = test_platform("idle", 10);
        let r1 = p.request_at("golang-hello", 0).unwrap();
        let t1 = r1.latency_ns;
        // Idle long past the threshold → policy hibernates it.
        let actions = p.policy_tick(t1 + 50_000_000).unwrap();
        assert!(
            actions.iter().any(|a| a.verb == Verb::Hibernate),
            "{actions:?}"
        );
        let r2 = p
            .request_at("golang-hello", t1 + 60_000_000)
            .unwrap();
        assert_eq!(r2.served_from, ServedFrom::Hibernate);
        // Hibernate-wake is slower than warm but much faster than cold.
        assert!(r2.latency_ns < r1.latency_ns / 2);
        // And the next one is WokenUp ≈ warm.
        let r3 = p
            .request_at("golang-hello", t1 + 70_000_000 + r2.latency_ns)
            .unwrap();
        assert_eq!(r3.served_from, ServedFrom::WokenUp);
    }

    #[test]
    fn trace_replay_records_metrics() {
        let p = test_platform("trace", 20);
        let events: Vec<TraceEvent> = (0..5)
            .map(|i| TraceEvent {
                at_ns: i * 200_000_000,
                workload: "golang-hello".into(),
            })
            .collect();
        let reports = p.run_trace(&events).unwrap();
        assert_eq!(reports.len(), 5);
        assert_eq!(reports[0].served_from, ServedFrom::ColdStart);
        // 200 ms gaps ≫ 20 ms idle threshold → later requests hit
        // hibernated containers, not cold starts.
        assert!(reports[1..]
            .iter()
            .all(|r| r.served_from != ServedFrom::ColdStart));
        assert!(p.metrics.counters.hibernations.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn unknown_workload_rejected() {
        let p = test_platform("unknown", 10);
        assert!(p.request_at("nope", 0).is_err());
    }

    #[test]
    fn memory_pressure_triggers_deflation() {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.policy.hibernate_idle_ms = 1_000_000; // effectively never idle
        cfg.policy.memory_budget = 1 << 20; // absurdly tight → always pressure
        cfg.policy.predictive_wakeup = false;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-pressure-test-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        p.deploy(scaled_for_test(golang_hello(), 16)).unwrap();
        p.request_at("golang-hello", 0).unwrap();
        let used_before = p.memory_used();
        let actions = p.policy_tick(1).unwrap();
        assert!(actions.iter().any(|a| a.verb == Verb::Hibernate));
        assert!(
            p.memory_used() < used_before,
            "deflation must reduce committed memory: {} -> {}",
            used_before,
            p.memory_used()
        );
    }

    #[test]
    fn deploys_partition_across_shards() {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.shards = 4;
        cfg.cost = CostModel::free();
        cfg.policy.predictive_wakeup = false;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-shards-test-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        assert_eq!(p.shard_count(), 4);
        let mut names = Vec::new();
        for i in 0..8 {
            let mut s = scaled_for_test(golang_hello(), 32);
            s.name = format!("fn-{i}");
            names.push(s.name.clone());
            p.deploy(s).unwrap();
        }
        names.sort();
        assert_eq!(p.deployed(), names);
        // Every function serves independently of its shard placement.
        for n in &names {
            let r = p.request_at(n, 0).unwrap();
            assert_eq!(r.served_from, ServedFrom::ColdStart);
            assert_eq!(p.instance_count(n), 1);
        }
        assert_eq!(p.metrics.counters.requests.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn shard_count_defaults_to_parallelism() {
        let p = test_platform("parallel", 1000);
        let want = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        assert_eq!(p.shard_count(), want);
    }

    #[test]
    fn staggered_ticks_cover_all_shards_over_a_full_rotation() {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.shards = 4;
        cfg.cost = CostModel::free();
        cfg.policy.hibernate_idle_ms = 10;
        cfg.policy.predictive_wakeup = false;
        cfg.policy.tick_stride = 4; // 1 shard per tick
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-stagger-test-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        for i in 0..8 {
            let mut s = scaled_for_test(golang_hello(), 32);
            s.name = format!("fn-{i}");
            p.deploy(s).unwrap();
        }
        for i in 0..8 {
            p.request_at(&format!("fn-{i}"), 0).unwrap();
        }
        // All 8 instances are idle far past the threshold. One staggered
        // tick covers 1/4 of the shards; four ticks cover all of them.
        let mut hibernated = 0usize;
        for _ in 0..4 {
            let actions = p.policy_tick(1_000_000_000).unwrap();
            hibernated += actions
                .iter()
                .filter(|a| a.verb == Verb::Hibernate)
                .count();
        }
        assert_eq!(
            hibernated, 8,
            "a full stride rotation must visit every shard exactly once"
        );
        // Stride 1 (the default) still covers everything in one call.
        let p2 = test_platform("stagger2", 10);
        p2.request_at("golang-hello", 0).unwrap();
        let actions = p2.policy_tick(1_000_000_000).unwrap();
        assert!(actions.iter().any(|a| a.verb == Verb::Hibernate));
    }

    #[test]
    fn stride_reuses_budget_frame_across_a_round() {
        // Expensive frame (leases on) + stride 4: the sweep runs once per
        // round, so 8 nowait ticks rebuild exactly twice.
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.shards = 4;
        cfg.cost = CostModel::free();
        cfg.policy.predictive_wakeup = false;
        cfg.policy.tick_stride = 4;
        cfg.policy.pressure_leases = true;
        cfg.policy.memory_budget = 256 << 20;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-stride-frame-test-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Platform::new(cfg.clone(), Arc::new(NoopRunner)).unwrap();
        for i in 0..8u64 {
            p.policy_tick_nowait(i).unwrap();
        }
        assert_eq!(
            p.budget_rebuilds(),
            2,
            "stride 4 must reconcile once per 4-tick round"
        );

        // Stride 1 reconciles every call, leases or not.
        cfg.policy.tick_stride = 1;
        let p2 = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        for i in 0..3u64 {
            p2.policy_tick_nowait(i).unwrap();
        }
        assert_eq!(p2.budget_rebuilds(), 3);
    }

    #[test]
    fn predictor_state_survives_restart() {
        let state = std::env::temp_dir()
            .join(format!("qh-predstate-test-{}.csv", std::process::id()))
            .to_string_lossy()
            .into_owned();
        std::fs::remove_file(&state).ok();
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.cost = CostModel::free();
        cfg.policy.predictive_wakeup = true;
        cfg.predictor_state_file = state.clone();
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-predstate-swap-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();

        let p = Platform::new(cfg.clone(), Arc::new(NoopRunner)).unwrap();
        p.deploy(scaled_for_test(golang_hello(), 16)).unwrap();
        // Strictly periodic 500 ms arrivals → the learned EWMA gap is
        // exactly 500 ms.
        let mut t = 0u64;
        for _ in 0..5 {
            p.request_at("golang-hello", t).unwrap();
            t += 500_000_000;
        }
        assert!(p.save_predictor_state().unwrap());

        // "Restart": a fresh platform with the same config restores the
        // tracks at construction and predicts without new observations —
        // in the *new* process's time domain (last arrival rebased to 0),
        // so the next arrival is expected one learned gap after start.
        let p2 = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        p2.deploy(scaled_for_test(golang_hello(), 16)).unwrap();
        assert_eq!(
            p2.predicted_next_arrival("golang-hello"),
            Some(500_000_000),
            "restored prediction must live in the new run's timeline"
        );
        std::fs::remove_file(&state).ok();
    }

    #[test]
    fn hibernated_instances_survive_platform_restart() {
        let dir = std::env::temp_dir()
            .join(format!("qh-restart-test-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.cost = CostModel::paper();
        cfg.policy.hibernate_idle_ms = 10;
        cfg.policy.predictive_wakeup = false;
        cfg.swap_dir = dir.clone();

        // First process life: cold start, then hibernate (which persists
        // the image + manifest).
        let p = Platform::new(cfg.clone(), Arc::new(NoopRunner)).unwrap();
        p.deploy(scaled_for_test(golang_hello(), 16)).unwrap();
        let r1 = p.request_at("golang-hello", 0).unwrap();
        assert_eq!(r1.served_from, ServedFrom::ColdStart);
        let actions = p.policy_tick(r1.latency_ns + 50_000_000).unwrap();
        assert!(actions.iter().any(|a| a.verb == Verb::Hibernate));
        drop(p); // "crash"/shutdown: sandboxes drop, persisted files stay

        // Second process life: the deploy adopts the image and the first
        // request *wakes* it — no cold start at all.
        let p2 = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        p2.deploy(scaled_for_test(golang_hello(), 16)).unwrap();
        assert_eq!(p2.instance_count("golang-hello"), 1);
        assert_eq!(
            p2.metrics.durability.manifests_adopted.load(Ordering::Relaxed),
            1
        );
        let r2 = p2.request_at("golang-hello", 0).unwrap();
        assert_eq!(
            r2.served_from,
            ServedFrom::Hibernate,
            "adopted instance must serve as a hibernate wake"
        );
        assert_eq!(
            p2.metrics.counters.cold_starts.load(Ordering::Relaxed),
            0,
            "restart must not cold-start an adopted workload"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
