//! Request routing: pick the container that minimizes response latency.
//!
//! Selection order encodes the paper's latency hierarchy (Fig. 6):
//! `Warm ≈ WokenUp < Hibernate ≪ cold start` — so route to an idle Warm
//! container first, then a WokenUp one, then wake a Hibernate one, and only
//! cold-start when nothing reusable exists. Busy containers are skipped
//! (one in-flight request per instance): an instance reserved by an
//! in-flight request or a policy action is passed over *without touching
//! its sandbox mutex*, so routing never blocks behind slow work and the
//! shard critical section stays short.
//!
//! The off-tick pipeline's `wake_begin` flip makes an anticipatorily woken
//! instance rank WokenUp the moment the policy tick runs; while its REAP
//! prefetch is still in flight the riding reservation keeps it skipped
//! (a request scales out instead of waiting), and the instant the finish
//! completes the router hands it out at Warm-like rank.

use super::pool::FunctionPool;
use crate::container::state::ContainerState;

/// Routing outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Use instance `idx` of the pool (state at selection time included).
    Existing { idx: usize, state: ContainerState },
    /// Nothing reusable: cold-start a new instance.
    ColdStart,
}

/// Pick per the Warm > WokenUp > Hibernate > cold order. Among equals,
/// prefer the most-recently-active instance (better cache locality, and it
/// lets older instances age toward hibernation/eviction — LIFO keep-alive,
/// as in production FaaS schedulers).
pub fn route(pool: &FunctionPool) -> Route {
    let mut best: Option<(usize, ContainerState, u64)> = None;
    for (idx, inst) in pool.instances.iter().enumerate() {
        // Reserved = a request or policy action owns the sandbox right now.
        // Skip before reading `state()` — the state read locks the sandbox
        // mutex, which the owner may hold for the whole request.
        if inst.is_reserved() {
            continue;
        }
        let state = inst.state();
        if !state.accepts_requests() {
            continue;
        }
        let rank = match state {
            ContainerState::Warm => 0,
            ContainerState::WokenUp => 1,
            ContainerState::Hibernate => 2,
            _ => continue,
        };
        let better = match best {
            None => true,
            Some((_, bstate, blast)) => {
                let brank = match bstate {
                    ContainerState::Warm => 0,
                    ContainerState::WokenUp => 1,
                    _ => 2,
                };
                rank < brank || (rank == brank && inst.last_active_vns() > blast)
            }
        };
        if better {
            best = Some((idx, state, inst.last_active_vns()));
        }
    }
    match best {
        Some((idx, state, _)) => Route::Existing { idx, state },
        None => Route::ColdStart,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharingConfig;
    use crate::container::sandbox::{Sandbox, SandboxServices};
    use crate::container::NoopRunner;
    use crate::simtime::{Clock, CostModel};
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};
    use std::sync::Arc;

    fn rig() -> (Arc<SandboxServices>, FunctionPool) {
        let svc = SandboxServices::new_local(
            512 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            "router-test",
        )
        .unwrap();
        (svc, FunctionPool::new())
    }

    fn spawn(svc: &Arc<SandboxServices>, id: u64) -> Sandbox {
        Sandbox::cold_start(
            id,
            scaled_for_test(golang_hello(), 32),
            svc.clone(),
            &Clock::new(),
        )
        .unwrap()
    }

    #[test]
    fn empty_pool_cold_starts() {
        let (_svc, pool) = rig();
        assert_eq!(route(&pool), Route::ColdStart);
    }

    #[test]
    fn warm_beats_hibernate() {
        let (svc, mut pool) = rig();
        let clock = Clock::new();
        let mut a = spawn(&svc, 1);
        a.hibernate(&clock).unwrap(); // instance 0: Hibernate
        pool.add(a, 0);
        pool.add(spawn(&svc, 2), 1); // instance 1: Warm
        match route(&pool) {
            Route::Existing { idx, state } => {
                assert_eq!(idx, 1);
                assert_eq!(state, ContainerState::Warm);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wokenup_beats_hibernate_loses_to_warm() {
        let (svc, mut pool) = rig();
        let clock = Clock::new();
        let mut h = spawn(&svc, 1);
        h.hibernate(&clock).unwrap();
        let mut w = spawn(&svc, 2);
        w.hibernate(&clock).unwrap();
        w.wake(&clock).unwrap(); // WokenUp
        pool.add(h, 0);
        pool.add(w, 1);
        match route(&pool) {
            Route::Existing { idx, state } => {
                assert_eq!(idx, 1);
                assert_eq!(state, ContainerState::WokenUp);
            }
            other => panic!("{other:?}"),
        }
        pool.add(spawn(&svc, 3), 2); // Warm now exists
        match route(&pool) {
            Route::Existing { state, .. } => assert_eq!(state, ContainerState::Warm),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn reserved_instances_skipped() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 100);
        pool.add(spawn(&svc, 2), 900);
        // Reserve the better (most recent) instance: routing must fall back
        // to the other Warm one.
        let _r1 = pool.instances[1].try_reserve().unwrap();
        match route(&pool) {
            Route::Existing { idx, .. } => assert_eq!(idx, 0),
            other => panic!("{other:?}"),
        }
        // Both reserved → nothing reusable → cold start.
        let _r0 = pool.instances[0].try_reserve().unwrap();
        assert_eq!(route(&pool), Route::ColdStart);
    }

    #[test]
    fn wokenup_mid_inflation_skipped_until_reservation_drops() {
        // The wake_begin/wake_finish split: after the flip the instance
        // ranks WokenUp, but while the pipeline's prefetch is in flight
        // (reservation held) the router must pass it over — and hand it
        // out the moment the reservation releases.
        let (svc, mut pool) = rig();
        let clock = Clock::new();
        let mut s = spawn(&svc, 1);
        s.hibernate(&clock).unwrap();
        pool.add(s, 0);
        let guard = pool.instances[0].try_reserve().unwrap();
        pool.instances[0]
            .sandbox
            .lock()
            .unwrap()
            .wake_begin(&clock)
            .unwrap();
        assert_eq!(route(&pool), Route::ColdStart, "mid-inflation: skipped");
        drop(guard); // the pipeline worker finished and released
        match route(&pool) {
            Route::Existing { idx, state } => {
                assert_eq!(idx, 0);
                assert_eq!(state, ContainerState::WokenUp);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn most_recent_warm_preferred() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 100);
        pool.add(spawn(&svc, 2), 900);
        pool.add(spawn(&svc, 3), 500);
        match route(&pool) {
            Route::Existing { idx, .. } => assert_eq!(idx, 1, "LIFO keep-alive"),
            other => panic!("{other:?}"),
        }
    }
}
