//! Threaded serving front-end: real worker threads over the same platform
//! primitives the virtual-time replay uses. This is what the end-to-end
//! serve demo runs: a request bus (std mpsc — no async runtime in the
//! offline registry), N workers, and a background policy thread issuing
//! SIGSTOP/SIGCONT per the paper's control plane.
//!
//! Wall-clock time doubles as the virtual timeline (1 ns = 1 ns): idleness
//! for the hibernate policy is real idleness.

use super::{Platform, RequestReport};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A request submitted to the server.
pub struct Submission {
    pub workload: String,
    /// Filled with the report when done.
    pub reply: mpsc::Sender<Result<RequestReport>>,
}

/// Handle to a running server.
pub struct Server {
    tx: mpsc::Sender<Submission>,
    stop: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
    policy_thread: Option<JoinHandle<()>>,
    epoch: Instant,
}

impl Server {
    /// Start `workers` serving threads plus the policy loop.
    pub fn start(platform: Arc<Platform>, workers: usize, policy_interval: Duration) -> Server {
        let (tx, rx) = mpsc::channel::<Submission>();
        let rx = Arc::new(Mutex::new(rx));
        let stop = Arc::new(AtomicBool::new(false));
        let epoch = Instant::now();

        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let rx = rx.clone();
            let platform = platform.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || loop {
                let msg = {
                    let rx = rx.lock().unwrap();
                    rx.recv_timeout(Duration::from_millis(50))
                };
                match msg {
                    Ok(sub) => {
                        let now_vns = epoch_ns(epoch);
                        let report = platform.request_at(&sub.workload, now_vns);
                        let _ = sub.reply.send(report);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            }));
        }

        let policy_thread = {
            let platform = platform.clone();
            let stop = stop.clone();
            Some(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(policy_interval);
                    let _ = platform.policy_tick(epoch_ns(epoch));
                }
            }))
        };

        Server {
            tx,
            stop,
            workers: handles,
            policy_thread,
            epoch,
        }
    }

    /// Submit a request; returns a receiver for the report.
    pub fn submit(&self, workload: &str) -> mpsc::Receiver<Result<RequestReport>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Submission {
            workload: workload.to_string(),
            reply,
        });
        rx
    }

    /// Submit and wait.
    pub fn call(&self, workload: &str) -> Result<RequestReport> {
        self.submit(workload)
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped the request"))?
    }

    pub fn uptime_ns(&self) -> u64 {
        epoch_ns(self.epoch)
    }

    /// Stop workers and the policy loop; joins all threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.policy_thread.take() {
            let _ = h.join();
        }
    }
}

fn epoch_ns(epoch: Instant) -> u64 {
    epoch.elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::container::NoopRunner;
    use crate::platform::metrics::ServedFrom;
    use crate::simtime::CostModel;
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};

    fn platform() -> Arc<Platform> {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.cost = CostModel::free();
        cfg.policy.hibernate_idle_ms = 30;
        cfg.policy.predictive_wakeup = false;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-server-test-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        p.deploy(scaled_for_test(golang_hello(), 32)).unwrap();
        Arc::new(p)
    }

    #[test]
    fn serves_concurrent_requests() {
        let p = platform();
        let server = Server::start(p.clone(), 4, Duration::from_millis(10));
        let rxs: Vec<_> = (0..8).map(|_| server.submit("golang-hello")).collect();
        let mut served = 0;
        for rx in rxs {
            let report = rx.recv().unwrap().unwrap();
            assert_eq!(report.workload, "golang-hello");
            served += 1;
        }
        assert_eq!(served, 8);
        server.shutdown();
        assert_eq!(
            p.metrics.counters.requests.load(Ordering::Relaxed),
            8
        );
    }

    #[test]
    fn policy_thread_hibernates_idle_containers() {
        let p = platform();
        let server = Server::start(p.clone(), 2, Duration::from_millis(10));
        server.call("golang-hello").unwrap();
        // Wait past the 30 ms idle threshold for the policy thread to act.
        std::thread::sleep(Duration::from_millis(150));
        let r = server.call("golang-hello").unwrap();
        assert!(
            matches!(r.served_from, ServedFrom::Hibernate | ServedFrom::WokenUp),
            "expected a hibernate-path serve, got {:?}",
            r.served_from
        );
        server.shutdown();
        assert!(p.metrics.counters.hibernations.load(Ordering::Relaxed) >= 1);
    }
}
