//! Threaded serving front-end: real worker threads over the same platform
//! primitives the virtual-time replay uses. This is what the end-to-end
//! serve demo runs: per-worker request queues (std mpsc — no async runtime
//! in the offline registry), N workers, and a background policy thread
//! issuing SIGSTOP/SIGCONT per the paper's control plane.
//!
//! # Dispatch
//!
//! Each worker owns a private queue; there is no shared queue (and so no
//! single lock for every worker to contend on). Submissions are
//! dispatched with **function affinity**: a workload hashes to a preferred
//! worker, so requests for the same function land on the same worker —
//! FIFO per worker then gives per-function serve ordering, warm instances
//! stay warm under one worker's cache, and a single function cannot occupy
//! more than one worker unless the dispatcher spills. When the preferred
//! worker's queue runs more than `spill_threshold` deeper than the
//! least-loaded worker's, the request spills to the least-loaded worker
//! instead (sacrificing per-function ordering for throughput under skew).
//!
//! Spilling balances at submission time; **work stealing** balances after
//! it: a worker whose own queue runs dry pulls the oldest submission from
//! the deepest foreign queue above the same `spill_threshold`, so a burst
//! that landed on one queue before the imbalance was visible still
//! spreads across idle workers. With `spill_threshold = None` (strict
//! affinity) both mechanisms are off and per-function serve ordering is
//! unconditional. Steals are counted ([`Server::steal_count`]) so tests
//! can pin the branch down.
//!
//! Wall-clock time doubles as the virtual timeline (1 ns = 1 ns): idleness
//! for the hibernate policy is real idleness.

use super::health::TimedOut;
use super::{Platform, RequestReport};
use crate::obs::EventKind;
use crate::util::fnv1a;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A request submitted to the server.
pub struct Submission {
    pub workload: String,
    /// Filled with the report when done.
    pub reply: mpsc::Sender<Result<RequestReport>>,
    /// When the submission entered a queue — the age the per-request
    /// deadline (`resilience.request_deadline_ms`) is measured against. A
    /// submission a worker picks up past its deadline is shed with a typed
    /// [`TimedOut`] instead of served: under overload, serving requests the
    /// client has already given up on only deepens the backlog.
    pub enqueued: Instant,
}

/// Server tuning knobs.
pub struct ServerConfig {
    /// Worker threads (clamped to ≥ 1).
    pub workers: usize,
    /// Policy tick cadence.
    pub policy_interval: Duration,
    /// How much deeper than the least-loaded worker the affinity worker's
    /// queue may run before a submission spills off it. `None` = strict
    /// affinity (never spill — preserves per-function serve ordering
    /// unconditionally).
    pub spill_threshold: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            policy_interval: Duration::from_millis(20),
            spill_threshold: Some(2),
        }
    }
}

/// One worker's dispatch endpoint: its private queue plus a depth gauge
/// (queued + in-flight) that the dispatcher load-balances on and idle
/// workers scan for steal candidates.
struct WorkerQueue {
    queue: Mutex<VecDeque<Submission>>,
    /// Signalled when a submission lands on this queue.
    cv: Condvar,
    /// Queued + in-flight submissions charged to this worker. The charge
    /// transfers with the submission on a steal; whichever worker *runs*
    /// a submission decrements its own gauge afterwards.
    depth: AtomicUsize,
}

impl WorkerQueue {
    fn new() -> Self {
        Self {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            depth: AtomicUsize::new(0),
        }
    }
}

/// Handle to a running server.
pub struct Server {
    platform: Arc<Platform>,
    queues: Arc<Vec<WorkerQueue>>,
    spill_threshold: Option<usize>,
    /// Submissions served by a worker other than the one they were
    /// queued on.
    steals: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
    policy_thread: Option<JoinHandle<()>>,
    epoch: Instant,
}

impl Server {
    /// Start `workers` serving threads plus the policy loop, with default
    /// spill behavior.
    pub fn start(platform: Arc<Platform>, workers: usize, policy_interval: Duration) -> Server {
        Self::start_with(
            platform,
            ServerConfig {
                workers,
                policy_interval,
                ..ServerConfig::default()
            },
        )
    }

    /// Start with explicit tuning.
    pub fn start_with(platform: Arc<Platform>, cfg: ServerConfig) -> Server {
        let stop = Arc::new(AtomicBool::new(false));
        let epoch = Instant::now();
        let n = cfg.workers.max(1);

        let queues: Arc<Vec<WorkerQueue>> =
            Arc::new((0..n).map(|_| WorkerQueue::new()).collect());
        let steals = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(n);
        for me in 0..n {
            let queues = queues.clone();
            let steals = steals.clone();
            let platform = platform.clone();
            let stop = stop.clone();
            let threshold = cfg.spill_threshold;
            handles.push(std::thread::spawn(move || {
                worker_loop(me, &queues, &steals, &platform, &stop, threshold, epoch)
            }));
        }

        let policy_thread = {
            let platform = platform.clone();
            let stop = stop.clone();
            let interval = cfg.policy_interval;
            // Sleep in small steps so shutdown never waits out a long
            // policy interval.
            let step = Duration::from_millis(10).min(interval.max(Duration::from_millis(1)));
            Some(std::thread::spawn(move || {
                let mut since_tick = Duration::ZERO;
                loop {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    std::thread::sleep(step);
                    since_tick += step;
                    if since_tick >= interval {
                        since_tick = Duration::ZERO;
                        // nowait: deflation/inflation/teardown I/O runs on
                        // the platform's pipeline; this loop reaps
                        // completions next tick instead of stalling behind
                        // a large swap-out or a REAP prefetch. Errors (a
                        // failed job surfacing at reap, a failed action)
                        // must not vanish silently.
                        if let Err(e) = platform.policy_tick_nowait(epoch_ns(epoch)) {
                            eprintln!("policy tick error: {e:#}");
                        }
                    }
                }
            }))
        };

        Server {
            platform,
            queues,
            spill_threshold: cfg.spill_threshold,
            steals,
            stop,
            workers: handles,
            policy_thread,
            epoch,
        }
    }

    /// Pick the worker for `workload`: the affinity worker unless its queue
    /// runs past the spill threshold, in which case the least-loaded one.
    fn pick_worker(&self, workload: &str) -> usize {
        let n = self.queues.len();
        let preferred = (fnv1a(workload) % n as u64) as usize;
        let Some(threshold) = self.spill_threshold else {
            return preferred;
        };
        let preferred_depth = self.queues[preferred].depth.load(Ordering::Acquire);
        if preferred_depth <= threshold {
            // min_depth ≥ 0, so no spill is possible: skip the full scan.
            return preferred;
        }
        let (min_idx, min_depth) = self
            .queues
            .iter()
            .enumerate()
            .map(|(i, q)| (i, q.depth.load(Ordering::Acquire)))
            .min_by_key(|&(i, d)| (d, i))
            .expect("server has at least one worker");
        if preferred_depth > min_depth + threshold {
            min_idx
        } else {
            preferred
        }
    }

    /// Submissions served off a foreign queue by an idle worker (the
    /// work-stealing path). Monotonic over the server's lifetime.
    pub fn steal_count(&self) -> usize {
        self.steals.load(Ordering::Relaxed)
    }

    /// Submit a request; returns a receiver for the report. Errors if the
    /// server has shut down (or the target worker died) — the submission
    /// was *not* enqueued and will never be served.
    pub fn submit(&self, workload: &str) -> Result<mpsc::Receiver<Result<RequestReport>>> {
        if self.stop.load(Ordering::Relaxed) || self.workers.is_empty() {
            bail!("server is shut down; submission for `{workload}` rejected");
        }
        let (reply, rx) = mpsc::channel();
        let idx = self.pick_worker(workload);
        let q = &self.queues[idx];
        q.depth.fetch_add(1, Ordering::AcqRel);
        q.queue.lock().unwrap().push_back(Submission {
            workload: workload.to_string(),
            reply,
            enqueued: Instant::now(),
        });
        q.cv.notify_one();
        Ok(rx)
    }

    /// Submit and wait.
    pub fn call(&self, workload: &str) -> Result<RequestReport> {
        self.submit(workload)?
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped the request for `{workload}`"))?
    }

    pub fn uptime_ns(&self) -> u64 {
        epoch_ns(self.epoch)
    }

    /// Stop workers and the policy loop; joins all threads. Queued
    /// submissions are drained before the workers exit. After shutdown,
    /// [`Server::submit`] reports the shutdown instead of handing back a
    /// receiver that can only fail. If the platform is configured with a
    /// `predictor_state_file`, the learned arrival tracks are persisted
    /// here so anticipatory wake-up survives a restart.
    pub fn shutdown(&mut self) {
        if self.workers.is_empty() && self.policy_thread.is_none() {
            // Already shut down (Drop re-invokes this after an explicit
            // shutdown) — don't re-save predictor state, which would
            // resurrect a file the caller may have removed or rotated.
            return;
        }
        self.stop.store(true, Ordering::Relaxed);
        // Wake every parked worker so none waits out its poll timeout;
        // each then sweeps the queues dry (affinity ignored) and exits.
        for q in self.queues.iter() {
            q.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.policy_thread.take() {
            let _ = h.join();
        }
        // Settle any pipeline jobs (deflations, inflations, teardowns) the
        // last tick left in flight, so shutdown hands back a quiescent
        // platform (and surfaces their errors).
        if let Err(e) = self.platform.drain_pipeline() {
            eprintln!("pipeline error surfaced at shutdown: {e:#}");
        }
        if let Err(e) = self.platform.save_predictor_state() {
            eprintln!("predictor: failed to persist state on shutdown ({e:#})");
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if std::thread::panicking() {
            // Don't block the unwind on a backlog drain: signal stop and
            // wake the workers — they sweep their queues and exit
            // detached.
            self.stop.store(true, Ordering::Relaxed);
            for q in self.queues.iter() {
                q.cv.notify_all();
            }
            return;
        }
        self.shutdown();
    }
}

fn epoch_ns(epoch: Instant) -> u64 {
    epoch.elapsed().as_nanos() as u64
}

/// One serving thread: drain the own queue (the affinity fast path), then
/// — when idle and stealing is enabled — pull the oldest submission from
/// the deepest foreign queue past `steal_threshold`. On stop the worker
/// sweeps every queue dry regardless of affinity or threshold, so an
/// accepted submission is never abandoned even if its affinity worker
/// has already exited.
fn worker_loop(
    me: usize,
    queues: &[WorkerQueue],
    steals: &AtomicUsize,
    platform: &Platform,
    stop: &AtomicBool,
    steal_threshold: Option<usize>,
    epoch: Instant,
) {
    let serve = |sub: Submission| {
        let now_vns = epoch_ns(epoch);
        // Deadline-aware shedding: a submission that aged past the
        // configured deadline while queued is answered with a typed
        // `TimedOut` instead of being served — wall clock, because queue
        // wait is a real scheduling delay (this path is never part of the
        // replay fingerprint).
        let deadline_ms = platform.cfg.resilience.request_deadline_ms;
        let waited = sub.enqueued.elapsed();
        let report = if deadline_ms > 0 && waited > Duration::from_millis(deadline_ms) {
            platform
                .metrics
                .resilience
                .requests_timed_out
                .fetch_add(1, Ordering::Relaxed);
            if platform.metrics.recorder.is_enabled() {
                platform.metrics.recorder.emit_workload(
                    EventKind::Timeout,
                    0,
                    fnv1a(&sub.workload),
                    1,
                    now_vns,
                );
            }
            Err(anyhow::Error::new(TimedOut {
                workload: sub.workload.clone(),
                waited_ns: waited.as_nanos() as u64,
            }))
        } else {
            platform.request_at(&sub.workload, now_vns)
        };
        queues[me].depth.fetch_sub(1, Ordering::Release);
        let _ = sub.reply.send(report);
    };
    // Steal from the deepest foreign queue with depth > floor. Depth
    // counts the victim's in-flight submission too, so the deepest gauge
    // can belong to an already-empty queue — walk candidates deepest
    // first rather than betting on a single victim.
    let steal = |floor: usize| -> Option<Submission> {
        let mut order: Vec<(usize, usize)> = queues
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != me)
            .map(|(i, q)| (i, q.depth.load(Ordering::Acquire)))
            .filter(|&(_, d)| d > floor)
            .collect();
        order.sort_by_key(|&(i, d)| (std::cmp::Reverse(d), i));
        for (victim, _) in order {
            if let Some(sub) = queues[victim].queue.lock().unwrap().pop_front() {
                // The submission changes homes: the victim sheds the
                // charge, the thief picks it up as its own in-flight.
                queues[victim].depth.fetch_sub(1, Ordering::AcqRel);
                queues[me].depth.fetch_add(1, Ordering::AcqRel);
                steals.fetch_add(1, Ordering::Relaxed);
                return Some(sub);
            }
        }
        None
    };
    let next = |floor: Option<usize>| -> Option<Submission> {
        if let Some(sub) = queues[me].queue.lock().unwrap().pop_front() {
            return Some(sub);
        }
        steal(floor?)
    };
    loop {
        if let Some(sub) = next(steal_threshold) {
            serve(sub);
            continue;
        }
        if stop.load(Ordering::Relaxed) {
            while let Some(sub) = next(Some(0)) {
                serve(sub);
            }
            return;
        }
        let guard = queues[me].queue.lock().unwrap();
        if guard.is_empty() {
            let _ = queues[me]
                .cv
                .wait_timeout(guard, Duration::from_millis(50))
                .unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::container::NoopRunner;
    use crate::platform::metrics::ServedFrom;
    use crate::simtime::CostModel;
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};

    fn platform() -> Arc<Platform> {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.cost = CostModel::free();
        cfg.policy.hibernate_idle_ms = 30;
        cfg.policy.predictive_wakeup = false;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-server-test-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Platform::new(cfg, Arc::new(NoopRunner)).unwrap();
        p.deploy(scaled_for_test(golang_hello(), 32)).unwrap();
        Arc::new(p)
    }

    #[test]
    fn serves_concurrent_requests() {
        let p = platform();
        let mut server = Server::start(p.clone(), 4, Duration::from_millis(10));
        let rxs: Vec<_> = (0..8)
            .map(|_| server.submit("golang-hello").unwrap())
            .collect();
        let mut served = 0;
        for rx in rxs {
            let report = rx.recv().unwrap().unwrap();
            assert_eq!(report.workload, "golang-hello");
            served += 1;
        }
        assert_eq!(served, 8);
        server.shutdown();
        assert_eq!(p.metrics.counters.requests.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn policy_thread_hibernates_idle_containers() {
        let p = platform();
        let mut server = Server::start(p.clone(), 2, Duration::from_millis(10));
        server.call("golang-hello").unwrap();
        // Wait past the 30 ms idle threshold for the policy thread to act.
        std::thread::sleep(Duration::from_millis(150));
        let r = server.call("golang-hello").unwrap();
        assert!(
            matches!(r.served_from, ServedFrom::Hibernate | ServedFrom::WokenUp),
            "expected a hibernate-path serve, got {:?}",
            r.served_from
        );
        server.shutdown();
        assert!(p.metrics.counters.hibernations.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn submit_after_shutdown_reports_real_error() {
        let p = platform();
        let mut server = Server::start(p, 2, Duration::from_millis(10));
        server.call("golang-hello").unwrap();
        server.shutdown();
        let err = server.submit("golang-hello").unwrap_err();
        assert!(
            err.to_string().contains("shut down"),
            "error must name the shutdown, got: {err}"
        );
        let err = server.call("golang-hello").unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
    }

    #[test]
    fn shutdown_drains_backlog() {
        let p = platform();
        let mut server = Server::start(p.clone(), 1, Duration::from_millis(500));
        let rxs: Vec<_> = (0..16)
            .map(|_| server.submit("golang-hello").unwrap())
            .collect();
        server.shutdown();
        for rx in rxs {
            rx.recv().expect("queued submission must still be served").unwrap();
        }
        assert_eq!(p.metrics.counters.requests.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn shutdown_persists_predictor_state_when_configured() {
        let state = std::env::temp_dir()
            .join(format!("qh-server-predstate-{}.csv", std::process::id()))
            .to_string_lossy()
            .into_owned();
        std::fs::remove_file(&state).ok();
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.cost = CostModel::free();
        cfg.policy.predictive_wakeup = true;
        cfg.predictor_state_file = state.clone();
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-server-predstate-swap-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Arc::new(Platform::new(cfg, Arc::new(NoopRunner)).unwrap());
        p.deploy(scaled_for_test(golang_hello(), 32)).unwrap();
        let mut server = Server::start(p, 2, Duration::from_millis(10));
        server.call("golang-hello").unwrap();
        server.call("golang-hello").unwrap();
        server.shutdown();
        let saved = crate::platform::predictor_store::load(&state).unwrap();
        std::fs::remove_file(&state).ok();
        assert!(
            saved.iter().any(|(w, _, _, n)| w == "golang-hello" && *n >= 2),
            "shutdown must persist the learned track: {saved:?}"
        );
    }

    #[test]
    fn stale_queued_submissions_are_shed_with_a_typed_timeout() {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.cost = CostModel::free();
        cfg.policy.predictive_wakeup = false;
        cfg.resilience.request_deadline_ms = 50;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-server-deadline-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let p = Arc::new(Platform::new(cfg, Arc::new(NoopRunner)).unwrap());
        p.deploy(scaled_for_test(golang_hello(), 32)).unwrap();
        let mut server = Server::start(p.clone(), 1, Duration::from_secs(3600));
        // A fresh submission is comfortably inside the deadline.
        server.call("golang-hello").unwrap();
        // A submission that aged 200 ms in queue (hand-planted: real queue
        // waits that long are timing-dependent) is picked up past its 50 ms
        // deadline and shed.
        let (reply, rx) = mpsc::channel();
        let q = &server.queues[0];
        q.depth.fetch_add(1, Ordering::AcqRel);
        q.queue.lock().unwrap().push_back(Submission {
            workload: "golang-hello".into(),
            reply,
            enqueued: Instant::now() - Duration::from_millis(200),
        });
        q.cv.notify_one();
        let err = rx
            .recv()
            .expect("a shed submission still gets an answer")
            .unwrap_err();
        assert!(
            crate::platform::is_resilience_reject(&err),
            "the shed must be typed, got: {err}"
        );
        assert!(err.to_string().contains("timed out"), "{err}");
        assert_eq!(
            p.metrics
                .resilience
                .requests_timed_out
                .load(Ordering::Relaxed),
            1
        );
        server.shutdown();
        // Exactly the served request reached the platform.
        assert_eq!(p.metrics.counters.requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn affinity_is_deterministic() {
        let p = platform();
        let server = Server::start_with(
            p,
            ServerConfig {
                workers: 4,
                policy_interval: Duration::from_secs(3600),
                spill_threshold: None,
            },
        );
        let w0 = server.pick_worker("golang-hello");
        for _ in 0..10 {
            assert_eq!(server.pick_worker("golang-hello"), w0);
        }
        assert!(w0 < 4);
    }

    #[test]
    fn spill_moves_to_least_loaded_only_past_threshold() {
        let p = platform();
        let server = Server::start_with(
            p,
            ServerConfig {
                workers: 4,
                policy_interval: Duration::from_secs(3600),
                spill_threshold: Some(2),
            },
        );
        let preferred = server.pick_worker("golang-hello");
        // At exactly the threshold over the least-loaded worker (0), the
        // submission stays on its affinity worker...
        server.queues[preferred].depth.store(2, Ordering::Release);
        assert_eq!(server.pick_worker("golang-hello"), preferred);
        // ...one deeper, it spills to a least-loaded worker.
        server.queues[preferred].depth.store(3, Ordering::Release);
        let picked = server.pick_worker("golang-hello");
        assert_ne!(picked, preferred, "must spill off the overloaded worker");
        assert_eq!(server.queues[picked].depth.load(Ordering::Acquire), 0);
        server.queues[preferred].depth.store(0, Ordering::Release);
    }

    #[test]
    fn strict_affinity_disables_stealing_and_spilling() {
        let p = platform();
        let mut server = Server::start_with(
            p.clone(),
            ServerConfig {
                workers: 4,
                policy_interval: Duration::from_secs(3600),
                spill_threshold: None,
            },
        );
        let rxs: Vec<_> = (0..32)
            .map(|_| server.submit("golang-hello").unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(
            server.steal_count(),
            0,
            "strict affinity must never steal"
        );
        server.shutdown();
        assert_eq!(p.metrics.counters.requests.load(Ordering::Relaxed), 32);
    }
}
