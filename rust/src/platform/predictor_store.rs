//! Predictor-state persistence: save/load per-workload arrival tracks as a
//! versioned CSV sidecar, so anticipatory wake-up (Fig. 3 ⑤) survives
//! platform restarts instead of re-learning every workload's cadence from
//! scratch after a redeploy.
//!
//! Format (first line is a mandatory version tag; `#` comments allowed):
//!
//! ```csv
//! # qh-predictor-tracks v1
//! workload,last_arrival_ns,ewma_gap_ns,samples
//! golang-hello,123456789,250000000,17
//! ```
//!
//! Tracks are stored flat by workload — *not* by shard — because the
//! workload → shard mapping depends on the shard count, which may differ
//! across restarts. [`crate::platform::Platform`] re-routes each row to the
//! owning shard's predictor on load.

use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Mandatory first line; unknown versions are rejected, not guessed at.
pub const VERSION_LINE: &str = "# qh-predictor-tracks v1";

const HEADER: &str = "workload,last_arrival_ns,ewma_gap_ns,samples";

/// One persisted track: `(workload, last_arrival_ns, ewma_gap_ns, samples)`.
pub type TrackRow = (String, u64, f64, u64);

/// Save tracks to `path`. Written to a sibling temp file and renamed into
/// place, so a crash mid-save leaves the previous state intact instead of
/// a truncated file that the next startup would discard.
pub fn save(path: impl AsRef<Path>, rows: &[TrackRow]) -> Result<()> {
    let path = path.as_ref();
    for (w, ..) in rows {
        // A leading '#' would be silently dropped as a comment on load —
        // refuse it here so a save/load cycle can never lose a track.
        if w.contains(',') || w.contains('\n') || w.starts_with('#') {
            bail!("workload name {w:?} cannot be stored in CSV");
        }
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating predictor state {}", tmp.display()))?;
    writeln!(f, "{VERSION_LINE}")?;
    writeln!(f, "{HEADER}")?;
    for (w, last, ewma, n) in rows {
        writeln!(f, "{w},{last},{ewma},{n}")?;
    }
    f.sync_all().ok(); // best effort — the file is a cache, not a ledger
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("installing predictor state {}", path.display()))?;
    Ok(())
}

/// Parse predictor-state text. Strict: a wrong version or malformed row is
/// an error, never a silent partial restore.
pub fn parse(text: &str) -> Result<Vec<TrackRow>> {
    let mut lines = text.lines().map(str::trim).filter(|l| !l.is_empty());
    let version = lines.next().context("empty predictor state file")?;
    if version != VERSION_LINE {
        bail!("unsupported predictor state version {version:?} (expected {VERSION_LINE:?})");
    }
    let mut lines = lines.filter(|l| !l.starts_with('#'));
    let header = lines.next().context("missing header row")?;
    if header != HEADER {
        bail!("bad header {header:?} (expected {HEADER:?})");
    }
    let mut rows = Vec::new();
    for line in lines {
        let cols: Vec<&str> = line.split(',').collect();
        let [w, last, ewma, n] = cols.as_slice() else {
            bail!("bad row {line:?} (expected 4 comma-separated fields)");
        };
        if w.is_empty() {
            bail!("bad row {line:?}: empty workload");
        }
        let last: u64 = last
            .parse()
            .with_context(|| format!("bad last_arrival_ns in {line:?}"))?;
        let ewma: f64 = ewma
            .parse()
            .with_context(|| format!("bad ewma_gap_ns in {line:?}"))?;
        if !ewma.is_finite() || ewma < 0.0 {
            bail!("bad ewma_gap_ns {ewma} in {line:?}");
        }
        let n: u64 = n
            .parse()
            .with_context(|| format!("bad samples in {line:?}"))?;
        rows.push((w.to_string(), last, ewma, n));
    }
    Ok(rows)
}

/// Load tracks from a file.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<TrackRow>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading predictor state {}", path.as_ref().display()))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_through_file() {
        let rows: Vec<TrackRow> = vec![
            ("golang-hello".into(), 123_456_789, 250_000_000.25, 17),
            ("nodejs-hello".into(), 9, 0.5, 2),
        ];
        let path = std::env::temp_dir().join(format!(
            "qh-predictor-store-{}.csv",
            std::process::id()
        ));
        save(&path, &rows).unwrap();
        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // f64 Display round-trips exactly in Rust.
        assert_eq!(rows, back);
    }

    #[test]
    fn rejects_wrong_version_and_malformed_rows() {
        assert!(parse("").is_err());
        assert!(parse("# qh-predictor-tracks v2\nworkload,last_arrival_ns,ewma_gap_ns,samples\n").is_err());
        assert!(parse(&format!("{VERSION_LINE}\nwrong,header\n")).is_err());
        let good_head = format!("{VERSION_LINE}\nworkload,last_arrival_ns,ewma_gap_ns,samples\n");
        assert!(parse(&format!("{good_head}w,1,2.0\n")).is_err(), "3 fields");
        assert!(parse(&format!("{good_head},1,2.0,3\n")).is_err(), "empty workload");
        assert!(parse(&format!("{good_head}w,x,2.0,3\n")).is_err(), "bad int");
        assert!(parse(&format!("{good_head}w,1,NaN,3\n")).is_err(), "NaN ewma");
        assert!(parse(&format!("{good_head}w,1,2.0,3\n")).is_ok());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let text = format!(
            "{VERSION_LINE}\n\n# a comment\nworkload,last_arrival_ns,ewma_gap_ns,samples\n\nw,1,2,3\n"
        );
        let rows = parse(&text).unwrap();
        assert_eq!(rows, vec![("w".to_string(), 1, 2.0, 3)]);
    }

    #[test]
    fn refuses_unstorable_names() {
        let path = std::env::temp_dir().join(format!(
            "qh-predictor-store-bad-{}.csv",
            std::process::id()
        ));
        let rows: Vec<TrackRow> = vec![("a,b".into(), 1, 1.0, 1)];
        assert!(save(&path, &rows).is_err());
        let rows: Vec<TrackRow> = vec![("#canary".into(), 1, 1.0, 1)];
        assert!(
            save(&path, &rows).is_err(),
            "'#'-leading names would be dropped as comments on load"
        );
        std::fs::remove_file(&path).ok();
    }
}
