//! Platform metrics: latency summaries keyed by (workload, serving state),
//! lifecycle counters, and text/JSON export — what the Fig. 6/7 benches and
//! the serve demo report from.
//!
//! Latency summaries are **striped** by workload-name hash: every request
//! records into one of [`LATENCY_STRIPES`] independently-locked maps, so
//! the hot-path `record_latency` for function A never contends with
//! function B's (matching the sharded control plane — no global lock on
//! the request path). Readers merge the stripes; a workload's rows always
//! live in exactly one stripe, so the merge is collision-free.

use crate::container::state::ContainerState;
use crate::obs::Recorder;
use crate::util::fnv1a;
use crate::util::human_ns;
use crate::util::json::{obj, Json};
use crate::util::stats::{Histogram, Summary};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Lock stripes for the latency registry.
pub const LATENCY_STRIPES: usize = 16;

/// Which serving path a request took (Fig. 6's bar groups).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ServedFrom {
    ColdStart,
    Warm,
    Hibernate,
    WokenUp,
}

impl ServedFrom {
    pub fn label(self) -> &'static str {
        match self {
            ServedFrom::ColdStart => "cold",
            ServedFrom::Warm => "warm",
            ServedFrom::Hibernate => "hibernate",
            ServedFrom::WokenUp => "woken-up",
        }
    }

    pub fn from_state(s: ContainerState) -> Self {
        match s {
            ContainerState::Warm => ServedFrom::Warm,
            ContainerState::Hibernate => ServedFrom::Hibernate,
            ContainerState::WokenUp => ServedFrom::WokenUp,
            _ => ServedFrom::ColdStart,
        }
    }
}

/// Lifecycle counters.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub cold_starts: AtomicU64,
    pub hibernations: AtomicU64,
    pub reap_hibernations: AtomicU64,
    pub anticipatory_wakes: AtomicU64,
    pub demand_wakes: AtomicU64,
    pub evictions: AtomicU64,
    pub pages_reclaimed: AtomicU64,
    pub pages_swapped_out: AtomicU64,
    /// Pipeline jobs shed by the backpressure cap
    /// (`policy.pipeline_queue_cap`) where the *incoming* submission paid:
    /// deflations/teardowns that fell back to running inline on the tick,
    /// plus anticipatory wakes skipped.
    pub pipeline_sheds: AtomicU64,
    /// Sheds where the *largest queued deflation* paid instead: a bigger
    /// pending deflation (more deferred I/O per queue slot) was pulled
    /// off the queue and run inline so the smaller incoming job could
    /// queue.
    pub pipeline_sheds_largest: AtomicU64,
    /// Applied policy decisions by typed reason (see
    /// [`super::policy::Reason`]).
    pub decisions_idle_timeout: AtomicU64,
    pub decisions_host_pressure: AtomicU64,
    pub decisions_tenant_pressure: AtomicU64,
    pub decisions_stale_hibernate: AtomicU64,
    pub decisions_anticipated_arrival: AtomicU64,
    /// Gauge (not a monotonic counter): instance-pipeline jobs queued or
    /// in flight right now, mirrored by the pipeline on every submit and
    /// completion. Reads 0 whenever the pipeline is drained.
    pub pipeline_depth: AtomicU64,
}

macro_rules! counter_snapshot {
    ($self:ident, $($f:ident),+) => {
        vec![$((stringify!($f), $self.$f.load(Ordering::Relaxed))),+]
    };
}

impl Counters {
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        counter_snapshot!(
            self,
            requests,
            cold_starts,
            hibernations,
            reap_hibernations,
            anticipatory_wakes,
            demand_wakes,
            evictions,
            pages_reclaimed,
            pages_swapped_out,
            pipeline_sheds,
            pipeline_sheds_largest,
            pipeline_depth,
            decisions_idle_timeout,
            decisions_host_pressure,
            decisions_tenant_pressure,
            decisions_stale_hibernate,
            decisions_anticipated_arrival
        )
    }
}

/// I/O-backend observability: submission/coalescing/occupancy counters for
/// the [`crate::platform::io_backend`] layer.
///
/// **Deliberately not part of [`Counters::snapshot`]** (and therefore not
/// part of the replay fingerprint): how runs batch, chunk, and bypass each
/// other depends on wall-clock worker scheduling, so folding these into the
/// fingerprint would break both 1-vs-N bit-identity and sync-vs-batched
/// fingerprint equality. They are surfaced in [`Metrics::report`] /
/// [`Metrics::to_json`] as a separate section instead.
#[derive(Debug, Default)]
pub struct IoStats {
    /// `IoBackend::execute` calls (one per SlotFile batch read/write).
    pub submissions: AtomicU64,
    /// Coalesced contiguous runs executed (≥ 1 syscall each).
    pub runs_submitted: AtomicU64,
    /// Pages moved through the backend (4 KiB each).
    pub pages_submitted: AtomicU64,
    /// Gauge: bytes admitted (queued or executing) right now. Reads 0
    /// whenever the backend is idle.
    pub inflight_bytes: AtomicU64,
    /// High-water mark of `inflight_bytes` (validates `io.max_inflight_bytes`).
    pub inflight_bytes_peak: AtomicU64,
    /// Latency-class work dispatched ahead of queued throughput work — at
    /// the pipeline queue (an inflate popped over queued deflations) or at
    /// the backend queue (a wake read popped over queued deflation chunks).
    pub priority_bypasses: AtomicU64,
    /// Throughput submissions split at `io.batch_pages` boundaries — each
    /// split is a point where a queued wake may overtake.
    pub throughput_yields: AtomicU64,
}

impl IoStats {
    /// Raise `inflight_bytes` by `bytes`, tracking the peak.
    pub fn inflight_add(&self, bytes: u64) {
        let now = self.inflight_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.inflight_bytes_peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Lower `inflight_bytes` by `bytes`.
    pub fn inflight_sub(&self, bytes: u64) {
        self.inflight_bytes.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Name/value pairs for reporting (kept out of the replay fingerprint —
    /// see the type docs).
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        counter_snapshot!(
            self,
            submissions,
            runs_submitted,
            pages_submitted,
            inflight_bytes,
            inflight_bytes_peak,
            priority_bypasses,
            throughput_yields
        )
    }
}

/// Durability counters: checksum verification failures, transient-I/O
/// retries, degrade-ladder transitions, manifest lifecycle — see
/// `docs/durability.md` for the ladder these instrument.
///
/// **Deliberately not part of [`Counters::snapshot`]** (and therefore not
/// part of the replay fingerprint), same contract as [`IoStats`]: whether
/// an injected fault fires, how many retries a flaky device needs, and
/// what a restarted host adopts are all environment-dependent, so folding
/// these into the fingerprint would break 1-vs-N bit-identity. They are
/// surfaced in [`Metrics::report`] / [`Metrics::to_json`] as a separate
/// section instead.
#[derive(Debug, Default)]
pub struct DurabilityStats {
    /// Slot reads whose recorded checksum did not match — the page was
    /// never served (ladder rung 2 → 3).
    pub verify_failures: AtomicU64,
    /// Transient slot-file I/O failures retried with backoff.
    pub io_retries: AtomicU64,
    /// Working-set pages rescued through per-page swap-file reads after a
    /// REAP image was invalidated (ladder rung 1 → 2).
    pub reap_rescues: AtomicU64,
    /// Instances whose image was discarded and replaced by a cold start —
    /// the bottom of the ladder (rung 3).
    pub degraded_cold_starts: AtomicU64,
    /// Image manifests persisted at hibernate.
    pub manifests_written: AtomicU64,
    /// Manifests adopted at platform construction (restart wake path).
    pub manifests_adopted: AtomicU64,
    /// Manifests rejected at platform construction (torn / stale /
    /// checksum-failing — image discarded).
    pub manifests_rejected: AtomicU64,
}

impl DurabilityStats {
    /// Name/value pairs for reporting (kept out of the replay fingerprint —
    /// see the type docs).
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        counter_snapshot!(
            self,
            verify_failures,
            io_retries,
            reap_rescues,
            degraded_cold_starts,
            manifests_written,
            manifests_adopted,
            manifests_rejected
        )
    }
}

/// Resilience counters: chaos fault injections, self-healing actions
/// (watchdog cancels, deadline sheds, breaker transitions), and crash
/// recoveries — see `docs/resilience.md` for the machinery these
/// instrument.
///
/// **Deliberately not part of [`Counters::snapshot`]** (and therefore not
/// part of the replay fingerprint), same contract as [`DurabilityStats`]:
/// whether faults are injected is a property of the chaos plan, not the
/// workload, and the same trace replayed with and without chaos must
/// disagree only in outcomes the fingerprint already captures. They are
/// surfaced in [`Metrics::report`] / [`Metrics::to_json`] as a separate
/// section instead.
#[derive(Debug, Default)]
pub struct ResilienceStats {
    /// Chaos faults injected, total (sum of the per-family counters).
    pub faults_injected: AtomicU64,
    /// Sandbox crashes injected mid-request.
    pub injected_crashes: AtomicU64,
    /// Requests failed with a typed `Poisoned` error.
    pub injected_poison: AtomicU64,
    /// Requests charged extra virtual slow-I/O latency.
    pub injected_slow_io: AtomicU64,
    /// Inflation (wake) jobs hung until the watchdog cancelled them.
    pub injected_hangs: AtomicU64,
    /// Deflation/teardown jobs stalled until the watchdog cancelled them.
    pub injected_stalls: AtomicU64,
    /// Pipeline jobs panicked mid-job (chaos-injected).
    pub injected_panics: AtomicU64,
    /// Pipeline worker panics contained by the `catch_unwind` fence
    /// (chaos-injected and genuine alike) — the reservation released and
    /// `drain` stayed live every time.
    pub panics_fenced: AtomicU64,
    /// Pipeline jobs cancelled by the virtual-clock watchdog; each one
    /// retired its instance through the degrade ladder.
    pub watchdog_cancels: AtomicU64,
    /// Queued server submissions shed past their deadline with a typed
    /// `TimedOut` error.
    pub requests_timed_out: AtomicU64,
    /// Requests rejected with a typed `Quarantined` error while their
    /// function's breaker was open.
    pub requests_quarantined: AtomicU64,
    /// Circuit-breaker open transitions (function quarantined).
    pub breaker_opens: AtomicU64,
    /// Circuit-breaker close transitions (function healthy again after
    /// its half-open probes passed).
    pub breaker_closes: AtomicU64,
    /// Crashed instances recovered by re-adopting their still-valid
    /// hibernated image — no cold start paid.
    pub recovered_readopt: AtomicU64,
    /// Crashed instances replaced by a cold start (no adoptable image).
    pub recovered_cold: AtomicU64,
}

impl ResilienceStats {
    /// Count one injected fault in its family counter and the total.
    pub fn count_fault(&self, family: &AtomicU64) {
        self.faults_injected.fetch_add(1, Ordering::Relaxed);
        family.fetch_add(1, Ordering::Relaxed);
    }

    /// Instances recovered without operator input, however recovered —
    /// the CI chaos-smoke gate greps this.
    pub fn recovered_instances(&self) -> u64 {
        self.recovered_readopt.load(Ordering::Relaxed)
            + self.recovered_cold.load(Ordering::Relaxed)
    }

    /// Name/value pairs for reporting (kept out of the replay fingerprint —
    /// see the type docs).
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        counter_snapshot!(
            self,
            faults_injected,
            injected_crashes,
            injected_poison,
            injected_slow_io,
            injected_hangs,
            injected_stalls,
            injected_panics,
            panics_fenced,
            watchdog_cancels,
            requests_timed_out,
            requests_quarantined,
            breaker_opens,
            breaker_closes,
            recovered_readopt,
            recovered_cold
        )
    }
}

/// One (workload, serving-path) latency cell: the raw-sample [`Summary`]
/// that backs the text report's mean/max columns, plus the fixed-edge
/// [`Histogram`] that backs p50/p99/p999. Histogram merges are exact
/// (bucket-wise addition), so per-path and whole-run aggregates built from
/// cells are identical to having recorded every sample into one histogram —
/// unlike concatenating `Summary` sample vectors, which the replay report
/// used to do and which made merged quantiles depend on allocation-heavy
/// re-sorts of the full sample set.
#[derive(Debug, Clone, Default)]
pub struct LatencyCell {
    pub summary: Summary,
    pub hist: Histogram,
}

impl LatencyCell {
    fn add(&mut self, ns: u64) {
        self.summary.add(ns);
        self.hist.record(ns);
    }
}

/// Wake-phase latency histograms.
///
/// Fingerprint-excluded like [`IoStats`]: `queue_wait` measures wall-clock
/// pipeline scheduling (worker-count dependent), so none of these may enter
/// [`Counters::snapshot`] — they are surfaced in [`Metrics::report`] /
/// [`Metrics::to_json`] as their own section instead.
#[derive(Debug, Default)]
pub struct WakeHistograms {
    /// Wall-clock wait between an inflate job's enqueue and its start on a
    /// pipeline worker.
    pub queue_wait: Mutex<Histogram>,
    /// Charged inflate (REAP batch swap-in) virtual ns per woken instance.
    pub inflate: Mutex<Histogram>,
    /// Demand-wake admission overhead (virtual ns) charged on the request
    /// path while a signalled wake is still in flight.
    pub admission: Mutex<Histogram>,
}

/// JSON fields for one histogram: quantiles plus the non-empty bucket dump
/// as `[low_edge_ns, count]` pairs.
fn hist_json_fields(h: &Histogram) -> Vec<(&'static str, Json)> {
    vec![
        ("n", Json::Num(h.count() as f64)),
        ("mean_ns", Json::Num(h.mean())),
        ("p50_ns", Json::Num(h.p50() as f64)),
        ("p99_ns", Json::Num(h.p99() as f64)),
        ("p999_ns", Json::Num(h.p999() as f64)),
        ("max_ns", Json::Num(h.max() as f64)),
        (
            "buckets",
            Json::Arr(
                h.nonzero_buckets()
                    .map(|(low, c)| Json::Arr(vec![Json::Num(low as f64), Json::Num(c as f64)]))
                    .collect(),
            ),
        ),
    ]
}

/// The registry.
pub struct Metrics {
    stripes: Vec<Mutex<BTreeMap<(String, ServedFrom), LatencyCell>>>,
    pub counters: Counters,
    /// Shared with the platform's [`crate::platform::io_backend`] instance
    /// so backend activity lands in this registry's reports.
    pub io: Arc<IoStats>,
    /// Flight recorder shared with every emission seam (sandbox lifecycle,
    /// pipeline jobs, policy decisions, I/O backends). Like [`IoStats`],
    /// deliberately **not** part of [`Counters::snapshot`] — ring contents
    /// and drop counts are scheduling-dependent and must never reach the
    /// replay fingerprint.
    pub recorder: Arc<Recorder>,
    /// Wake-phase histograms (queue-wait / inflate / admission).
    pub wake: WakeHistograms,
    /// Durability counters, shared with every sandbox's swap manager and
    /// the platform's adoption scan. Fingerprint-excluded like [`IoStats`].
    pub durability: Arc<DurabilityStats>,
    /// Resilience counters, shared with the chaos plan, the pipeline
    /// watchdog/fence, the circuit breaker, and the server's deadline
    /// shedder. Fingerprint-excluded like [`DurabilityStats`].
    pub resilience: Arc<ResilienceStats>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Registry with a disabled (zero-overhead) flight recorder — tests and
    /// benches that don't trace use this.
    pub fn new() -> Self {
        Self::with_recorder(Recorder::disabled())
    }

    /// Registry sharing `recorder` with every component it is handed to.
    pub fn with_recorder(recorder: Arc<Recorder>) -> Self {
        Self {
            stripes: (0..LATENCY_STRIPES).map(|_| Mutex::new(BTreeMap::new())).collect(),
            counters: Counters::default(),
            io: Arc::new(IoStats::default()),
            recorder,
            wake: WakeHistograms::default(),
            durability: Arc::new(DurabilityStats::default()),
            resilience: Arc::new(ResilienceStats::default()),
        }
    }

    /// The stripe owning `workload`'s rows.
    fn stripe(&self, workload: &str) -> &Mutex<BTreeMap<(String, ServedFrom), LatencyCell>> {
        &self.stripes[(fnv1a(workload) % LATENCY_STRIPES as u64) as usize]
    }

    /// Count one applied policy decision under its typed reason.
    pub fn record_decision(&self, reason: super::policy::Reason) {
        use super::policy::Reason;
        let counter = match reason {
            Reason::IdleTimeout => &self.counters.decisions_idle_timeout,
            Reason::HostPressure => &self.counters.decisions_host_pressure,
            Reason::TenantPressure => &self.counters.decisions_tenant_pressure,
            Reason::StaleHibernate => &self.counters.decisions_stale_hibernate,
            Reason::AnticipatedArrival => &self.counters.decisions_anticipated_arrival,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request latency (virtual ns).
    pub fn record_latency(&self, workload: &str, from: ServedFrom, ns: u64) {
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        self.stripe(workload)
            .lock()
            .unwrap()
            .entry((workload.to_string(), from))
            .or_default()
            .add(ns);
    }

    /// Record wall-clock queue wait for an inflate pipeline job.
    pub fn record_queue_wait(&self, ns: u64) {
        self.wake.queue_wait.lock().unwrap().record(ns);
    }

    /// Record charged inflate time for a woken instance.
    pub fn record_inflate(&self, ns: u64) {
        self.wake.inflate.lock().unwrap().record(ns);
    }

    /// Record demand-wake admission overhead charged on a request.
    pub fn record_admission(&self, ns: u64) {
        self.wake.admission.lock().unwrap().record(ns);
    }

    /// Mean latency for a (workload, path) cell, if sampled.
    pub fn mean_latency(&self, workload: &str, from: ServedFrom) -> Option<f64> {
        self.stripe(workload)
            .lock()
            .unwrap()
            .get(&(workload.to_string(), from))
            .filter(|c| !c.summary.is_empty())
            .map(|c| c.summary.mean())
    }

    pub fn sample_count(&self, workload: &str, from: ServedFrom) -> usize {
        self.stripe(workload)
            .lock()
            .unwrap()
            .get(&(workload.to_string(), from))
            .map(|c| c.summary.len())
            .unwrap_or(0)
    }

    /// Per serving-path latency histograms: the exact bucket-wise merge of
    /// every workload's cell on that path.
    pub fn path_histograms(&self) -> BTreeMap<ServedFrom, Histogram> {
        let mut out: BTreeMap<ServedFrom, Histogram> = BTreeMap::new();
        for stripe in &self.stripes {
            for ((_, from), cell) in stripe.lock().unwrap().iter() {
                out.entry(*from).or_default().merge(&cell.hist);
            }
        }
        out
    }

    /// Render one row per (workload, path) cell across every stripe,
    /// sorted by key. Each key lives in exactly one stripe, so rows never
    /// collide; only the keys are cloned, never the sample vectors.
    fn render_rows<T>(
        &self,
        mut render: impl FnMut(&str, ServedFrom, &mut LatencyCell) -> T,
    ) -> Vec<T> {
        let mut rows: Vec<((String, ServedFrom), T)> = Vec::new();
        for stripe in &self.stripes {
            let mut map = stripe.lock().unwrap();
            for ((w, from), cell) in map.iter_mut() {
                rows.push(((w.clone(), *from), render(w, *from, cell)));
            }
        }
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows.into_iter().map(|(_, r)| r).collect()
    }

    /// Clone of every (workload, path) cell, sorted by key — the replay
    /// report builds its rows from this.
    pub fn latency_cells(&self) -> Vec<(String, ServedFrom, LatencyCell)> {
        self.render_rows(|w, from, cell| (w.to_string(), from, cell.clone()))
    }

    /// Text report: one row per (workload, path) — the Fig. 6 layout.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for row in self.render_rows(|w, from, cell| {
            format!(
                "{} p999={:>10}",
                cell.summary.report_ns(&format!("{w}/{}", from.label())),
                human_ns(cell.hist.p999())
            )
        }) {
            out.push_str(&row);
            out.push('\n');
        }
        out.push_str("counters:");
        for (k, v) in self.counters.snapshot() {
            out.push_str(&format!(" {k}={v}"));
        }
        out.push('\n');
        out.push_str("io:");
        for (k, v) in self.io.snapshot() {
            out.push_str(&format!(" {k}={v}"));
        }
        out.push('\n');
        out.push_str("durability:");
        for (k, v) in self.durability.snapshot() {
            out.push_str(&format!(" {k}={v}"));
        }
        out.push('\n');
        out.push_str("resilience:");
        for (k, v) in self.resilience.snapshot() {
            out.push_str(&format!(" {k}={v}"));
        }
        out.push('\n');
        for (name, hist) in [
            ("queue_wait", &self.wake.queue_wait),
            ("inflate", &self.wake.inflate),
            ("admission", &self.wake.admission),
        ] {
            let h = hist.lock().unwrap();
            out.push_str(&format!(
                "wake/{name}: n={} p50={} p99={} p999={} max={}\n",
                h.count(),
                human_ns(h.p50()),
                human_ns(h.p99()),
                human_ns(h.p999()),
                human_ns(h.max()),
            ));
        }
        out
    }

    /// JSON export (dashboards, EXPERIMENTS.md tooling). Quantiles are
    /// histogram-backed (fixed edges, exact merges); `mean_ns` stays
    /// sample-exact via the cell's `Summary`.
    pub fn to_json(&self) -> Json {
        let rows = self.render_rows(|w, from, cell| {
            obj(vec![
                ("workload", Json::Str(w.to_string())),
                ("path", Json::Str(from.label().to_string())),
                ("n", Json::Num(cell.summary.len() as f64)),
                ("mean_ns", Json::Num(cell.summary.mean())),
                ("p50_ns", Json::Num(cell.hist.p50() as f64)),
                ("p99_ns", Json::Num(cell.hist.p99() as f64)),
                ("p999_ns", Json::Num(cell.hist.p999() as f64)),
            ])
        });
        let paths: Vec<Json> = self
            .path_histograms()
            .iter()
            .map(|(from, h)| {
                let mut fields = vec![("path", Json::Str(from.label().to_string()))];
                fields.extend(hist_json_fields(h));
                obj(fields)
            })
            .collect();
        let wake = obj(vec![
            (
                "queue_wait",
                obj(hist_json_fields(&self.wake.queue_wait.lock().unwrap())),
            ),
            (
                "inflate",
                obj(hist_json_fields(&self.wake.inflate.lock().unwrap())),
            ),
            (
                "admission",
                obj(hist_json_fields(&self.wake.admission.lock().unwrap())),
            ),
        ]);
        let counters: Vec<(&str, Json)> = self
            .counters
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        let io: Vec<(&str, Json)> = self
            .io
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        let durability: Vec<(&str, Json)> = self
            .durability
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        let resilience: Vec<(&str, Json)> = self
            .resilience
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        obj(vec![
            ("latencies", Json::Arr(rows)),
            ("paths", Json::Arr(paths)),
            ("wake_phases", wake),
            ("counters", obj(counters)),
            ("io", obj(io)),
            ("durability", obj(durability)),
            ("resilience", obj(resilience)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let m = Metrics::new();
        m.record_latency("w", ServedFrom::Warm, 100);
        m.record_latency("w", ServedFrom::Warm, 200);
        m.record_latency("w", ServedFrom::ColdStart, 5000);
        assert_eq!(m.mean_latency("w", ServedFrom::Warm), Some(150.0));
        assert_eq!(m.sample_count("w", ServedFrom::ColdStart), 1);
        assert_eq!(m.mean_latency("w", ServedFrom::Hibernate), None);
        assert_eq!(m.counters.requests.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn report_and_json_render() {
        let m = Metrics::new();
        m.record_latency("video", ServedFrom::Hibernate, 1_000_000);
        m.counters.hibernations.fetch_add(1, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("video/hibernate"));
        assert!(r.contains("hibernations=1"));
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(
            back.get("latencies").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn decision_reasons_count_separately() {
        use crate::platform::policy::Reason;
        let m = Metrics::new();
        m.record_decision(Reason::IdleTimeout);
        m.record_decision(Reason::IdleTimeout);
        m.record_decision(Reason::TenantPressure);
        let snap = m.counters.snapshot();
        let get = |k: &str| snap.iter().find(|(n, _)| *n == k).map(|(_, v)| *v).unwrap();
        assert_eq!(get("decisions_idle_timeout"), 2);
        assert_eq!(get("decisions_tenant_pressure"), 1);
        assert_eq!(get("decisions_host_pressure"), 0);
        let r = m.report();
        assert!(r.contains("decisions_idle_timeout=2"));
    }

    #[test]
    fn stripes_merge_completely() {
        let m = Metrics::new();
        // More workloads than stripes → every stripe exercised, and the
        // merged report must still contain one row per workload.
        for i in 0..64 {
            m.record_latency(&format!("fn-{i}"), ServedFrom::Warm, 1000 + i);
        }
        for i in 0..64 {
            let w = format!("fn-{i}");
            assert_eq!(m.sample_count(&w, ServedFrom::Warm), 1, "{w}");
            assert_eq!(m.mean_latency(&w, ServedFrom::Warm), Some((1000 + i) as f64));
        }
        let r = m.report();
        for i in 0..64 {
            assert!(r.contains(&format!("fn-{i}/warm")), "missing fn-{i}");
        }
        assert_eq!(m.counters.requests.load(Ordering::Relaxed), 64);
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(back.get("latencies").unwrap().as_arr().unwrap().len(), 64);
    }

    #[test]
    fn io_stats_render_but_stay_out_of_the_fingerprint_snapshot() {
        let m = Metrics::new();
        m.io.submissions.fetch_add(3, Ordering::Relaxed);
        m.io.inflight_add(8192);
        m.io.inflight_add(4096);
        m.io.inflight_sub(12288);
        m.io.priority_bypasses.fetch_add(1, Ordering::Relaxed);
        // Rendered in both exports…
        let r = m.report();
        assert!(r.contains("io: submissions=3"), "{r}");
        assert!(r.contains("inflight_bytes_peak=12288"), "{r}");
        assert!(r.contains("priority_bypasses=1"), "{r}");
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert!(back.get("io").is_some());
        // …but NEVER in the counter snapshot the replay fingerprint folds:
        // backend scheduling is wall-clock dependent, so leaking any io_*
        // key here would break 1-vs-N bit-identity.
        for (k, _) in m.counters.snapshot() {
            assert!(
                !k.starts_with("io")
                    && k != "submissions"
                    && k != "runs_submitted"
                    && k != "priority_bypasses",
                "io stat `{k}` leaked into the fingerprint snapshot"
            );
        }
        assert_eq!(m.io.inflight_bytes.load(Ordering::Relaxed), 0, "gauge settles");
    }

    #[test]
    fn durability_stats_render_but_stay_out_of_the_fingerprint_snapshot() {
        let m = Metrics::new();
        let before = m.counters.snapshot();
        m.durability.verify_failures.fetch_add(2, Ordering::Relaxed);
        m.durability.io_retries.fetch_add(3, Ordering::Relaxed);
        m.durability.reap_rescues.fetch_add(1, Ordering::Relaxed);
        m.durability.manifests_adopted.fetch_add(1, Ordering::Relaxed);
        // Rendered in both exports…
        let r = m.report();
        assert!(r.contains("durability: verify_failures=2"), "{r}");
        assert!(r.contains("io_retries=3"), "{r}");
        assert!(r.contains("manifests_adopted=1"), "{r}");
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(
            back.get("durability")
                .unwrap()
                .get("reap_rescues")
                .unwrap()
                .as_u64(),
            Some(1)
        );
        // …but NEVER in the counter snapshot the replay fingerprint folds:
        // fault occurrence, retry counts, and restart adoption are
        // environment-dependent, so leaking any durability_* key here
        // would break 1-vs-N bit-identity (same contract as IoStats).
        assert_eq!(m.counters.snapshot(), before);
        for (k, _) in m.counters.snapshot() {
            assert!(
                !k.starts_with("durability")
                    && k != "verify_failures"
                    && k != "io_retries"
                    && k != "reap_rescues"
                    && k != "manifests_written",
                "durability stat `{k}` leaked into the fingerprint snapshot"
            );
        }
    }

    #[test]
    fn resilience_stats_render_but_stay_out_of_the_fingerprint_snapshot() {
        let m = Metrics::new();
        let before = m.counters.snapshot();
        m.resilience.count_fault(&m.resilience.injected_crashes);
        m.resilience.count_fault(&m.resilience.injected_panics);
        m.resilience.panics_fenced.fetch_add(1, Ordering::Relaxed);
        m.resilience.watchdog_cancels.fetch_add(2, Ordering::Relaxed);
        m.resilience.requests_quarantined.fetch_add(4, Ordering::Relaxed);
        m.resilience.breaker_opens.fetch_add(1, Ordering::Relaxed);
        m.resilience.recovered_readopt.fetch_add(1, Ordering::Relaxed);
        m.resilience.recovered_cold.fetch_add(2, Ordering::Relaxed);
        assert_eq!(m.resilience.recovered_instances(), 3);
        // Rendered in both exports…
        let r = m.report();
        assert!(r.contains("resilience: faults_injected=2"), "{r}");
        assert!(r.contains("injected_crashes=1"), "{r}");
        assert!(r.contains("watchdog_cancels=2"), "{r}");
        assert!(r.contains("recovered_readopt=1"), "{r}");
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(
            back.get("resilience")
                .unwrap()
                .get("requests_quarantined")
                .unwrap()
                .as_u64(),
            Some(4)
        );
        // …but NEVER in the counter snapshot the replay fingerprint folds:
        // the same trace replayed with and without a chaos plan must
        // disagree only where the fingerprint already looks, so leaking
        // any resilience key here would break the chaos-vs-clean and
        // 1-vs-N determinism contracts (same contract as DurabilityStats).
        assert_eq!(m.counters.snapshot(), before);
        for (k, _) in m.counters.snapshot() {
            assert!(
                !k.starts_with("injected")
                    && !k.starts_with("breaker")
                    && !k.starts_with("recovered")
                    && k != "faults_injected"
                    && k != "panics_fenced"
                    && k != "watchdog_cancels"
                    && k != "requests_timed_out"
                    && k != "requests_quarantined",
                "resilience stat `{k}` leaked into the fingerprint snapshot"
            );
        }
    }

    #[test]
    fn recorder_and_histograms_stay_out_of_the_fingerprint_snapshot() {
        use crate::obs::EventKind;
        let m = Metrics::with_recorder(Recorder::new(2, 16, true));
        m.record_latency("w", ServedFrom::WokenUp, 500);
        let before = m.counters.snapshot();
        // Flight-recorder events and wake-phase histogram records…
        m.recorder.emit_workload(EventKind::WakeBegin, 1, 7, 0, 100);
        m.recorder.emit_workload(EventKind::WakeFinish, 1, 7, 4096, 200);
        m.record_queue_wait(1_000);
        m.record_inflate(2_000);
        m.record_admission(3_000);
        // …render in both exports…
        let r = m.report();
        assert!(r.contains("wake/inflate: n=1"), "{r}");
        assert!(r.contains("p999="), "{r}");
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert!(back.get("wake_phases").is_some());
        assert!(back.get("paths").is_some());
        let row = &back.get("latencies").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("p999_ns").unwrap().as_u64(), Some(500));
        // …but leave the counter snapshot the replay fingerprint folds
        // bit-identical: ring contents, drop counts, and histogram buckets
        // are scheduling-dependent and must never become counters.
        assert_eq!(m.counters.snapshot(), before);
        for (k, _) in m.counters.snapshot() {
            assert!(
                !k.contains("obs") && !k.contains("ring") && !k.contains("wake_phase"),
                "obs state `{k}` leaked into the fingerprint snapshot"
            );
        }
        assert_eq!(m.recorder.len(), 2, "events did land in the ring");
    }

    #[test]
    fn served_from_mapping() {
        assert_eq!(
            ServedFrom::from_state(ContainerState::Warm),
            ServedFrom::Warm
        );
        assert_eq!(
            ServedFrom::from_state(ContainerState::Hibernate),
            ServedFrom::Hibernate
        );
        assert_eq!(
            ServedFrom::from_state(ContainerState::WokenUp),
            ServedFrom::WokenUp
        );
    }
}
