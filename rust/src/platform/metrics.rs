//! Platform metrics: latency summaries keyed by (workload, serving state),
//! lifecycle counters, and text/JSON export — what the Fig. 6/7 benches and
//! the serve demo report from.

use crate::container::state::ContainerState;
use crate::util::json::{obj, Json};
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Which serving path a request took (Fig. 6's bar groups).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ServedFrom {
    ColdStart,
    Warm,
    Hibernate,
    WokenUp,
}

impl ServedFrom {
    pub fn label(self) -> &'static str {
        match self {
            ServedFrom::ColdStart => "cold",
            ServedFrom::Warm => "warm",
            ServedFrom::Hibernate => "hibernate",
            ServedFrom::WokenUp => "woken-up",
        }
    }

    pub fn from_state(s: ContainerState) -> Self {
        match s {
            ContainerState::Warm => ServedFrom::Warm,
            ContainerState::Hibernate => ServedFrom::Hibernate,
            ContainerState::WokenUp => ServedFrom::WokenUp,
            _ => ServedFrom::ColdStart,
        }
    }
}

/// Lifecycle counters.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub cold_starts: AtomicU64,
    pub hibernations: AtomicU64,
    pub reap_hibernations: AtomicU64,
    pub anticipatory_wakes: AtomicU64,
    pub demand_wakes: AtomicU64,
    pub evictions: AtomicU64,
    pub pages_reclaimed: AtomicU64,
    pub pages_swapped_out: AtomicU64,
}

macro_rules! counter_snapshot {
    ($self:ident, $($f:ident),+) => {
        vec![$((stringify!($f), $self.$f.load(Ordering::Relaxed))),+]
    };
}

impl Counters {
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        counter_snapshot!(
            self,
            requests,
            cold_starts,
            hibernations,
            reap_hibernations,
            anticipatory_wakes,
            demand_wakes,
            evictions,
            pages_reclaimed,
            pages_swapped_out
        )
    }
}

/// The registry.
#[derive(Default)]
pub struct Metrics {
    latencies: Mutex<BTreeMap<(String, ServedFrom), Summary>>,
    pub counters: Counters,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request latency (virtual ns).
    pub fn record_latency(&self, workload: &str, from: ServedFrom, ns: u64) {
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        self.latencies
            .lock()
            .unwrap()
            .entry((workload.to_string(), from))
            .or_default()
            .add(ns);
    }

    /// Mean latency for a (workload, path) cell, if sampled.
    pub fn mean_latency(&self, workload: &str, from: ServedFrom) -> Option<f64> {
        self.latencies
            .lock()
            .unwrap()
            .get(&(workload.to_string(), from))
            .filter(|s| !s.is_empty())
            .map(|s| s.mean())
    }

    pub fn sample_count(&self, workload: &str, from: ServedFrom) -> usize {
        self.latencies
            .lock()
            .unwrap()
            .get(&(workload.to_string(), from))
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Text report: one row per (workload, path) — the Fig. 6 layout.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let mut map = self.latencies.lock().unwrap();
        for ((w, from), summary) in map.iter_mut() {
            out.push_str(&summary.report_ns(&format!("{w}/{}", from.label())));
            out.push('\n');
        }
        out.push_str("counters:");
        for (k, v) in self.counters.snapshot() {
            out.push_str(&format!(" {k}={v}"));
        }
        out.push('\n');
        out
    }

    /// JSON export (dashboards, EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        let mut map = self.latencies.lock().unwrap();
        let rows: Vec<Json> = map
            .iter_mut()
            .map(|((w, from), s)| {
                obj(vec![
                    ("workload", Json::Str(w.clone())),
                    ("path", Json::Str(from.label().to_string())),
                    ("n", Json::Num(s.len() as f64)),
                    ("mean_ns", Json::Num(s.mean())),
                    ("p50_ns", Json::Num(s.p50() as f64)),
                    ("p99_ns", Json::Num(s.p99() as f64)),
                ])
            })
            .collect();
        let counters: Vec<(&str, Json)> = self
            .counters
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        obj(vec![
            ("latencies", Json::Arr(rows)),
            ("counters", obj(counters)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let m = Metrics::new();
        m.record_latency("w", ServedFrom::Warm, 100);
        m.record_latency("w", ServedFrom::Warm, 200);
        m.record_latency("w", ServedFrom::ColdStart, 5000);
        assert_eq!(m.mean_latency("w", ServedFrom::Warm), Some(150.0));
        assert_eq!(m.sample_count("w", ServedFrom::ColdStart), 1);
        assert_eq!(m.mean_latency("w", ServedFrom::Hibernate), None);
        assert_eq!(m.counters.requests.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn report_and_json_render() {
        let m = Metrics::new();
        m.record_latency("video", ServedFrom::Hibernate, 1_000_000);
        m.counters.hibernations.fetch_add(1, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("video/hibernate"));
        assert!(r.contains("hibernations=1"));
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(
            back.get("latencies").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn served_from_mapping() {
        assert_eq!(
            ServedFrom::from_state(ContainerState::Warm),
            ServedFrom::Warm
        );
        assert_eq!(
            ServedFrom::from_state(ContainerState::Hibernate),
            ServedFrom::Hibernate
        );
        assert_eq!(
            ServedFrom::from_state(ContainerState::WokenUp),
            ServedFrom::WokenUp
        );
    }
}
