//! Workload traces: Azure-FaaS-shaped arrival generation and replay input.
//!
//! The paper motivates Hibernate with the serverless workload studies it
//! cites (Shahrad et al.: most functions are invoked rarely; Datadog: small
//! memory). The generator produces per-function arrival processes with
//! Poisson or bursty (lognormal think-time) inter-arrivals so the policy
//! experiments see realistic idle gaps — the gaps Hibernate monetizes.

use crate::util::rng::Rng;

/// One request arrival in virtual time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Virtual arrival time (ns since trace start).
    pub at_ns: u64,
    /// Target workload name.
    pub workload: String,
}

/// Arrival process for one function.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Poisson with the given mean inter-arrival (ns).
    Poisson { mean_gap_ns: u64 },
    /// Bursts: lognormal gaps between bursts, `burst` back-to-back requests.
    Bursty {
        median_gap_ns: u64,
        sigma: f64,
        burst: u32,
    },
    /// Fixed-rate (deterministic gap).
    Uniform { gap_ns: u64 },
}

/// Generator configuration for one workload.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub workload: String,
    pub arrival: Arrival,
}

/// Spacing between back-to-back requests inside one burst.
pub const BURST_SPACING_NS: u64 = 1_000_000;

/// Generate a merged, time-sorted trace of `duration_ns` for all specs.
pub fn generate(specs: &[TraceSpec], duration_ns: u64, seed: u64) -> Vec<TraceEvent> {
    let mut events = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let mut rng = Rng::new(seed ^ ((i as u64 + 1) * 0x9E37_79B9));
        let mut t = 0u64;
        loop {
            let gap = match &spec.arrival {
                Arrival::Poisson { mean_gap_ns } => rng.exp(*mean_gap_ns as f64) as u64,
                Arrival::Uniform { gap_ns } => *gap_ns,
                Arrival::Bursty {
                    median_gap_ns,
                    sigma,
                    ..
                } => rng.lognormal(*median_gap_ns as f64, *sigma) as u64,
            };
            t = t.saturating_add(gap.max(1));
            if t >= duration_ns {
                break;
            }
            events.push(TraceEvent {
                at_ns: t,
                workload: spec.workload.clone(),
            });
            if let Arrival::Bursty { burst, .. } = &spec.arrival {
                // Burst members trail their head arrival at a fixed spacing
                // (anchored at the head, never before it), and the next
                // inter-burst gap is measured from the end of the burst.
                for b in 1..*burst {
                    let bt = t.saturating_add(b as u64 * BURST_SPACING_NS);
                    if bt < duration_ns {
                        events.push(TraceEvent {
                            at_ns: bt,
                            workload: spec.workload.clone(),
                        });
                    }
                }
                t = t.saturating_add(burst.saturating_sub(1) as u64 * BURST_SPACING_NS);
            }
        }
    }
    events.sort_by_key(|e| e.at_ns);
    events
}

/// A convenience mix: every paper workload with an idle-heavy Poisson
/// process (mean gap ≫ processing time, so hibernation opportunities exist).
pub fn paper_mix(duration_ns: u64, mean_gap_ms: u64, seed: u64) -> Vec<TraceEvent> {
    let specs: Vec<TraceSpec> = crate::workloads::all_workloads()
        .into_iter()
        .map(|w| TraceSpec {
            workload: w.name,
            arrival: Arrival::Poisson {
                mean_gap_ns: mean_gap_ms * 1_000_000,
            },
        })
        .collect();
    generate(&specs, duration_ns, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_and_bounded() {
        let specs = vec![
            TraceSpec {
                workload: "a".into(),
                arrival: Arrival::Poisson {
                    mean_gap_ns: 10_000_000,
                },
            },
            TraceSpec {
                workload: "b".into(),
                arrival: Arrival::Uniform { gap_ns: 25_000_000 },
            },
        ];
        let t = generate(&specs, 1_000_000_000, 42);
        assert!(!t.is_empty());
        assert!(t.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        assert!(t.iter().all(|e| e.at_ns < 1_000_000_000));
        // Uniform at 25 ms over 1 s → ~39 events of "b".
        let b = t.iter().filter(|e| e.workload == "b").count();
        assert_eq!(b, 39);
    }

    #[test]
    fn deterministic_per_seed() {
        let specs = vec![TraceSpec {
            workload: "a".into(),
            arrival: Arrival::Poisson {
                mean_gap_ns: 5_000_000,
            },
        }];
        let t1 = generate(&specs, 500_000_000, 7);
        let t2 = generate(&specs, 500_000_000, 7);
        let t3 = generate(&specs, 500_000_000, 8);
        assert_eq!(t1, t2);
        assert_ne!(t1, t3);
    }

    #[test]
    fn poisson_rate_roughly_right() {
        let specs = vec![TraceSpec {
            workload: "a".into(),
            arrival: Arrival::Poisson {
                mean_gap_ns: 1_000_000,
            },
        }];
        let t = generate(&specs, 1_000_000_000, 3);
        // expect ~1000 events ± 20%
        assert!((800..1200).contains(&t.len()), "{}", t.len());
    }

    #[test]
    fn bursts_cluster() {
        let burst = 4usize;
        let specs = vec![TraceSpec {
            workload: "a".into(),
            arrival: Arrival::Bursty {
                median_gap_ns: 100_000_000,
                sigma: 0.5,
                burst: burst as u32,
            },
        }];
        let t = generate(&specs, 2_000_000_000, 11);
        assert!(t.len() >= 8, "bursts must multiply events: {}", t.len());
        // Intra-burst structure: the trace decomposes into groups of
        // exactly `burst` events spaced exactly BURST_SPACING_NS apart
        // (the last group may be truncated by the trace end), each group
        // anchored at its head — so no member ever precedes its head —
        // and consecutive groups separated by more than the spacing.
        let times: Vec<u64> = t.iter().map(|e| e.at_ns).collect();
        let mut i = 0;
        while i < times.len() {
            let mut len = 1;
            while i + len < times.len()
                && times[i + len] - times[i + len - 1] == BURST_SPACING_NS
            {
                len += 1;
            }
            for k in 1..len {
                assert_eq!(
                    times[i + k],
                    times[i] + k as u64 * BURST_SPACING_NS,
                    "member {k} must trail its head by exactly {k}×spacing"
                );
            }
            assert!(
                len == burst || i + len == times.len(),
                "only the trailing burst may be truncated: group of {len} at index {i}"
            );
            if i + len < times.len() {
                assert!(
                    times[i + len] - times[i + len - 1] > BURST_SPACING_NS,
                    "inter-burst gap must exceed the intra-burst spacing"
                );
            }
            i += len;
        }
    }

    #[test]
    fn paper_mix_covers_all_workloads() {
        let t = paper_mix(3_000_000_000, 200, 1);
        let names: std::collections::HashSet<_> =
            t.iter().map(|e| e.workload.clone()).collect();
        assert_eq!(names.len(), 8);
    }
}
