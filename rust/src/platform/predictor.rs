//! Anticipatory wake-up prediction (Fig. 3 ⑤).
//!
//! "When Serverless Platform predicts there is an incoming user request, it
//! may also wake up a Hibernate Container … in anticipation by sending a
//! SIGCONT." We keep a per-workload EWMA of inter-arrival gaps; the policy
//! loop asks the predictor whether a request is expected within the wake
//! lead time, and if so issues the SIGCONT so the request lands on a
//! WokenUp container (Warm-like latency) instead of a Hibernate one.

use std::collections::HashMap;
use std::sync::Mutex;

/// Per-workload arrival statistics.
#[derive(Debug, Clone, Copy)]
struct Track {
    last_arrival_ns: u64,
    ewma_gap_ns: f64,
    samples: u64,
    /// Restored from persistence: `last_arrival_ns` is a rebased anchor,
    /// not a real arrival, so the first observed "gap" (startup → first
    /// request) is meaningless and must not be folded into the EWMA.
    restored: bool,
}

/// EWMA-based next-arrival predictor.
pub struct Predictor {
    alpha: f64,
    tracks: Mutex<HashMap<String, Track>>,
}

impl Predictor {
    pub fn new(alpha: f64) -> Self {
        Self {
            alpha,
            tracks: Mutex::new(HashMap::new()),
        }
    }

    /// Observe an arrival for `workload` at virtual time `now_ns`.
    pub fn observe(&self, workload: &str, now_ns: u64) {
        let mut tracks = self.tracks.lock().unwrap();
        match tracks.get_mut(workload) {
            None => {
                tracks.insert(
                    workload.to_string(),
                    Track {
                        last_arrival_ns: now_ns,
                        ewma_gap_ns: 0.0,
                        samples: 1,
                        restored: false,
                    },
                );
            }
            Some(t) if t.restored => {
                // First arrival after a restore: the interval since the
                // rebased anchor is startup delay, not cadence — re-anchor
                // without touching the learned EWMA or the sample count
                // (so a 1-sample track still seeds its EWMA from the next
                // real gap instead of blending against 0).
                t.last_arrival_ns = now_ns;
                t.restored = false;
            }
            Some(t) => {
                let gap = now_ns.saturating_sub(t.last_arrival_ns) as f64;
                t.ewma_gap_ns = if t.samples == 1 {
                    gap
                } else {
                    self.alpha * gap + (1.0 - self.alpha) * t.ewma_gap_ns
                };
                t.last_arrival_ns = now_ns;
                t.samples += 1;
            }
        }
    }

    /// Predicted next arrival time, if we have ≥ 2 samples.
    pub fn predicted_next(&self, workload: &str) -> Option<u64> {
        let tracks = self.tracks.lock().unwrap();
        let t = tracks.get(workload)?;
        if t.samples < 2 {
            return None;
        }
        Some(t.last_arrival_ns + t.ewma_gap_ns as u64)
    }

    /// Should the platform wake a hibernated container for `workload` now?
    /// True when the predicted arrival falls within `lead_ns` of `now_ns`
    /// (and has not already passed by more than one gap — stale tracks
    /// shouldn't cause wake storms).
    pub fn should_wake(&self, workload: &str, now_ns: u64, lead_ns: u64) -> bool {
        let Some(next) = self.predicted_next(workload) else {
            return false;
        };
        let gap = {
            let tracks = self.tracks.lock().unwrap();
            tracks.get(workload).map(|t| t.ewma_gap_ns as u64).unwrap_or(0)
        };
        next.saturating_sub(now_ns) <= lead_ns && now_ns.saturating_sub(next) < gap.max(1)
    }

    /// Mean observed gap (diagnostics).
    pub fn mean_gap(&self, workload: &str) -> Option<f64> {
        let tracks = self.tracks.lock().unwrap();
        tracks
            .get(workload)
            .filter(|t| t.samples >= 2)
            .map(|t| t.ewma_gap_ns)
    }

    /// Export every track with a learned cadence (≥ 2 samples — a
    /// single-sample track has no gap worth persisting) as `(workload,
    /// last_arrival_ns, ewma_gap_ns, samples)` rows, sorted by workload —
    /// the persistence surface used by [`super::predictor_store`].
    pub fn export_tracks(&self) -> Vec<(String, u64, f64, u64)> {
        let tracks = self.tracks.lock().unwrap();
        let mut rows: Vec<_> = tracks
            .iter()
            .filter(|(_, t)| t.samples >= 2)
            .map(|(w, t)| (w.clone(), t.last_arrival_ns, t.ewma_gap_ns, t.samples))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }

    /// Restore one track (replacing any existing one). Subsequent
    /// [`Predictor::observe`] calls keep updating the EWMA from the
    /// restored state, so anticipation resumes where the previous process
    /// left off.
    pub fn import_track(
        &self,
        workload: &str,
        last_arrival_ns: u64,
        ewma_gap_ns: f64,
        samples: u64,
    ) {
        let mut tracks = self.tracks.lock().unwrap();
        tracks.insert(
            workload.to_string(),
            Track {
                last_arrival_ns,
                ewma_gap_ns,
                samples: samples.max(1),
                restored: true,
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_uniform_gap() {
        let p = Predictor::new(0.3);
        for i in 0..10u64 {
            p.observe("w", i * 1_000_000);
        }
        let gap = p.mean_gap("w").unwrap();
        assert!((gap - 1_000_000.0).abs() < 1.0, "{gap}");
        assert_eq!(p.predicted_next("w"), Some(10_000_000));
    }

    #[test]
    fn needs_two_samples() {
        let p = Predictor::new(0.3);
        assert!(p.predicted_next("w").is_none());
        p.observe("w", 100);
        assert!(p.predicted_next("w").is_none());
        p.observe("w", 200);
        assert!(p.predicted_next("w").is_some());
    }

    #[test]
    fn wake_window() {
        let p = Predictor::new(0.5);
        p.observe("w", 0);
        p.observe("w", 100_000_000); // gap 100 ms → next at 200 ms
        assert!(!p.should_wake("w", 100_000_001, 10_000_000), "too early");
        assert!(p.should_wake("w", 195_000_000, 10_000_000), "inside lead");
        assert!(
            !p.should_wake("w", 400_000_000, 10_000_000),
            "stale prediction must not wake"
        );
    }

    #[test]
    fn export_import_round_trip() {
        let p = Predictor::new(0.3);
        for i in 0..10u64 {
            p.observe("w", i * 1_000_000);
        }
        for i in 0..3u64 {
            p.observe("a-second", i * 2_000_000);
        }
        // One observation = no learned cadence = nothing to persist.
        p.observe("once-only", 5);
        let rows = p.export_tracks();
        assert_eq!(rows.len(), 2, "1-sample tracks are not exported");
        assert_eq!(rows[0].0, "a-second", "rows sorted by workload");

        let q = Predictor::new(0.3);
        for (w, last, ewma, n) in &rows {
            q.import_track(w, *last, *ewma, *n);
        }
        assert_eq!(q.predicted_next("w"), p.predicted_next("w"));
        assert_eq!(q.mean_gap("w"), p.mean_gap("w"));
        assert_eq!(q.predicted_next("once-only"), None);
        // The restored EWMA keeps evolving on new observations.
        q.observe("w", 20_000_000);
        assert!(q.predicted_next("w").is_some());
    }

    #[test]
    fn first_observation_after_restore_reanchors_without_corrupting_ewma() {
        let p = Predictor::new(0.3);
        // Restored rare function: learned 120 s cadence, anchor rebased to 0.
        p.import_track("w", 0, 120e9, 10);
        // First arrival lands 2 virtual hours after startup — that interval
        // is startup delay, not cadence, and must not enter the EWMA.
        p.observe("w", 7_200_000_000_000);
        assert_eq!(p.mean_gap("w"), Some(120e9), "EWMA must survive re-anchor");
        assert_eq!(
            p.predicted_next("w"),
            Some(7_200_000_000_000 + 120_000_000_000)
        );
        // Subsequent arrivals update normally.
        p.observe("w", 7_320_000_000_000); // exactly one 120 s gap later
        assert_eq!(p.mean_gap("w"), Some(120e9));
    }

    #[test]
    fn adapts_to_rate_change() {
        let p = Predictor::new(0.5);
        let mut t = 0;
        for _ in 0..5 {
            t += 100_000_000;
            p.observe("w", t);
        }
        for _ in 0..20 {
            t += 10_000_000;
            p.observe("w", t);
        }
        let gap = p.mean_gap("w").unwrap();
        assert!(gap < 15_000_000.0, "EWMA must track the new 10ms rate: {gap}");
    }
}
