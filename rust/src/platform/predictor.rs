//! Anticipatory wake-up prediction (Fig. 3 ⑤).
//!
//! "When Serverless Platform predicts there is an incoming user request, it
//! may also wake up a Hibernate Container … in anticipation by sending a
//! SIGCONT." We keep a per-workload EWMA of inter-arrival gaps; the policy
//! loop asks the predictor whether a request is expected within the wake
//! lead time, and if so issues the SIGCONT so the request lands on a
//! WokenUp container (Warm-like latency) instead of a Hibernate one.

use std::collections::HashMap;
use std::sync::Mutex;

/// Per-workload arrival statistics.
#[derive(Debug, Clone, Copy)]
struct Track {
    last_arrival_ns: u64,
    ewma_gap_ns: f64,
    samples: u64,
}

/// EWMA-based next-arrival predictor.
pub struct Predictor {
    alpha: f64,
    tracks: Mutex<HashMap<String, Track>>,
}

impl Predictor {
    pub fn new(alpha: f64) -> Self {
        Self {
            alpha,
            tracks: Mutex::new(HashMap::new()),
        }
    }

    /// Observe an arrival for `workload` at virtual time `now_ns`.
    pub fn observe(&self, workload: &str, now_ns: u64) {
        let mut tracks = self.tracks.lock().unwrap();
        match tracks.get_mut(workload) {
            None => {
                tracks.insert(
                    workload.to_string(),
                    Track {
                        last_arrival_ns: now_ns,
                        ewma_gap_ns: 0.0,
                        samples: 1,
                    },
                );
            }
            Some(t) => {
                let gap = now_ns.saturating_sub(t.last_arrival_ns) as f64;
                t.ewma_gap_ns = if t.samples == 1 {
                    gap
                } else {
                    self.alpha * gap + (1.0 - self.alpha) * t.ewma_gap_ns
                };
                t.last_arrival_ns = now_ns;
                t.samples += 1;
            }
        }
    }

    /// Predicted next arrival time, if we have ≥ 2 samples.
    pub fn predicted_next(&self, workload: &str) -> Option<u64> {
        let tracks = self.tracks.lock().unwrap();
        let t = tracks.get(workload)?;
        if t.samples < 2 {
            return None;
        }
        Some(t.last_arrival_ns + t.ewma_gap_ns as u64)
    }

    /// Should the platform wake a hibernated container for `workload` now?
    /// True when the predicted arrival falls within `lead_ns` of `now_ns`
    /// (and has not already passed by more than one gap — stale tracks
    /// shouldn't cause wake storms).
    pub fn should_wake(&self, workload: &str, now_ns: u64, lead_ns: u64) -> bool {
        let Some(next) = self.predicted_next(workload) else {
            return false;
        };
        let gap = {
            let tracks = self.tracks.lock().unwrap();
            tracks.get(workload).map(|t| t.ewma_gap_ns as u64).unwrap_or(0)
        };
        next.saturating_sub(now_ns) <= lead_ns && now_ns.saturating_sub(next) < gap.max(1)
    }

    /// Mean observed gap (diagnostics).
    pub fn mean_gap(&self, workload: &str) -> Option<f64> {
        let tracks = self.tracks.lock().unwrap();
        tracks
            .get(workload)
            .filter(|t| t.samples >= 2)
            .map(|t| t.ewma_gap_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_uniform_gap() {
        let p = Predictor::new(0.3);
        for i in 0..10u64 {
            p.observe("w", i * 1_000_000);
        }
        let gap = p.mean_gap("w").unwrap();
        assert!((gap - 1_000_000.0).abs() < 1.0, "{gap}");
        assert_eq!(p.predicted_next("w"), Some(10_000_000));
    }

    #[test]
    fn needs_two_samples() {
        let p = Predictor::new(0.3);
        assert!(p.predicted_next("w").is_none());
        p.observe("w", 100);
        assert!(p.predicted_next("w").is_none());
        p.observe("w", 200);
        assert!(p.predicted_next("w").is_some());
    }

    #[test]
    fn wake_window() {
        let p = Predictor::new(0.5);
        p.observe("w", 0);
        p.observe("w", 100_000_000); // gap 100 ms → next at 200 ms
        assert!(!p.should_wake("w", 100_000_001, 10_000_000), "too early");
        assert!(p.should_wake("w", 195_000_000, 10_000_000), "inside lead");
        assert!(
            !p.should_wake("w", 400_000_000, 10_000_000),
            "stale prediction must not wake"
        );
    }

    #[test]
    fn adapts_to_rate_change() {
        let p = Predictor::new(0.5);
        let mut t = 0;
        for _ in 0..5 {
            t += 100_000_000;
            p.observe("w", t);
        }
        for _ in 0..20 {
            t += 10_000_000;
            p.observe("w", t);
        }
        let gap = p.mean_gap("w").unwrap();
        assert!(gap < 15_000_000.0, "EWMA must track the new 10ms rate: {gap}");
    }
}
