//! Per-function container pools.
//!
//! A pool owns every sandbox instance of one workload, plus the scheduling
//! metadata the policy loop needs (virtual-time idleness, serve counts).
//! Sandboxes are mutex-wrapped: one request at a time per container (the
//! paper's model — concurrency comes from more instances).

use crate::container::sandbox::Sandbox;
use crate::container::state::ContainerState;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A pooled instance.
pub struct Instance {
    pub sandbox: Arc<Mutex<Sandbox>>,
    /// Virtual time of last activity (request completion / wake). Shared
    /// with in-flight request handlers (updated outside the pool lock).
    pub last_active: Arc<AtomicU64>,
    /// Virtual time the instance was created.
    pub created_vns: u64,
    /// Cached live-byte charge (see `Sandbox::live_bytes`): resident
    /// footprint while runnable, swapped-slot image bytes while
    /// hibernated. Refreshed at every settled transition point — cold
    /// start, request completion, pipeline-job completion — so the policy
    /// loop and the budget reconciler can read it without touching the
    /// sandbox mutex.
    pub live_gauge: Arc<AtomicU64>,
    /// Reservation flag: exactly one owner (a request handler or the policy
    /// loop) drives the sandbox through a state transition at a time. The
    /// router and the policy engine *skip* reserved instances instead of
    /// blocking on the sandbox mutex, which keeps shard critical sections
    /// short — a busy sandbox (mid-request, mid-swap) never stalls routing.
    busy: Arc<AtomicBool>,
}

impl Instance {
    pub fn state(&self) -> ContainerState {
        self.sandbox.lock().unwrap().state()
    }

    pub fn last_active_vns(&self) -> u64 {
        self.last_active.load(Ordering::Relaxed)
    }

    pub fn touch(&self, now_vns: u64) {
        self.last_active.fetch_max(now_vns, Ordering::Relaxed);
    }

    pub fn idle_ns(&self, now_vns: u64) -> u64 {
        now_vns.saturating_sub(self.last_active_vns())
    }

    /// The cached live-byte charge (no sandbox lock taken).
    pub fn live_bytes(&self) -> u64 {
        self.live_gauge.load(Ordering::Relaxed)
    }

    /// Is the instance currently reserved (request in flight or policy
    /// action in progress)?
    pub fn is_reserved(&self) -> bool {
        self.busy.load(Ordering::Acquire)
    }

    /// Try to reserve the instance. Returns the reservation guard, or
    /// `None` if another owner holds it. Callers reserve under the shard
    /// lock (so routing decisions and reservations are atomic); the guard
    /// releases on drop — including on panic, so a poisoned request can
    /// never leak a permanently-invisible instance.
    pub fn try_reserve(&self) -> Option<Reservation> {
        if self
            .busy
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            Some(Reservation(self.busy.clone()))
        } else {
            None
        }
    }
}

/// Exclusive ownership of an instance's transition rights, released on
/// drop. Holds no lock — routing/policy simply skip reserved instances.
pub struct Reservation(Arc<AtomicBool>);

impl Drop for Reservation {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

/// All instances of one workload.
#[derive(Default)]
pub struct FunctionPool {
    pub instances: Vec<Instance>,
}

impl FunctionPool {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, sandbox: Sandbox, now_vns: u64) -> &Instance {
        let live = sandbox.live_bytes();
        let idx = self.instances.len();
        self.instances.push(Instance {
            sandbox: Arc::new(Mutex::new(sandbox)),
            last_active: Arc::new(AtomicU64::new(now_vns)),
            created_vns: now_vns,
            live_gauge: Arc::new(AtomicU64::new(live)),
            busy: Arc::new(AtomicBool::new(false)),
        });
        &self.instances[idx]
    }

    /// Count instances by state.
    pub fn count_state(&self, s: ContainerState) -> usize {
        self.instances.iter().filter(|i| i.state() == s).count()
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Drop Dead instances (post-eviction cleanup). Reserved instances are
    /// skipped without touching their sandbox mutex: a reserved instance is
    /// never Dead (eviction happens under the reservation and releases it
    /// only afterwards), and callers hold the shard lock — blocking here on
    /// a busy sandbox would stall the whole shard behind one slow request.
    pub fn sweep_dead(&mut self) -> usize {
        let before = self.instances.len();
        self.instances
            .retain(|i| i.is_reserved() || i.state() != ContainerState::Dead);
        before - self.instances.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharingConfig;
    use crate::container::sandbox::SandboxServices;
    use crate::container::NoopRunner;
    use crate::simtime::{Clock, CostModel};
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};
    use std::sync::Arc;

    fn mini_sandbox(id: u64, svc: &Arc<SandboxServices>) -> Sandbox {
        let spec = scaled_for_test(golang_hello(), 32);
        Sandbox::cold_start(id, spec, svc.clone(), &Clock::new()).unwrap()
    }

    #[test]
    fn pool_lifecycle() {
        let svc = SandboxServices::new_local(
            256 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            "pool-test",
        )
        .unwrap();
        let mut pool = FunctionPool::new();
        pool.add(mini_sandbox(1, &svc), 0);
        pool.add(mini_sandbox(2, &svc), 1000);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.count_state(ContainerState::Warm), 2);
        assert_eq!(pool.instances[0].idle_ns(5000), 5000);
        assert_eq!(pool.instances[1].idle_ns(5000), 4000);
        // Evict one and sweep.
        pool.instances[0]
            .sandbox
            .lock()
            .unwrap()
            .terminate()
            .unwrap();
        assert_eq!(pool.sweep_dead(), 1);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn live_gauge_seeds_from_the_sandbox_and_tracks_stores() {
        let svc = SandboxServices::new_local(
            256 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            "pool-gauge-test",
        )
        .unwrap();
        let mut pool = FunctionPool::new();
        let sb = mini_sandbox(1, &svc);
        let expect = sb.live_bytes();
        assert!(expect > 0, "a cold-started sandbox has a live charge");
        pool.add(sb, 0);
        assert_eq!(pool.instances[0].live_bytes(), expect);
        pool.instances[0].live_gauge.store(123, Ordering::Relaxed);
        assert_eq!(pool.instances[0].live_bytes(), 123);
    }

    #[test]
    fn reservation_is_exclusive_until_dropped() {
        let svc = SandboxServices::new_local(
            256 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            "pool-reserve-test",
        )
        .unwrap();
        let mut pool = FunctionPool::new();
        pool.add(mini_sandbox(1, &svc), 0);
        let inst = &pool.instances[0];
        assert!(!inst.is_reserved());
        let guard = inst.try_reserve().expect("first reserve succeeds");
        assert!(inst.is_reserved());
        assert!(inst.try_reserve().is_none(), "second reserve must fail");
        drop(guard);
        assert!(!inst.is_reserved(), "drop releases");
        assert!(
            inst.try_reserve().is_some(),
            "released instance is reservable again"
        );
    }

    #[test]
    fn sweep_skips_reserved_instances_without_blocking() {
        let svc = SandboxServices::new_local(
            256 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            "pool-sweep-test",
        )
        .unwrap();
        let mut pool = FunctionPool::new();
        pool.add(mini_sandbox(1, &svc), 0);
        pool.add(mini_sandbox(2, &svc), 0);
        pool.instances[0]
            .sandbox
            .lock()
            .unwrap()
            .terminate()
            .unwrap();
        // Reserve instance 1 and hold its sandbox mutex on another thread —
        // the sweep must neither remove it nor block on it.
        let guard = pool.instances[1].try_reserve().unwrap();
        let sb = pool.instances[1].sandbox.clone();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        let holder = std::thread::spawn(move || {
            let _sb = sb.lock().unwrap();
            release_rx.recv().unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(pool.sweep_dead(), 1, "only the dead instance is swept");
        assert_eq!(pool.len(), 1);
        release_tx.send(()).unwrap();
        holder.join().unwrap();
        drop(guard);
        assert_eq!(pool.sweep_dead(), 0);
    }
}
