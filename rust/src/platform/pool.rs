//! Per-function container pools.
//!
//! A pool owns every sandbox instance of one workload, plus the scheduling
//! metadata the policy loop needs (virtual-time idleness, serve counts).
//! Sandboxes are mutex-wrapped: one request at a time per container (the
//! paper's model — concurrency comes from more instances).

use crate::container::sandbox::Sandbox;
use crate::container::state::ContainerState;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A pooled instance.
pub struct Instance {
    pub sandbox: Arc<Mutex<Sandbox>>,
    /// Virtual time of last activity (request completion / wake). Shared
    /// with in-flight request handlers (updated outside the pool lock).
    pub last_active: Arc<AtomicU64>,
    /// Virtual time the instance was created.
    pub created_vns: u64,
}

impl Instance {
    pub fn state(&self) -> ContainerState {
        self.sandbox.lock().unwrap().state()
    }

    pub fn last_active_vns(&self) -> u64 {
        self.last_active.load(Ordering::Relaxed)
    }

    pub fn touch(&self, now_vns: u64) {
        self.last_active.fetch_max(now_vns, Ordering::Relaxed);
    }

    pub fn idle_ns(&self, now_vns: u64) -> u64 {
        now_vns.saturating_sub(self.last_active_vns())
    }
}

/// All instances of one workload.
#[derive(Default)]
pub struct FunctionPool {
    pub instances: Vec<Instance>,
}

impl FunctionPool {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, sandbox: Sandbox, now_vns: u64) -> &Instance {
        self.instances.push(Instance {
            sandbox: Arc::new(Mutex::new(sandbox)),
            last_active: Arc::new(AtomicU64::new(now_vns)),
            created_vns: now_vns,
        });
        self.instances.last().unwrap()
    }

    /// Count instances by state.
    pub fn count_state(&self, s: ContainerState) -> usize {
        self.instances.iter().filter(|i| i.state() == s).count()
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Drop Dead instances (post-eviction cleanup).
    pub fn sweep_dead(&mut self) -> usize {
        let before = self.instances.len();
        self.instances.retain(|i| i.state() != ContainerState::Dead);
        before - self.instances.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharingConfig;
    use crate::container::sandbox::SandboxServices;
    use crate::container::NoopRunner;
    use crate::simtime::{Clock, CostModel};
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};
    use std::sync::Arc;

    fn mini_sandbox(id: u64, svc: &Arc<SandboxServices>) -> Sandbox {
        let spec = scaled_for_test(golang_hello(), 32);
        Sandbox::cold_start(id, spec, svc.clone(), &Clock::new()).unwrap()
    }

    #[test]
    fn pool_lifecycle() {
        let svc = SandboxServices::new_local(
            256 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            "pool-test",
        )
        .unwrap();
        let mut pool = FunctionPool::new();
        pool.add(mini_sandbox(1, &svc), 0);
        pool.add(mini_sandbox(2, &svc), 1000);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.count_state(ContainerState::Warm), 2);
        assert_eq!(pool.instances[0].idle_ns(5000), 5000);
        assert_eq!(pool.instances[1].idle_ns(5000), 4000);
        // Evict one and sweep.
        pool.instances[0]
            .sandbox
            .lock()
            .unwrap()
            .terminate()
            .unwrap();
        assert_eq!(pool.sweep_dead(), 1);
        assert_eq!(pool.len(), 1);
    }
}
