//! Control-plane sharding: the lock-granularity layer under [`super::Platform`].
//!
//! The platform's mutable state — per-function [`FunctionPool`]s and
//! [`WorkloadSpec`]s — is partitioned across a fixed array of shards by a
//! deterministic hash of the function name ([`crate::util::fnv1a`]). Each
//! shard guards its slice behind its own mutex, so the request hot path for
//! function A never contends with — let alone blocks on — a lock held for
//! function B on a different shard, and the policy loop walks shards
//! incrementally instead of freezing the whole control plane per tick.
//!
//! Invariants:
//! * a function's pool and spec always live on the same shard (single lock
//!   acquisition per request);
//! * shard count is fixed at platform construction (default: one per CPU),
//!   so `name → shard` never changes over the platform's lifetime — no
//!   rebalancing, no cross-shard moves;
//! * lock ordering is `shard → sandbox`; no code path acquires a shard lock
//!   while holding a sandbox mutex, and no path ever holds two shard locks
//!   at once.

use super::pool::FunctionPool;
use crate::util::fnv1a;
use crate::workloads::WorkloadSpec;
use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard};

/// The state one shard owns: the pools and specs of every function hashed
/// to it.
#[derive(Default)]
pub struct ShardState {
    pub pools: HashMap<String, FunctionPool>,
    pub specs: HashMap<String, WorkloadSpec>,
}

/// One shard: a mutex around its slice of the control-plane state.
#[derive(Default)]
pub struct Shard {
    state: Mutex<ShardState>,
}

impl Shard {
    /// Lock this shard's state. Callers must keep the critical section
    /// short (route + bookkeeping); slow work (cold start, swap I/O,
    /// request execution) happens outside the guard.
    pub fn lock(&self) -> MutexGuard<'_, ShardState> {
        self.state.lock().unwrap()
    }
}

/// The fixed shard array.
pub struct ShardSet {
    shards: Vec<Shard>,
}

impl ShardSet {
    /// Build `n` shards (clamped to ≥ 1).
    pub fn new(n: usize) -> Self {
        Self {
            shards: (0..n.max(1)).map(|_| Shard::default()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Shard index owning `name` (stable for the platform's lifetime).
    pub fn index_for(&self, name: &str) -> usize {
        (fnv1a(name) % self.shards.len() as u64) as usize
    }

    /// The shard owning `name`.
    pub fn shard_for(&self, name: &str) -> &Shard {
        &self.shards[self.index_for(name)]
    }

    pub fn get(&self, idx: usize) -> &Shard {
        &self.shards[idx]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Shard> {
        self.shards.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_stable_and_in_range() {
        let set = ShardSet::new(8);
        for name in ["a", "golang-hello", "fn-3", ""] {
            let i = set.index_for(name);
            assert!(i < 8);
            assert_eq!(i, set.index_for(name), "placement must be stable");
        }
    }

    #[test]
    fn zero_shards_clamped_to_one() {
        let set = ShardSet::new(0);
        assert_eq!(set.len(), 1);
        assert_eq!(set.index_for("anything"), 0);
    }

    #[test]
    fn pool_and_spec_colocated() {
        let set = ShardSet::new(4);
        let name = "nodejs-hello";
        {
            let mut s = set.shard_for(name).lock();
            s.pools.entry(name.to_string()).or_default();
        }
        // The same shard sees the pool; the others don't.
        let own = set.index_for(name);
        for i in 0..set.len() {
            let has = set.get(i).lock().pools.contains_key(name);
            assert_eq!(has, i == own);
        }
    }

    #[test]
    fn different_names_spread() {
        let set = ShardSet::new(8);
        let hit: std::collections::HashSet<usize> = (0..64)
            .map(|i| set.index_for(&format!("workload-{i}")))
            .collect();
        assert!(hit.len() >= 4, "64 names must land on ≥ 4 of 8 shards");
    }
}
