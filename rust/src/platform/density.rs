//! Deployment-density experiment (§1/§4.2): how many keep-alive containers
//! fit in a host memory budget?
//!
//! The paper's headline systems claim: because a Hibernate container keeps
//! 7–25% of the Warm footprint (and WokenUp 28–90%), co-deploying
//! Hibernate/WokenUp containers yields a much higher density than keeping
//! everything Warm. This module packs real sandboxes (not arithmetic
//! estimates) into a budget and reports the achieved density per mode.

use crate::config::SharingConfig;
use crate::container::sandbox::{Sandbox, SandboxServices};
use crate::container::NoopRunner;
use crate::simtime::{Clock, CostModel};
use crate::workloads::WorkloadSpec;
use anyhow::Result;
use std::sync::Arc;

/// Which keep-alive state instances are parked in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParkMode {
    Warm,
    Hibernate,
    /// Hibernate, then wake and serve one request (WokenUp parking).
    WokenUp,
}

impl ParkMode {
    pub fn label(self) -> &'static str {
        match self {
            ParkMode::Warm => "warm",
            ParkMode::Hibernate => "hibernate",
            ParkMode::WokenUp => "woken-up",
        }
    }
}

/// Result of one packing run.
#[derive(Debug, Clone)]
pub struct DensityResult {
    pub mode: ParkMode,
    /// Instances successfully parked within the budget.
    pub instances: u64,
    /// Committed bytes when the budget filled.
    pub committed_bytes: u64,
    /// Mean PSS per parked instance.
    pub mean_pss: u64,
}

/// Pack instances of `spec` into `budget` bytes of committed host memory,
/// parking each in `mode`, until the next instance would exceed the budget
/// (or `max_instances` is hit — a safety valve for tests).
pub fn pack(
    spec: &WorkloadSpec,
    mode: ParkMode,
    budget: u64,
    host_bytes: usize,
    max_instances: u64,
    sharing: SharingConfig,
) -> Result<DensityResult> {
    let svc = SandboxServices::new_local(
        host_bytes,
        CostModel::paper(),
        sharing,
        Arc::new(NoopRunner),
        &format!("density-{}", mode.label()),
    )?;
    let clock = Clock::new();
    let mut parked: Vec<Sandbox> = Vec::new();
    let mut pss_sum = 0u64;

    loop {
        if parked.len() as u64 >= max_instances {
            break;
        }
        let id = parked.len() as u64 + 1;
        let mut sb = Sandbox::cold_start(id, spec.clone(), svc.clone(), &clock)?;
        // Serve one request so the working set exists (a realistic parked
        // container has handled traffic).
        sb.handle_request(&clock)?;
        match mode {
            ParkMode::Warm => {}
            ParkMode::Hibernate => {
                sb.hibernate(&clock)?;
            }
            ParkMode::WokenUp => {
                sb.hibernate(&clock)?;
                // Demand-wake with one request, leaving it WokenUp.
                sb.handle_request(&clock)?;
            }
        }
        let used = svc.host.committed_bytes();
        if used > budget {
            // This instance blew the budget: count up to the previous one.
            let _ = sb.terminate();
            break;
        }
        pss_sum += sb.footprint().total_bytes();
        parked.push(sb);
    }

    let n = parked.len() as u64;
    Ok(DensityResult {
        mode,
        instances: n,
        committed_bytes: svc.host.committed_bytes(),
        mean_pss: if n > 0 { pss_sum / n } else { 0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::functionbench::{nodejs_hello, scaled_for_test};

    #[test]
    fn hibernate_packs_denser_than_warm() {
        let spec = scaled_for_test(nodejs_hello(), 16);
        let budget = 48 << 20;
        let warm = pack(
            &spec,
            ParkMode::Warm,
            budget,
            4 << 30,
            150,
            SharingConfig::default(),
        )
        .unwrap();
        let hib = pack(
            &spec,
            ParkMode::Hibernate,
            budget,
            4 << 30,
            150,
            SharingConfig::default(),
        )
        .unwrap();
        // At 1/16 scale the fixed QKernel resident heap dominates both
        // modes, compressing the ratio; the full-scale bench asserts the
        // paper's ≥3x. Here: strictly denser and clearly smaller PSS.
        assert!(
            hib.instances as f64 >= 1.5 * warm.instances as f64,
            "hibernate {} vs warm {} instances",
            hib.instances,
            warm.instances
        );
        assert!(hib.mean_pss < warm.mean_pss * 3 / 4);
    }

    #[test]
    fn wokenup_between_warm_and_hibernate() {
        let spec = scaled_for_test(nodejs_hello(), 16);
        let budget = 48 << 20;
        let warm = pack(&spec, ParkMode::Warm, budget, 4 << 30, 150, SharingConfig::default()).unwrap();
        let wok = pack(&spec, ParkMode::WokenUp, budget, 4 << 30, 150, SharingConfig::default()).unwrap();
        let hib = pack(&spec, ParkMode::Hibernate, budget, 4 << 30, 150, SharingConfig::default()).unwrap();
        assert!(warm.instances <= wok.instances);
        assert!(wok.instances <= hib.instances);
    }
}
