//! Keep-alive / hibernation policy (§3.1): *deflate instead of evict*.
//!
//! The conventional platform evicts idle Warm containers under memory
//! pressure and eats the next cold start. The paper's platform instead
//! sends SIGSTOP — turning the Warm container into a Hibernate one at a
//! fraction of the memory — and only evicts after a much longer idle
//! period. This module decides, per policy tick:
//!
//! * which idle Warm/WokenUp containers to hibernate (idle > threshold, or
//!   memory pressure above the watermark — most-idle first);
//! * which Hibernate containers to evict outright (idle > eviction
//!   threshold);
//! * which Hibernate containers to wake anticipatorily (predictor says a
//!   request is imminent).
//!
//! A `warm_only` baseline mode reproduces the conventional platform for the
//! density comparison bench.
//!
//! Decisions are cheap; their I/O is not. The platform applies every
//! action as an in-tick state flip (or, for evictions, nothing at all)
//! plus a job on the [`instance pipeline`](super::pipeline), so the tick's
//! latency is never bounded by deflation swap-outs, anticipatory REAP
//! prefetches or eviction teardowns.

use super::pool::FunctionPool;
use super::predictor::Predictor;
use crate::config::PolicyConfig;
use crate::container::state::ContainerState;

/// What the policy wants done to one instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// SIGSTOP instance `idx` of `workload` (deflate).
    Hibernate { workload: String, idx: usize },
    /// Terminate instance (free everything).
    Evict { workload: String, idx: usize },
    /// SIGCONT instance (anticipatory inflate).
    Wake { workload: String, idx: usize },
}

/// Policy operating mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// The paper's platform: hibernate idle containers, evict late.
    Hibernate,
    /// Conventional baseline: evict idle containers (no hibernation).
    WarmOnly,
}

/// The policy engine (stateless between ticks; all state is in the pools).
pub struct PolicyEngine {
    pub cfg: PolicyConfig,
    pub mode: Mode,
    /// Anticipatory wake lead time (ns).
    pub wake_lead_ns: u64,
}

impl PolicyEngine {
    pub fn new(cfg: PolicyConfig, mode: Mode) -> Self {
        Self {
            cfg,
            mode,
            wake_lead_ns: 50_000_000,
        }
    }

    /// Compute actions for one workload's pool at virtual time `now_vns`.
    /// `memory_used` / `budget` drive the pressure path.
    pub fn decide(
        &self,
        workload: &str,
        pool: &FunctionPool,
        now_vns: u64,
        memory_used: u64,
        predictor: Option<&Predictor>,
    ) -> Vec<Action> {
        let mut actions = Vec::new();
        let pressure =
            memory_used as f64 >= self.cfg.pressure_watermark * self.cfg.memory_budget as f64;
        let hibernate_idle_ns = self.cfg.hibernate_idle_ms * 1_000_000;
        let evict_idle_ns = self.cfg.evict_idle_ms * 1_000_000;

        // Idle Warm/WokenUp instances, most idle first.
        let mut idle: Vec<(usize, u64, ContainerState)> = pool
            .instances
            .iter()
            .enumerate()
            .filter_map(|(idx, inst)| {
                // Reserved = request/policy action in flight: not idle, and
                // reading `state()` would block on the sandbox mutex.
                if inst.is_reserved() {
                    return None;
                }
                let s = inst.state();
                match s {
                    ContainerState::Warm | ContainerState::WokenUp => {
                        Some((idx, inst.idle_ns(now_vns), s))
                    }
                    _ => None,
                }
            })
            .collect();
        idle.sort_by_key(|&(_, idle_ns, _)| std::cmp::Reverse(idle_ns));

        for (idx, idle_ns, _s) in &idle {
            let over_idle = *idle_ns >= hibernate_idle_ns;
            if !(over_idle || pressure) {
                continue;
            }
            match self.mode {
                Mode::Hibernate => actions.push(Action::Hibernate {
                    workload: workload.to_string(),
                    idx: *idx,
                }),
                Mode::WarmOnly => {
                    // Conventional platform: under pressure or past
                    // keep-alive, the container is simply evicted.
                    actions.push(Action::Evict {
                        workload: workload.to_string(),
                        idx: *idx,
                    });
                }
            }
        }

        // Old Hibernate containers are eventually evicted too.
        for (idx, inst) in pool.instances.iter().enumerate() {
            if !inst.is_reserved()
                && inst.state() == ContainerState::Hibernate
                && inst.idle_ns(now_vns) >= evict_idle_ns
            {
                actions.push(Action::Evict {
                    workload: workload.to_string(),
                    idx,
                });
            }
        }

        // Anticipatory wake (only meaningful in Hibernate mode, never under
        // memory pressure).
        if self.mode == Mode::Hibernate && self.cfg.predictive_wakeup && !pressure {
            if let Some(pred) = predictor {
                if pred.should_wake(workload, now_vns, self.wake_lead_ns) {
                    if let Some((idx, _)) = pool
                        .instances
                        .iter()
                        .enumerate()
                        .find(|(_, i)| !i.is_reserved() && i.state() == ContainerState::Hibernate)
                    {
                        actions.push(Action::Wake {
                            workload: workload.to_string(),
                            idx,
                        });
                    }
                }
            }
        }

        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharingConfig;
    use crate::container::sandbox::{Sandbox, SandboxServices};
    use crate::container::NoopRunner;
    use crate::simtime::{Clock, CostModel};
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};
    use std::sync::Arc;

    fn rig() -> (Arc<SandboxServices>, FunctionPool) {
        let svc = SandboxServices::new_local(
            512 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            "policy-test",
        )
        .unwrap();
        (svc, FunctionPool::new())
    }

    fn spawn(svc: &Arc<SandboxServices>, id: u64) -> Sandbox {
        Sandbox::cold_start(
            id,
            scaled_for_test(golang_hello(), 32),
            svc.clone(),
            &Clock::new(),
        )
        .unwrap()
    }

    fn cfg() -> PolicyConfig {
        PolicyConfig {
            hibernate_idle_ms: 10,
            evict_idle_ms: 1000,
            memory_budget: 1 << 30,
            pressure_watermark: 0.8,
            predictive_wakeup: true,
            reap_enabled: true,
            tick_stride: 1,
            pipeline_workers: 0,
            pipeline_queue_cap: 0,
        }
    }

    #[test]
    fn idle_warm_hibernated() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 0);
        let engine = PolicyEngine::new(cfg(), Mode::Hibernate);
        // 5 ms idle: nothing.
        assert!(engine
            .decide("w", &pool, 5_000_000, 0, None)
            .is_empty());
        // 20 ms idle: hibernate.
        let actions = engine.decide("w", &pool, 20_000_000, 0, None);
        assert_eq!(
            actions,
            vec![Action::Hibernate {
                workload: "w".into(),
                idx: 0
            }]
        );
    }

    #[test]
    fn pressure_hibernates_even_fresh_instances() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 0);
        let engine = PolicyEngine::new(cfg(), Mode::Hibernate);
        let used = (0.9 * (1u64 << 30) as f64) as u64;
        let actions = engine.decide("w", &pool, 1_000_000, used, None);
        assert!(matches!(actions[0], Action::Hibernate { .. }));
    }

    #[test]
    fn warm_only_evicts_instead() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 0);
        let engine = PolicyEngine::new(cfg(), Mode::WarmOnly);
        let actions = engine.decide("w", &pool, 20_000_000, 0, None);
        assert_eq!(
            actions,
            vec![Action::Evict {
                workload: "w".into(),
                idx: 0
            }]
        );
    }

    #[test]
    fn stale_hibernate_evicted() {
        let (svc, mut pool) = rig();
        let clock = Clock::new();
        let mut s = spawn(&svc, 1);
        s.hibernate(&clock).unwrap();
        pool.add(s, 0);
        let engine = PolicyEngine::new(cfg(), Mode::Hibernate);
        // idle 2 s > evict_idle 1 s
        let actions = engine.decide("w", &pool, 2_000_000_000, 0, None);
        assert_eq!(
            actions,
            vec![Action::Evict {
                workload: "w".into(),
                idx: 0
            }]
        );
    }

    #[test]
    fn predictor_triggers_wake() {
        let (svc, mut pool) = rig();
        let clock = Clock::new();
        let mut s = spawn(&svc, 1);
        s.hibernate(&clock).unwrap();
        pool.add(s, 0);
        let engine = PolicyEngine::new(cfg(), Mode::Hibernate);
        let pred = Predictor::new(0.5);
        pred.observe("w", 0);
        pred.observe("w", 100_000_000); // next expected ≈ 200 ms
        let actions = engine.decide("w", &pool, 190_000_000, 0, Some(&pred));
        assert!(
            actions.contains(&Action::Wake {
                workload: "w".into(),
                idx: 0
            }),
            "{actions:?}"
        );
    }
}
