//! Keep-alive / hibernation policy (§3.1): *deflate instead of evict* —
//! now a pluggable trait instead of a hardcoded engine.
//!
//! The conventional platform evicts idle Warm containers under memory
//! pressure and eats the next cold start. The paper's platform instead
//! sends SIGSTOP — turning the Warm container into a Hibernate one at a
//! fraction of the memory — and only evicts after a much longer idle
//! period. Which instances that happens to, and why, is the [`Policy`]
//! trait's job: once per tick and per function pool, the platform hands a
//! policy a [`TickCtx`] (virtual time, the predictor, the hierarchical
//! [`MemBudget`]) and a [`PoolView`] (per-instance state/idleness/live
//! bytes snapshot), and gets back [`Decision`]s — shard-local instance
//! indices plus a typed [`Reason`] that flows into
//! [`metrics`](super::metrics) and the replay report.
//!
//! Three built-ins ship:
//!
//! * [`HibernatePolicy`] — the paper's platform (hibernate idle, evict
//!   late, anticipatory wake); identical decisions to the pre-trait
//!   engine;
//! * [`WarmOnlyPolicy`] — the conventional baseline (evict instead of
//!   hibernate) the density comparison bench runs against;
//! * [`TenantFairPolicy`] — hibernate semantics plus per-tenant budget
//!   enforcement: each instance's live bytes are charged to the tenant
//!   parsed from its workload name ([`tenant_of`]), and an over-budget
//!   tenant's most-idle instances are deflated first, just enough to
//!   cover the overage.
//!
//! Decisions are cheap; their I/O is not. The platform applies every
//! action as an in-tick state flip (or, for evictions, nothing at all)
//! plus a job on the [`instance pipeline`](super::pipeline), so the tick's
//! latency is never bounded by deflation swap-outs, anticipatory REAP
//! prefetches or eviction teardowns.
//!
//! # Budget hierarchy and pressure leases
//!
//! Policies never see a raw host-global byte count. They see a
//! [`MemBudget`]: the budget/used pair scoped to the deciding shard (the
//! whole host budget by default; this shard's *lease* when
//! `policy.pressure_leases` is on) plus the reconciled per-tenant ledger.
//! The frame behind it ([`BudgetFrame`]) is rebuilt once per live tick
//! and once per replay epoch by the reconciling leader, which is what
//! keeps pressure decisions deterministic at any replay worker count —
//! see `docs/policy.md` for the full determinism model.

use super::predictor::Predictor;
use crate::config::PolicyConfig;
use crate::container::state::ContainerState;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

/// What a policy wants done to one instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verb {
    /// SIGSTOP the instance (deflate).
    Hibernate,
    /// Terminate the instance (free everything).
    Evict,
    /// SIGCONT the instance (anticipatory inflate).
    Wake,
}

/// Why a policy decided it — the typed reason that flows into
/// [`super::metrics`] counters and the replay report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reason {
    /// Idle past `policy.hibernate_idle_ms` (or the warm-only keep-alive).
    IdleTimeout,
    /// The deciding scope (host budget or shard lease) crossed the
    /// pressure watermark.
    HostPressure,
    /// The instance's tenant is over its budget share.
    TenantPressure,
    /// A Hibernate container idled past `policy.evict_idle_ms`.
    StaleHibernate,
    /// The predictor expects a request within the wake lead.
    AnticipatedArrival,
}

impl Verb {
    /// Stable wire code for flight-recorder `Decision` events
    /// (see [`crate::obs::pack_decision`]).
    pub fn code(self) -> u8 {
        match self {
            Verb::Hibernate => 0,
            Verb::Wake => 1,
            Verb::Evict => 2,
        }
    }
}

impl Reason {
    pub fn label(self) -> &'static str {
        match self {
            Reason::IdleTimeout => "idle-timeout",
            Reason::HostPressure => "host-pressure",
            Reason::TenantPressure => "tenant-pressure",
            Reason::StaleHibernate => "stale-hibernate",
            Reason::AnticipatedArrival => "anticipated-arrival",
        }
    }

    /// Stable wire code for flight-recorder `Decision` events
    /// (see [`crate::obs::pack_decision`]).
    pub fn code(self) -> u8 {
        match self {
            Reason::IdleTimeout => 0,
            Reason::HostPressure => 1,
            Reason::TenantPressure => 2,
            Reason::StaleHibernate => 3,
            Reason::AnticipatedArrival => 4,
        }
    }
}

/// One policy decision: a shard-local pool index plus verb and reason.
/// Deliberately `Copy`-small — no workload string rides along (the caller
/// deciding a pool already knows which pool it is), which is what keeps a
/// 1000-function replay tick free of per-action allocations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    pub idx: usize,
    pub verb: Verb,
    pub reason: Reason,
}

/// An applied action, as reported back from `Platform::policy_tick` (the
/// workload name is resolved by the caller that held the shard lock — only
/// *applied* actions, which do real I/O anyway, pay for the string).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AppliedAction {
    pub workload: String,
    pub idx: usize,
    pub verb: Verb,
    pub reason: Reason,
}

/// Immutable snapshot of one pool instance, taken under the shard lock
/// before any of this tick's decisions are applied (so decisions never
/// depend on apply order). Reserved instances are omitted entirely.
#[derive(Debug, Clone, Copy)]
pub struct InstanceView {
    /// Index into the pool's instance vector.
    pub idx: usize,
    pub state: ContainerState,
    pub idle_ns: u64,
    /// The instance's live-byte charge: resident footprint while runnable,
    /// swapped-slot image bytes while hibernated (see
    /// `Sandbox::live_bytes`).
    pub live_bytes: u64,
}

/// One function pool as a policy sees it.
pub struct PoolView<'a> {
    pub workload: &'a str,
    /// Tenant parsed from the workload name ([`tenant_of`]), if any.
    pub tenant: Option<&'a str>,
    pub instances: &'a [InstanceView],
}

/// Everything scope-wide a decision may depend on.
pub struct TickCtx<'a> {
    pub now_vns: u64,
    pub cfg: &'a PolicyConfig,
    /// The hierarchical budget for the deciding shard — see [`MemBudget`].
    pub budget: &'a MemBudget<'a>,
    pub predictor: Option<&'a Predictor>,
    /// Learned per-function anticipatory wake leads.
    pub wake_leads: &'a WakeLeads,
}

/// The policy trait: one call per (tick, function pool).
///
/// Contract: `decide` must be a pure function of `(ctx, pool)` plus the
/// policy's own immutable configuration — replay determinism depends on
/// it. Decisions are applied by the platform *after* every pool on the
/// shard has been decided, so a decision for pool B never observes pool
/// A's applications from the same tick. The only sanctioned cross-pool
/// channel is the budget's deflation ledger
/// ([`MemBudget::note_deflated`]), which the platform walks in sorted
/// workload order precisely so it stays deterministic.
pub trait Policy: Send + Sync {
    /// Stable identifier (`policy.kind` spelling).
    fn name(&self) -> &'static str;
    fn decide(&self, ctx: &TickCtx<'_>, pool: &PoolView<'_>) -> Vec<Decision>;
}

/// Known `policy.kind` values, resolvable by [`build_policy`].
pub const KINDS: &[&str] = &["hibernate", "warm-only", "tenant-fair"];

/// Resolve `cfg.kind` to a built-in policy.
pub fn build_policy(cfg: &PolicyConfig) -> Result<Box<dyn Policy>> {
    match cfg.kind.as_str() {
        "" | "hibernate" => Ok(Box::new(HibernatePolicy)),
        "warm-only" | "warm_only" => Ok(Box::new(WarmOnlyPolicy)),
        "tenant-fair" | "tenant_fair" => Ok(Box::new(TenantFairPolicy)),
        other => bail!(
            "unknown policy.kind `{other}` (known: {})",
            KINDS.join(", ")
        ),
    }
}

/// Parse the tenant a workload belongs to from its name: the
/// `tNN-` prefix convention the `tenant-skewed` scenario established
/// (`t` followed by one or more digits, then a dash). Returns the prefix
/// without the dash.
pub fn tenant_of(workload: &str) -> Option<&str> {
    let (prefix, _) = workload.split_once('-')?;
    let digits = prefix.strip_prefix('t')?;
    if !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()) {
        Some(prefix)
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// Budget hierarchy
// ---------------------------------------------------------------------------

/// One tenant's reconciled ledger row: live bytes charged to it and the
/// budget it is entitled to (explicit `[tenants.<name>] memory_budget`, or
/// its weight share of what the host budget leaves over).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantEntry {
    pub name: String,
    pub used: u64,
    pub budget: u64,
    /// The tenant's reconciled per-shard usage distribution — the basis
    /// for splitting its (watermarked) budget into per-shard cap shares,
    /// exactly like the host budget splits into leases. Empty for
    /// configured-but-unobserved tenants.
    pub shard_used: Vec<u64>,
}

/// One shard's *live* usage figures, computed by the deciding shard at
/// tick time (its own state is single-owner between reconciliations, so
/// the read is deterministic at any replay worker count).
#[derive(Debug, Clone)]
pub struct ShardLive {
    /// The shard index these figures belong to.
    pub si: usize,
    /// Live committed bytes in the shard (gauge sum).
    pub committed: u64,
    /// Live per-tenant bytes in the shard, sorted by tenant name.
    pub tenant_used: Vec<(String, u64)>,
}

/// A reconciled budget frame: built once per live policy tick and once
/// per replay epoch (by the epoch leader, behind the barrier), then read
/// by every shard tick until the next reconciliation.
#[derive(Debug, Clone, Default)]
pub struct BudgetFrame {
    /// Host bytes committed at reconciliation (the classic pressure
    /// signal, and the density timeline sample).
    pub host_used: u64,
    /// Per-shard live-byte sums at reconciliation (the lease basis).
    pub shard_committed: Vec<u64>,
    /// Per-shard budget leases (`policy.pressure_leases`): the host budget
    /// split proportionally to `shard_committed`. `None` = leases off,
    /// every shard decides against the whole host budget.
    pub leases: Option<Vec<u64>>,
    /// Reconciled tenant ledger, sorted by tenant name. Empty unless the
    /// config tracks tenants.
    pub tenants: Vec<TenantEntry>,
}

impl BudgetFrame {
    /// Split `budget` into per-shard leases proportional to `committed`.
    /// With nothing committed anywhere, the split is equal: there is no
    /// usage signal yet. Every lease is additionally floored at **half an
    /// equal share** — a shard idle at reconciliation must be able to
    /// absorb a mid-epoch cold start without instantly reading as
    /// pressured (a zero lease would turn any new instance there into
    /// "host pressure" for the rest of the epoch, force-deflating it
    /// regardless of real host headroom). Leases are pressure thresholds,
    /// not allocations, so the mild over-subscription the floor
    /// introduces is benign.
    pub fn split_leases(budget: u64, committed: &[u64]) -> Vec<u64> {
        let n = committed.len().max(1) as u64;
        let total: u128 = committed.iter().map(|&c| c as u128).sum();
        if total == 0 {
            return committed.iter().map(|_| budget / n).collect();
        }
        let floor = budget / (2 * n);
        committed
            .iter()
            .map(|&c| (((budget as u128 * c as u128) / total) as u64).max(floor))
            .collect()
    }

    /// The [`MemBudget`] shard `si` decides against. `live` carries the
    /// shard's *current* figures and must be supplied when leases or
    /// tenants are on: a shard's own state is single-owner between
    /// reconciliations, so reading it live is both deterministic and
    /// sharper than the frame-time snapshot (and, for tenants, is what
    /// stops a stale overage being re-paid tick after tick). Without it
    /// the scope is the whole host and the reconciled snapshot is the
    /// only interleaving-independent figure.
    pub fn mem_budget<'a>(
        &'a self,
        si: usize,
        cfg: &PolicyConfig,
        live: Option<&'a ShardLive>,
    ) -> MemBudget<'a> {
        let (budget, used) = match &self.leases {
            Some(leases) => (
                leases[si],
                live.map(|l| l.committed).unwrap_or_else(|| {
                    self.shard_committed.get(si).copied().unwrap_or(0)
                }),
            ),
            None => (cfg.memory_budget, self.host_used),
        };
        MemBudget {
            budget_bytes: budget,
            used_bytes: used,
            watermark: cfg.pressure_watermark,
            tenants: &self.tenants,
            live,
            deflated: RefCell::new(Vec::new()),
        }
    }
}

/// Resolve the tenant ledger from observed per-shard usage plus the
/// `[tenants]` config: explicitly-budgeted tenants keep their figure; the
/// rest share what the host budget leaves over, proportionally to their
/// weights (default 1.0).
pub fn resolve_tenants(
    cfg: &PolicyConfig,
    used: &BTreeMap<String, Vec<u64>>,
) -> Vec<TenantEntry> {
    let mut names: Vec<&str> = used.keys().map(|s| s.as_str()).collect();
    for t in &cfg.tenants {
        if !used.contains_key(&t.name) {
            names.push(&t.name);
        }
    }
    names.sort_unstable();
    names.dedup();
    if names.is_empty() {
        return Vec::new();
    }
    let explicit: u64 = cfg
        .tenants
        .iter()
        .filter_map(|t| t.memory_budget)
        .sum();
    let shared_pool = cfg.memory_budget.saturating_sub(explicit);
    let total_weight: f64 = names
        .iter()
        .filter(|n| cfg.tenant_cfg(n).and_then(|t| t.memory_budget).is_none())
        .map(|n| cfg.tenant_cfg(n).map(|t| t.weight).unwrap_or(1.0))
        .sum();
    names
        .into_iter()
        .map(|name| {
            let budget = match cfg.tenant_cfg(name).and_then(|t| t.memory_budget) {
                Some(b) => b,
                None => {
                    let w = cfg.tenant_cfg(name).map(|t| t.weight).unwrap_or(1.0);
                    if total_weight > 0.0 {
                        (shared_pool as f64 * (w / total_weight)) as u64
                    } else {
                        0
                    }
                }
            };
            let shard_used = used.get(name).cloned().unwrap_or_default();
            TenantEntry {
                name: name.to_string(),
                used: shard_used.iter().sum(),
                budget,
                shard_used,
            }
        })
        .collect()
}

/// The budget a policy decides against: host → tenant, scoped to one
/// shard tick. Carries a small interior-mutable *deflation ledger* so a
/// tick that deflates an over-budget tenant's instance in one pool does
/// not re-deflate for the same overage in the tenant's next pool (the
/// platform walks pools in sorted name order, so the ledger — and with it
/// every decision — is deterministic).
///
/// Tenant enforcement is **shard-scoped** when `live` figures are
/// supplied (the platform always supplies them): a globally-over tenant's
/// watermarked budget splits into per-shard cap shares proportional to
/// its reconciled per-shard usage, and each shard pays down only its own
/// live usage above its share. That keeps the total response equal to the
/// global overage (shares sum to the cap), keeps it deterministic (live
/// figures are shard-local), and — because deflations drop the live
/// gauges at the in-tick flip — stops a stale overage from being re-paid
/// tick after tick within one reconciliation interval.
pub struct MemBudget<'a> {
    budget_bytes: u64,
    used_bytes: u64,
    watermark: f64,
    tenants: &'a [TenantEntry],
    /// The deciding shard's live figures (`None` only in direct tests:
    /// tenant scoping then falls back to the global reconciled numbers).
    live: Option<&'a ShardLive>,
    /// `(tenant index, bytes deflated this tick scope)`.
    deflated: RefCell<Vec<(usize, u64)>>,
}

impl<'a> MemBudget<'a> {
    /// Host-global scope (tests and callers without shard-live figures);
    /// the platform builds budgets via [`BudgetFrame::mem_budget`].
    pub fn new(
        budget_bytes: u64,
        used_bytes: u64,
        watermark: f64,
        tenants: &'a [TenantEntry],
    ) -> Self {
        Self {
            budget_bytes,
            used_bytes,
            watermark,
            tenants,
            live: None,
            deflated: RefCell::new(Vec::new()),
        }
    }

    /// Like [`Self::new`] with the deciding shard's live figures attached
    /// (what [`BudgetFrame::mem_budget`] produces).
    pub fn with_live(
        budget_bytes: u64,
        used_bytes: u64,
        watermark: f64,
        tenants: &'a [TenantEntry],
        live: &'a ShardLive,
    ) -> Self {
        Self {
            live: Some(live),
            ..Self::new(budget_bytes, used_bytes, watermark, tenants)
        }
    }

    /// Budget bytes of the deciding scope (host budget, or this shard's
    /// lease).
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Bytes charged against that budget.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Scope-level memory pressure: usage at or past the watermark
    /// fraction of the budget. (Nothing used = no pressure, whatever the
    /// budget — a zero lease on an empty shard must not gate wakes.)
    pub fn pressure(&self) -> bool {
        self.used_bytes > 0
            && self.used_bytes as f64 >= self.watermark * self.budget_bytes as f64
    }

    fn tenant_index(&self, name: &str) -> Option<usize> {
        self.tenants
            .binary_search_by(|t| t.name.as_str().cmp(name))
            .ok()
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantEntry> {
        self.tenant_index(name).map(|i| &self.tenants[i])
    }

    /// How many live bytes tenant `name` is over its watermarked budget
    /// in this deciding scope, minus what this tick scope already
    /// deflated for it. Zero for unknown tenants, and zero everywhere for
    /// a tenant that was under budget at reconciliation (a shard where an
    /// under-budget tenant just cold-started must not deflate it). For a
    /// globally-over tenant with shard-live figures, the scope is the
    /// shard's live usage against its proportional cap share (see the
    /// type docs); without live figures it is the global reconciled pair.
    pub fn tenant_overage(&self, name: &str) -> u64 {
        let Some(i) = self.tenant_index(name) else {
            return 0;
        };
        let t = &self.tenants[i];
        let cap_total = (self.watermark * t.budget as f64) as u64;
        if t.used <= cap_total {
            return 0; // under budget at reconciliation: nothing to pay
        }
        let (used_scope, cap_scope) = match self.live {
            Some(live) => {
                let basis_total: u128 =
                    t.shard_used.iter().map(|&b| b as u128).sum();
                let basis = t.shard_used.get(live.si).copied().unwrap_or(0);
                let cap = if basis_total > 0 {
                    ((cap_total as u128 * basis as u128) / basis_total) as u64
                } else {
                    0
                };
                let used = live
                    .tenant_used
                    .binary_search_by(|(n, _)| n.as_str().cmp(name))
                    .ok()
                    .map(|j| live.tenant_used[j].1)
                    .unwrap_or(0);
                (used, cap)
            }
            None => (t.used, cap_total),
        };
        let over = used_scope.saturating_sub(cap_scope);
        let paid = self
            .deflated
            .borrow()
            .iter()
            .find(|(ti, _)| *ti == i)
            .map(|(_, b)| *b)
            .unwrap_or(0);
        over.saturating_sub(paid)
    }

    /// Is the tenant over its watermarked budget per the *reconciled*
    /// figures alone, ignoring this tick's deflation credits? The
    /// anticipatory-wake gate uses this: a tenant that was over at
    /// reconciliation must not re-inflate an instance in the very tick
    /// that deflated it back under (deflate/wake oscillation).
    pub fn tenant_over_reconciled(&self, name: &str) -> bool {
        self.tenant(name)
            .map(|t| {
                let cap = (self.watermark * t.budget as f64) as u64;
                t.used > cap
            })
            .unwrap_or(false)
    }

    /// Record that `bytes` of tenant `name`'s charge are being deflated
    /// this tick scope (so later pools of the same tenant see the reduced
    /// overage).
    ///
    /// The credit is deliberately the instance's *full* current charge,
    /// not the (unknowable at decide time) warm-minus-image delta, and it
    /// is recorded at decide time even if the apply later loses a
    /// reservation race. Both make the ledger a conservative
    /// *under*-responder within one tick — the next reconciliation
    /// recomputes truth from the gauges, so enforcement converges at
    /// instance granularity without ever over-deflating for charge
    /// already on its way out.
    pub fn note_deflated(&self, name: &str, bytes: u64) {
        let Some(i) = self.tenant_index(name) else {
            return;
        };
        let mut led = self.deflated.borrow_mut();
        match led.iter_mut().find(|(ti, _)| *ti == i) {
            Some((_, b)) => *b += bytes,
            None => led.push((i, bytes)),
        }
    }
}

// ---------------------------------------------------------------------------
// Adaptive wake lead
// ---------------------------------------------------------------------------

/// The pre-first-sample wake lead — the constant the engine always used.
/// Keeping it as the seed means the *first* anticipatory wake of every
/// function fingerprints exactly as before; later wakes lead by the
/// learned inflation time.
pub const WAKE_LEAD_SEED_NS: u64 = 50_000_000;
/// Clamp floor for the learned lead (5 ms).
pub const WAKE_LEAD_MIN_NS: u64 = 5_000_000;
/// Clamp ceiling for the learned lead (250 ms).
pub const WAKE_LEAD_MAX_NS: u64 = 250_000_000;
const WAKE_LEAD_ALPHA: f64 = 0.3;
const WAKE_LEAD_STRIPES: usize = 16;

/// Learned per-function anticipatory wake leads: an EWMA over measured
/// `wake_finish` durations (the pipeline times every inflation job in
/// charged virtual time, so the learned value is deterministic). Striped
/// like the metrics registry — the pipeline workers write, every policy
/// tick reads.
pub struct WakeLeads {
    adaptive: bool,
    stripes: Vec<Mutex<HashMap<String, u64>>>,
}

impl WakeLeads {
    /// `adaptive = false` pins every lead to [`WAKE_LEAD_SEED_NS`] (the
    /// pre-adaptive behavior, `policy.adaptive_wake_lead = false`).
    pub fn new(adaptive: bool) -> Self {
        Self {
            adaptive,
            stripes: (0..WAKE_LEAD_STRIPES)
                .map(|_| Mutex::new(HashMap::new()))
                .collect(),
        }
    }

    fn stripe(&self, workload: &str) -> &Mutex<HashMap<String, u64>> {
        &self.stripes
            [(crate::util::fnv1a(workload) % WAKE_LEAD_STRIPES as u64) as usize]
    }

    /// Fold one measured inflation duration into the function's EWMA.
    pub fn observe(&self, workload: &str, measured_ns: u64) {
        if !self.adaptive {
            return;
        }
        let mut map = self.stripe(workload).lock().unwrap();
        match map.get_mut(workload) {
            Some(ewma) => {
                *ewma = (WAKE_LEAD_ALPHA * measured_ns as f64
                    + (1.0 - WAKE_LEAD_ALPHA) * *ewma as f64) as u64;
            }
            None => {
                map.insert(workload.to_string(), measured_ns);
            }
        }
    }

    /// The lead to SIGCONT ahead of a predicted arrival: the learned EWMA
    /// clamped to [[`WAKE_LEAD_MIN_NS`], [`WAKE_LEAD_MAX_NS`]], or
    /// [`WAKE_LEAD_SEED_NS`] before the first sample.
    pub fn lead_ns(&self, workload: &str) -> u64 {
        self.stripe(workload)
            .lock()
            .unwrap()
            .get(workload)
            .map(|&e| e.clamp(WAKE_LEAD_MIN_NS, WAKE_LEAD_MAX_NS))
            .unwrap_or(WAKE_LEAD_SEED_NS)
    }
}

// ---------------------------------------------------------------------------
// Built-in policies
// ---------------------------------------------------------------------------

fn sorted_runnable(pool: &PoolView<'_>) -> Vec<InstanceView> {
    let mut idle: Vec<InstanceView> = pool
        .instances
        .iter()
        .filter(|v| matches!(v.state, ContainerState::Warm | ContainerState::WokenUp))
        .copied()
        .collect();
    // Most idle first; the sort is stable, so ties keep pool index order.
    idle.sort_by_key(|v| std::cmp::Reverse(v.idle_ns));
    idle
}

/// The shared deflate-or-evict sweep every built-in runs: idle (or
/// pressured, or — when `tenant_aware` — tenant-over-budget) runnable
/// instances, most idle first, each pushed with `verb` and the
/// highest-priority applicable reason. Tenant-aware sweeps charge every
/// chosen instance against the budget's deflation ledger, whatever the
/// reason — any deflation pays the tenant's overage down.
fn sweep_runnable(
    ctx: &TickCtx<'_>,
    pool: &PoolView<'_>,
    verb: Verb,
    tenant_aware: bool,
    out: &mut Vec<Decision>,
) {
    let pressure = ctx.budget.pressure();
    let hibernate_idle_ns = ctx.cfg.hibernate_idle_ms * 1_000_000;
    for v in sorted_runnable(pool) {
        let over_idle = v.idle_ns >= hibernate_idle_ns;
        let tenant_hit = tenant_aware
            && pool
                .tenant
                .map(|t| ctx.budget.tenant_overage(t) > 0)
                .unwrap_or(false);
        if !(over_idle || pressure || tenant_hit) {
            continue;
        }
        if tenant_aware {
            if let Some(t) = pool.tenant {
                ctx.budget.note_deflated(t, v.live_bytes);
            }
        }
        out.push(Decision {
            idx: v.idx,
            verb,
            reason: if over_idle {
                Reason::IdleTimeout
            } else if tenant_hit {
                Reason::TenantPressure
            } else {
                Reason::HostPressure
            },
        });
    }
}

fn evict_stale_hibernates(ctx: &TickCtx<'_>, pool: &PoolView<'_>, out: &mut Vec<Decision>) {
    let evict_idle_ns = ctx.cfg.evict_idle_ms * 1_000_000;
    for v in pool.instances {
        if v.state == ContainerState::Hibernate && v.idle_ns >= evict_idle_ns {
            out.push(Decision {
                idx: v.idx,
                verb: Verb::Evict,
                reason: Reason::StaleHibernate,
            });
        }
    }
}

fn anticipatory_wake(ctx: &TickCtx<'_>, pool: &PoolView<'_>, out: &mut Vec<Decision>) {
    if !ctx.cfg.predictive_wakeup {
        return;
    }
    let Some(pred) = ctx.predictor else { return };
    if !pred.should_wake(pool.workload, ctx.now_vns, ctx.wake_leads.lead_ns(pool.workload)) {
        return;
    }
    if let Some(v) = pool
        .instances
        .iter()
        .find(|v| v.state == ContainerState::Hibernate)
    {
        out.push(Decision {
            idx: v.idx,
            verb: Verb::Wake,
            reason: Reason::AnticipatedArrival,
        });
    }
}

/// The paper's platform: hibernate idle containers (and everything under
/// memory pressure), evict only stale Hibernate ones, wake
/// anticipatorily. Decision-for-decision identical to the pre-trait
/// `PolicyEngine` in `Mode::Hibernate` with
/// `policy.adaptive_wake_lead = false`; under the adaptive default, wake
/// timing matches up to each function's first measured inflation and
/// then leads by the learned duration instead of the 50 ms constant.
pub struct HibernatePolicy;

impl Policy for HibernatePolicy {
    fn name(&self) -> &'static str {
        "hibernate"
    }

    fn decide(&self, ctx: &TickCtx<'_>, pool: &PoolView<'_>) -> Vec<Decision> {
        let mut out = Vec::new();
        sweep_runnable(ctx, pool, Verb::Hibernate, false, &mut out);
        evict_stale_hibernates(ctx, pool, &mut out);
        // Never wake into pressure — inflation brings the memory back.
        if !ctx.budget.pressure() {
            anticipatory_wake(ctx, pool, &mut out);
        }
        out
    }
}

/// Conventional baseline: idle (or pressured) containers are evicted
/// outright — no hibernation, no anticipation. The density comparison
/// bench's control arm.
pub struct WarmOnlyPolicy;

impl Policy for WarmOnlyPolicy {
    fn name(&self) -> &'static str {
        "warm-only"
    }

    fn decide(&self, ctx: &TickCtx<'_>, pool: &PoolView<'_>) -> Vec<Decision> {
        let mut out = Vec::new();
        sweep_runnable(ctx, pool, Verb::Evict, false, &mut out);
        evict_stale_hibernates(ctx, pool, &mut out);
        out
    }
}

/// Hibernate semantics plus per-tenant budget fairness: a tenant whose
/// charged live bytes cross its (watermarked) budget has its most-idle
/// instances deflated — just enough of them, by live-byte charge, to
/// cover the overage — even when they are not idle-eligible and the host
/// scope is not under pressure. Anticipatory wakes are additionally gated
/// on the tenant being under budget (waking inflates the charge back).
pub struct TenantFairPolicy;

impl Policy for TenantFairPolicy {
    fn name(&self) -> &'static str {
        "tenant-fair"
    }

    fn decide(&self, ctx: &TickCtx<'_>, pool: &PoolView<'_>) -> Vec<Decision> {
        let mut out = Vec::new();
        sweep_runnable(ctx, pool, Verb::Hibernate, true, &mut out);
        evict_stale_hibernates(ctx, pool, &mut out);
        // Gate wakes on the *reconciled* tenant state, not the ledger:
        // the tick that just deflated an over-budget tenant under its cap
        // must not anticipatorily re-inflate it in the same breath.
        let tenant_over = pool
            .tenant
            .map(|t| ctx.budget.tenant_over_reconciled(t))
            .unwrap_or(false);
        if !ctx.budget.pressure() && !tenant_over {
            anticipatory_wake(ctx, pool, &mut out);
        }
        out
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SharingConfig, TenantBudget};
    use crate::container::sandbox::{Sandbox, SandboxServices};
    use crate::container::NoopRunner;
    use crate::platform::pool::FunctionPool;
    use crate::simtime::{Clock, CostModel};
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};
    use std::sync::Arc;

    fn rig() -> (Arc<SandboxServices>, FunctionPool) {
        let svc = SandboxServices::new_local(
            512 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            "policy-test",
        )
        .unwrap();
        (svc, FunctionPool::new())
    }

    fn spawn(svc: &Arc<SandboxServices>, id: u64) -> Sandbox {
        Sandbox::cold_start(
            id,
            scaled_for_test(golang_hello(), 32),
            svc.clone(),
            &Clock::new(),
        )
        .unwrap()
    }

    fn cfg() -> PolicyConfig {
        PolicyConfig {
            hibernate_idle_ms: 10,
            evict_idle_ms: 1000,
            memory_budget: 1 << 30,
            pressure_watermark: 0.8,
            predictive_wakeup: true,
            reap_enabled: true,
            tick_stride: 1,
            pipeline_workers: 0,
            pipeline_queue_cap: 0,
            kind: "hibernate".into(),
            adaptive_wake_lead: true,
            pressure_leases: false,
            tenants: Vec::new(),
        }
    }

    /// Mirror of the platform's view building: unreserved instances with
    /// state/idleness/live bytes.
    fn views(pool: &FunctionPool, now_vns: u64) -> Vec<InstanceView> {
        pool.instances
            .iter()
            .enumerate()
            .filter(|(_, i)| !i.is_reserved())
            .map(|(idx, i)| InstanceView {
                idx,
                state: i.state(),
                idle_ns: i.idle_ns(now_vns),
                live_bytes: i.live_bytes(),
            })
            .collect()
    }

    #[allow(clippy::too_many_arguments)]
    fn decide_one(
        policy: &dyn Policy,
        cfg: &PolicyConfig,
        pool: &FunctionPool,
        workload: &str,
        now_vns: u64,
        budget: &MemBudget<'_>,
        predictor: Option<&Predictor>,
        leads: &WakeLeads,
    ) -> Vec<Decision> {
        let v = views(pool, now_vns);
        let ctx = TickCtx {
            now_vns,
            cfg,
            budget,
            predictor,
            wake_leads: leads,
        };
        policy.decide(
            &ctx,
            &PoolView {
                workload,
                tenant: tenant_of(workload),
                instances: &v,
            },
        )
    }

    fn host_budget(cfg: &PolicyConfig, used: u64) -> MemBudget<'static> {
        MemBudget::new(cfg.memory_budget, used, cfg.pressure_watermark, &[])
    }

    #[test]
    fn idle_warm_hibernated() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 0);
        let c = cfg();
        let leads = WakeLeads::new(true);
        // 5 ms idle: nothing.
        assert!(decide_one(
            &HibernatePolicy,
            &c,
            &pool,
            "w",
            5_000_000,
            &host_budget(&c, 0),
            None,
            &leads
        )
        .is_empty());
        // 20 ms idle: hibernate, for idleness.
        let ds = decide_one(
            &HibernatePolicy,
            &c,
            &pool,
            "w",
            20_000_000,
            &host_budget(&c, 0),
            None,
            &leads,
        );
        assert_eq!(
            ds,
            vec![Decision {
                idx: 0,
                verb: Verb::Hibernate,
                reason: Reason::IdleTimeout
            }]
        );
    }

    #[test]
    fn pressure_hibernates_even_fresh_instances() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 0);
        let c = cfg();
        let used = (0.9 * (1u64 << 30) as f64) as u64;
        let ds = decide_one(
            &HibernatePolicy,
            &c,
            &pool,
            "w",
            1_000_000,
            &host_budget(&c, used),
            None,
            &WakeLeads::new(true),
        );
        assert_eq!(ds[0].verb, Verb::Hibernate);
        assert_eq!(ds[0].reason, Reason::HostPressure);
    }

    #[test]
    fn warm_only_evicts_instead() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 0);
        let c = cfg();
        let ds = decide_one(
            &WarmOnlyPolicy,
            &c,
            &pool,
            "w",
            20_000_000,
            &host_budget(&c, 0),
            None,
            &WakeLeads::new(true),
        );
        assert_eq!(
            ds,
            vec![Decision {
                idx: 0,
                verb: Verb::Evict,
                reason: Reason::IdleTimeout
            }]
        );
    }

    #[test]
    fn stale_hibernate_evicted() {
        let (svc, mut pool) = rig();
        let clock = Clock::new();
        let mut s = spawn(&svc, 1);
        s.hibernate(&clock).unwrap();
        pool.add(s, 0);
        let c = cfg();
        // idle 2 s > evict_idle 1 s
        let ds = decide_one(
            &HibernatePolicy,
            &c,
            &pool,
            "w",
            2_000_000_000,
            &host_budget(&c, 0),
            None,
            &WakeLeads::new(true),
        );
        assert_eq!(
            ds,
            vec![Decision {
                idx: 0,
                verb: Verb::Evict,
                reason: Reason::StaleHibernate
            }]
        );
    }

    #[test]
    fn predictor_triggers_wake() {
        let (svc, mut pool) = rig();
        let clock = Clock::new();
        let mut s = spawn(&svc, 1);
        s.hibernate(&clock).unwrap();
        pool.add(s, 0);
        let c = cfg();
        let pred = Predictor::new(0.5);
        pred.observe("w", 0);
        pred.observe("w", 100_000_000); // next expected ≈ 200 ms
        let ds = decide_one(
            &HibernatePolicy,
            &c,
            &pool,
            "w",
            190_000_000,
            &host_budget(&c, 0),
            Some(&pred),
            &WakeLeads::new(true),
        );
        assert!(
            ds.contains(&Decision {
                idx: 0,
                verb: Verb::Wake,
                reason: Reason::AnticipatedArrival
            }),
            "{ds:?}"
        );
    }

    #[test]
    fn tenant_names_parse() {
        assert_eq!(tenant_of("t00-golang-hello-0001"), Some("t00"));
        assert_eq!(tenant_of("t7-x"), Some("t7"));
        assert_eq!(tenant_of("golang-hello"), None);
        assert_eq!(tenant_of("tx-hello"), None);
        assert_eq!(tenant_of("t-hello"), None);
        assert_eq!(tenant_of("t00"), None);
    }

    #[test]
    fn tenant_fair_deflates_only_the_over_budget_tenant_most_idle_first() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 0); // idx 0: idle since 0 (most idle)
        pool.add(spawn(&svc, 2), 400); // idx 1: fresher
        let mut c = cfg();
        c.hibernate_idle_ms = 1_000_000; // idleness unreachable
        let inst_bytes = pool.instances[0].live_bytes();
        assert!(inst_bytes > 0, "cold-started instance must have a charge");
        let tenants = vec![
            TenantEntry {
                name: "t00".into(),
                used: 3 * inst_bytes,
                budget: inst_bytes, // hopelessly over
                shard_used: vec![3 * inst_bytes],
            },
            TenantEntry {
                name: "t01".into(),
                used: inst_bytes,
                budget: 100 * inst_bytes, // comfortably under
                shard_used: vec![inst_bytes],
            },
        ];
        let budget = MemBudget::new(1 << 30, 0, 0.8, &tenants);
        let leads = WakeLeads::new(true);
        // The over-budget tenant's pool: most idle (idx 0) deflates first.
        let ds = decide_one(
            &TenantFairPolicy,
            &c,
            &pool,
            "t00-fn",
            1000,
            &budget,
            None,
            &leads,
        );
        assert!(!ds.is_empty());
        assert_eq!(ds[0].idx, 0, "most idle instance goes first");
        assert!(ds
            .iter()
            .all(|d| d.verb == Verb::Hibernate && d.reason == Reason::TenantPressure));
        // The under-budget tenant is untouched.
        let budget2 = MemBudget::new(1 << 30, 0, 0.8, &tenants);
        let ds = decide_one(
            &TenantFairPolicy,
            &c,
            &pool,
            "t01-fn",
            1000,
            &budget2,
            None,
            &leads,
        );
        assert!(ds.is_empty(), "{ds:?}");
        // And workloads without a tenant prefix behave like plain
        // hibernate (nothing idle, no pressure → nothing).
        let budget3 = MemBudget::new(1 << 30, 0, 0.8, &tenants);
        let ds = decide_one(
            &TenantFairPolicy,
            &c,
            &pool,
            "untenanted",
            1000,
            &budget3,
            None,
            &leads,
        );
        assert!(ds.is_empty(), "{ds:?}");
    }

    #[test]
    fn tenant_fair_stops_once_the_overage_is_covered() {
        let (svc, mut pool) = rig();
        pool.add(spawn(&svc, 1), 0);
        pool.add(spawn(&svc, 2), 100);
        pool.add(spawn(&svc, 3), 200);
        let mut c = cfg();
        c.hibernate_idle_ms = 1_000_000;
        let inst_bytes = pool.instances[0].live_bytes();
        // Over by about one instance: deflating one must satisfy it.
        let used = 3 * inst_bytes;
        let tenants = vec![TenantEntry {
            name: "t00".into(),
            used,
            budget: (used as f64 / 0.8) as u64 - inst_bytes / 2,
            shard_used: vec![used],
        }];
        let budget = MemBudget::new(1 << 30, 0, 0.8, &tenants);
        let ds = decide_one(
            &TenantFairPolicy,
            &c,
            &pool,
            "t00-fn",
            1000,
            &budget,
            None,
            &WakeLeads::new(true),
        );
        assert_eq!(ds.len(), 1, "one instance covers the overage: {ds:?}");
        assert_eq!(ds[0].idx, 0);
        // The ledger now shows the overage paid, so a *second pool* of the
        // same tenant (same MemBudget — one tick scope) decides nothing.
        let ds2 = decide_one(
            &TenantFairPolicy,
            &c,
            &pool,
            "t00-other",
            1000,
            &budget,
            None,
            &WakeLeads::new(true),
        );
        assert!(ds2.is_empty(), "{ds2:?}");
    }

    #[test]
    fn lease_split_is_proportional_with_a_cold_start_floor() {
        // Proportional for busy shards; idle/small shards are floored at
        // half an equal share (1000 / (2×4) = 125) so a mid-epoch cold
        // start there doesn't instantly read as host pressure.
        let leases = BudgetFrame::split_leases(1000, &[300, 100, 0, 600]);
        assert_eq!(leases, vec![300, 125, 125, 600]);
        // Rounding floors, never overshoots, when everyone is above the
        // floor.
        let leases = BudgetFrame::split_leases(1000, &[1, 1, 1]);
        assert_eq!(leases, vec![333, 333, 333]);
        assert!(leases.iter().sum::<u64>() <= 1000);
        // No usage signal → equal split, not zero leases.
        let leases = BudgetFrame::split_leases(900, &[0, 0, 0]);
        assert_eq!(leases, vec![300, 300, 300]);
    }

    fn live(si: usize, committed: u64, tenant_used: Vec<(String, u64)>) -> ShardLive {
        ShardLive {
            si,
            committed,
            tenant_used,
        }
    }

    #[test]
    fn lease_budget_is_sharper_than_the_stale_snapshot() {
        let frame = BudgetFrame {
            host_used: 0,
            shard_committed: vec![800, 200],
            leases: Some(BudgetFrame::split_leases(1000, &[800, 200])),
            tenants: Vec::new(),
        };
        let c = cfg();
        // Shard 0 grew since the frame: its live usage presses against its
        // lease even though the frame's snapshot would not.
        let l0 = live(0, 900, Vec::new());
        let b = frame.mem_budget(0, &c, Some(&l0));
        assert_eq!(b.budget_bytes(), 800);
        assert!(b.pressure());
        // Shard 1 shrank: no pressure against its lease (250 — the
        // proportional 200 lifted to the half-equal-share floor).
        let l1 = live(1, 100, Vec::new());
        let b = frame.mem_budget(1, &c, Some(&l1));
        assert_eq!(b.budget_bytes(), 250);
        assert!(!b.pressure());
        // Leases off: everyone decides against the host budget + snapshot.
        let frame = BudgetFrame {
            host_used: 42,
            shard_committed: vec![800, 200],
            leases: None,
            tenants: Vec::new(),
        };
        let b = frame.mem_budget(0, &c, None);
        assert_eq!(b.budget_bytes(), c.memory_budget);
        assert_eq!(b.used_bytes(), 42);
    }

    #[test]
    fn tenant_overage_is_shard_scoped_against_live_usage() {
        // One tenant, globally over its watermarked cap, usage split
        // 80/20 across two shards at reconciliation.
        let tenants = vec![TenantEntry {
            name: "t00".into(),
            used: 1000,
            budget: 500, // cap = 0.8 × 500 = 400 → globally over by 600
            shard_used: vec![800, 200],
        }];
        // Shard 0 owns 80% of the usage → an 80% share of the cap (320).
        // Its live usage says 700 → it pays down exactly 700 − 320.
        let l0 = live(0, 0, vec![("t00".into(), 700)]);
        let b = MemBudget::with_live(1 << 30, 0, 0.8, &tenants, &l0);
        assert_eq!(b.tenant_overage("t00"), 700 - 320);
        // Shard 1's share is 80; its live usage already dropped to 60
        // (deflations land on the gauges at the flip) → nothing to pay,
        // even though the reconciled global figure is still stale-high.
        let l1 = live(1, 0, vec![("t00".into(), 60)]);
        let b = MemBudget::with_live(1 << 30, 0, 0.8, &tenants, &l1);
        assert_eq!(b.tenant_overage("t00"), 0);
        // A shard the tenant never touched at reconciliation gets a zero
        // cap share: live usage there is all overage (the tenant IS
        // globally over).
        let l2 = live(2, 0, vec![("t00".into(), 50)]);
        let b = MemBudget::with_live(1 << 30, 0, 0.8, &tenants, &l2);
        assert_eq!(b.tenant_overage("t00"), 50);
        // But a *globally under* tenant never pays anywhere, wherever its
        // live bytes sit.
        let under = vec![TenantEntry {
            name: "t01".into(),
            used: 100,
            budget: 500,
            shard_used: vec![0, 100],
        }];
        let l0 = live(0, 0, vec![("t01".into(), 400)]);
        let b = MemBudget::with_live(1 << 30, 0, 0.8, &under, &l0);
        assert_eq!(b.tenant_overage("t01"), 0);
        // The reconciled-state wake gate is global, not shard-scoped.
        assert!(!b.tenant_over_reconciled("t01"));
        let b = MemBudget::with_live(1 << 30, 0, 0.8, &tenants, &l1);
        assert!(b.tenant_over_reconciled("t00"));
    }

    #[test]
    fn empty_scope_is_never_pressured() {
        let b = MemBudget::new(0, 0, 0.8, &[]);
        assert!(!b.pressure(), "zero lease on an empty shard must not press");
    }

    #[test]
    fn resolve_tenants_explicit_budgets_and_weight_shares() {
        let mut c = cfg();
        c.memory_budget = 1000;
        c.tenants = vec![
            TenantBudget {
                name: "t00".into(),
                memory_budget: Some(400),
                weight: 1.0,
            },
            TenantBudget {
                name: "t01".into(),
                memory_budget: None,
                weight: 2.0,
            },
        ];
        c.tenants.sort_by(|a, b| a.name.cmp(&b.name));
        let mut used = BTreeMap::new();
        used.insert("t00".to_string(), vec![500u64, 200]);
        used.insert("t02".to_string(), vec![0u64, 10]); // unconfigured, weight 1.0
        let rows = resolve_tenants(&c, &used);
        assert_eq!(rows.len(), 3);
        assert_eq!(
            rows[0],
            TenantEntry {
                name: "t00".into(),
                used: 700,
                budget: 400,
                shard_used: vec![500, 200]
            }
        );
        // 600 left over, weights 2.0 vs 1.0.
        assert_eq!(
            rows[1],
            TenantEntry {
                name: "t01".into(),
                used: 0,
                budget: 400,
                shard_used: vec![]
            }
        );
        assert_eq!(
            rows[2],
            TenantEntry {
                name: "t02".into(),
                used: 10,
                budget: 200,
                shard_used: vec![0, 10]
            }
        );
    }

    #[test]
    fn wake_leads_seed_learn_and_clamp() {
        let leads = WakeLeads::new(true);
        assert_eq!(leads.lead_ns("f"), WAKE_LEAD_SEED_NS, "pre-sample = seed");
        leads.observe("f", 20_000_000);
        assert_eq!(leads.lead_ns("f"), 20_000_000, "first sample anchors");
        leads.observe("f", 40_000_000);
        let l = leads.lead_ns("f");
        assert!(l > 20_000_000 && l < 40_000_000, "EWMA moves between: {l}");
        // Clamps at both ends.
        let leads = WakeLeads::new(true);
        leads.observe("tiny", 1);
        assert_eq!(leads.lead_ns("tiny"), WAKE_LEAD_MIN_NS);
        let leads = WakeLeads::new(true);
        leads.observe("huge", 10_000_000_000);
        assert_eq!(leads.lead_ns("huge"), WAKE_LEAD_MAX_NS);
        // Non-adaptive: observations are ignored.
        let leads = WakeLeads::new(false);
        leads.observe("f", 1);
        assert_eq!(leads.lead_ns("f"), WAKE_LEAD_SEED_NS);
    }

    #[test]
    fn build_policy_resolves_kinds() {
        let mut c = cfg();
        for (kind, name) in [
            ("hibernate", "hibernate"),
            ("", "hibernate"),
            ("warm-only", "warm-only"),
            ("warm_only", "warm-only"),
            ("tenant-fair", "tenant-fair"),
        ] {
            c.kind = kind.into();
            assert_eq!(build_policy(&c).unwrap().name(), name, "kind `{kind}`");
        }
        c.kind = "nope".into();
        let err = build_policy(&c).unwrap_err();
        assert!(err.to_string().contains("tenant-fair"), "{err}");
    }
}
