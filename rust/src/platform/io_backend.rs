//! Batched, priority-classed I/O backend for the instance pipeline.
//!
//! The paper's wake path lives or dies on how fast deflated memory comes
//! back (§ abstract: a Woken-up Container must approach Warm-Container
//! latency). With synchronous per-instance `pwritev`/`preadv`, a
//! host-pressure deflation storm queues *ahead* of a user-visible wake at
//! the device. This module restructures the file-facing I/O path around an
//! io_uring-style submission/completion model, emulated over a small
//! `preadv`/`pwritev` worker pool (the offline registry has no async
//! runtime):
//!
//! * **Run descriptors, not calls** — [`SlotFile`](crate::swap::file)
//!   plans sorted, coalesced [`IoRun`]s and submits them through an
//!   [`IoBackend`] instead of issuing syscalls itself.
//! * **Latency classes** — every submission carries an [`IoClass`].
//!   Wake-path reads ([`IoClass::Latency`]) have strict priority over
//!   deflation/teardown writes ([`IoClass::Throughput`]): workers always
//!   drain the latency queue first.
//! * **Bounded batches** — throughput submissions are chopped at
//!   `io.batch_pages` boundaries, so a storm can never delay a wake by
//!   more than one bounded batch: the wake overtakes at the next chunk
//!   boundary (counted in
//!   [`IoStats::priority_bypasses`](crate::platform::metrics::IoStats)).
//! * **In-flight byte budget** — throughput *admission* waits while
//!   `inflight + chunk > io.max_inflight_bytes` (and something is in
//!   flight — a solo chunk always proceeds, so an oversized submission
//!   degrades to serial rather than deadlocking). Latency work is never
//!   throttled. Budget is acquired by the submitting thread, never by a
//!   pool worker, so workers are always free to serve a wake.
//!
//! Cross-instance batching: every sandbox's [`SwapFileSet`]
//! (crate::swap::SwapFileSet) shares the platform's one backend, so a
//! storm of deflations from many instances interleaves through the same
//! two queues and worker pool — coalescing stays per backing file (an
//! iovec syscall is per-fd), scheduling is global.
//!
//! # Determinism
//!
//! [`IoBackend::execute`] *blocks until every run completes* and returns
//! the same total-bytes result for any worker interleaving (runs address
//! disjoint file regions). Virtual-time charges are derived from those
//! byte counts by the cost model, never from wall time, and the
//! scheduling-dependent [`IoStats`](crate::platform::metrics::IoStats)
//! counters are excluded from the replay fingerprint — so `backend =
//! batched` joins the 1-vs-N bit-identity contract via the existing
//! drain-after-every-tick-batch barrier, and its fingerprints equal
//! `backend = sync` on the same scenario/seed (see `docs/io_backend.md`).

use crate::obs::{ARG_FLAG, EventKind, Recorder};
use crate::platform::metrics::IoStats;
use crate::PAGE_SIZE;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::fs::File;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Latency class of a submission — the scheduling contract.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum IoClass {
    /// Wake-path work (REAP prefetch read): strict priority, never
    /// throttled by the in-flight budget, submitted as one whole batch.
    Latency,
    /// Deflation/teardown work: yields at `batch_pages` boundaries and
    /// waits for in-flight budget before each chunk.
    Throughput,
}

/// Marker type attached (via the `anyhow` error chain) to I/O failures
/// that are worth retrying: the syscall was interrupted or the device was
/// momentarily busy, and an identical resubmission may well succeed.
/// Everything else — EOF, short transfers, checksum mismatches, `EIO` — is
/// *permanent*: retrying cannot help and the caller must degrade instead
/// (see `docs/durability.md` for the taxonomy).
///
/// Callers test for the marker with [`is_transient`]; failure-injection
/// backends attach it themselves to model flaky-but-recoverable devices.
#[derive(Debug, Clone, Copy)]
pub struct TransientIo;

impl std::fmt::Display for TransientIo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "transient I/O error")
    }
}

impl std::error::Error for TransientIo {}

/// Does `err`'s chain carry the [`TransientIo`] marker — i.e. is a bounded
/// retry with backoff worth attempting?
pub fn is_transient(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<TransientIo>().is_some())
}

/// Wrap a failed syscall's OS error into an `anyhow` error carrying `msg`,
/// attaching the [`TransientIo`] marker when the error kind is one an
/// immediate retry can plausibly clear. The rendered message is unchanged
/// either way, so existing error-string assertions keep holding.
pub fn classify_os_error(os: std::io::Error, msg: String) -> anyhow::Error {
    use std::io::ErrorKind;
    let transient = matches!(
        os.kind(),
        ErrorKind::Interrupted | ErrorKind::WouldBlock | ErrorKind::TimedOut
    );
    if transient {
        anyhow::Error::new(TransientIo).context(msg)
    } else {
        anyhow::anyhow!(msg)
    }
}

/// Direction of a vectored transfer.
#[derive(Copy, Clone, Debug)]
pub enum IoDir {
    Write,
    Read,
}

impl IoDir {
    fn verb(self) -> &'static str {
        match self {
            IoDir::Write => "pwritev",
            IoDir::Read => "preadv",
        }
    }
}

/// Raw page-buffer pointer, made sendable so runs can cross into the
/// worker pool.
///
/// SAFETY contract (upheld by every submitter): the pointer addresses one
/// exclusive page-sized buffer that stays valid and unaliased until the
/// blocking [`IoBackend::execute`] call returns — submitters hold the
/// owning sandbox's lock (or own the buffers outright) across the call.
/// For reads the buffer is writable; `*const` is only a unified carrier.
#[derive(Copy, Clone)]
pub struct PagePtr(pub *const u8);

// SAFETY: per the contract above, the pointee is exclusive, valid, and
// unaliased for the duration of the blocking execute call, so handing the
// pointer to a worker thread cannot race.
unsafe impl Send for PagePtr {}
unsafe impl Sync for PagePtr {}

/// One coalesced run: `pages.len()` page buffers bound for the contiguous
/// file byte range starting at `offset`.
pub struct IoRun {
    pub offset: u64,
    pub pages: Vec<PagePtr>,
}

impl IoRun {
    pub fn bytes(&self) -> u64 {
        (self.pages.len() * PAGE_SIZE) as u64
    }
}

/// Sort `(offset, page)` items and coalesce contiguous offsets into
/// [`IoRun`]s — the planning half of what `coalesced_io` used to do
/// inline. Pure; performs no I/O.
pub fn plan_runs(mut items: Vec<(u64, PagePtr)>) -> Vec<IoRun> {
    items.sort_unstable_by_key(|&(off, _)| off);
    let mut runs = Vec::new();
    let mut i = 0usize;
    while i < items.len() {
        let mut end = i + 1;
        while end < items.len() && items[end].0 == items[end - 1].0 + PAGE_SIZE as u64 {
            end += 1;
        }
        runs.push(IoRun {
            offset: items[i].0,
            pages: items[i..end].iter().map(|&(_, p)| p).collect(),
        });
        i = end;
    }
    runs
}

/// Execute one run against `file` (≤ 1024 iovecs per syscall — §Perf #1).
/// The executing half of the old `coalesced_io`, error strings included.
pub fn execute_run(file: &File, run: &IoRun, dir: IoDir) -> Result<u64> {
    let iovs: Vec<libc::iovec> = run
        .pages
        .iter()
        .map(|p| libc::iovec {
            iov_base: p.0 as *mut libc::c_void,
            iov_len: PAGE_SIZE,
        })
        .collect();
    let base = run.offset;
    let mut done = 0u64;
    let mut iov_idx = 0usize;
    while iov_idx < iovs.len() {
        let batch = &iovs[iov_idx..(iov_idx + 1024).min(iovs.len())];
        // SAFETY: iovecs point into exclusive page buffers the submitter
        // keeps alive across the blocking execute (see `PagePtr`).
        let n = unsafe {
            match dir {
                IoDir::Write => libc::pwritev(
                    file.as_raw_fd(),
                    batch.as_ptr(),
                    batch.len() as libc::c_int,
                    (base + done) as libc::off_t,
                ),
                IoDir::Read => libc::preadv(
                    file.as_raw_fd(),
                    batch.as_ptr(),
                    batch.len() as libc::c_int,
                    (base + done) as libc::off_t,
                ),
            }
        };
        if n < 0 {
            let os = std::io::Error::last_os_error();
            let msg = format!("{} failed: {os}", dir.verb());
            return Err(classify_os_error(os, msg));
        }
        if n == 0 {
            bail!("vectored I/O hit EOF (offset {})", base + done);
        }
        if n as usize % PAGE_SIZE != 0 {
            bail!("short vectored I/O not page-multiple: {n}");
        }
        done += n as u64;
        iov_idx += n as usize / PAGE_SIZE;
    }
    Ok(done)
}

/// The pluggable backend the pipeline's slot-run I/O goes through.
///
/// `execute` submits planned runs against one backing file and **blocks
/// until all of them complete**, returning total bytes moved (or the
/// first error; other runs of the submission may still have executed —
/// exactly the partial-completion surface the old sequential loop had).
pub trait IoBackend: Send + Sync {
    fn execute(&self, file: &Arc<File>, runs: Vec<IoRun>, dir: IoDir, class: IoClass)
        -> Result<u64>;

    /// Config name: `sync` or `batched`.
    fn name(&self) -> &'static str;

    /// The stats block this backend reports into.
    fn stats(&self) -> &Arc<IoStats>;
}

fn note_submission(stats: &IoStats, runs: &[IoRun]) {
    stats.submissions.fetch_add(1, Ordering::Relaxed);
    stats.runs_submitted.fetch_add(runs.len() as u64, Ordering::Relaxed);
    let pages: u64 = runs.iter().map(|r| r.pages.len() as u64).sum();
    stats.pages_submitted.fetch_add(pages, Ordering::Relaxed);
}

/// Emit one `io_submit`/`io_complete` instant on the recorder's global
/// ring: `arg` packs the byte count with the latency-class flag
/// ([`ARG_FLAG`] set ⇔ [`IoClass::Latency`]). The hint is 0 — backend
/// scheduling has no virtual timestamp, so under the replay clock these
/// stamp t = 0 and sort purely by content (still deterministic; see
/// `docs/observability.md`).
fn trace_io(rec: &Recorder, kind: EventKind, bytes: u64, class: IoClass) {
    if rec.is_enabled() {
        let flag = if class == IoClass::Latency { ARG_FLAG } else { 0 };
        rec.emit(rec.global_ring(), kind, 0, 0, bytes | flag, 0);
    }
}

/// `backend = sync`: executes runs inline on the submitting thread, in
/// sorted order — byte-for-byte the pre-backend behavior (same syscall
/// sequence, same error strings), so existing baselines and replay
/// fingerprints stay meaningful.
pub struct SyncBackend {
    stats: Arc<IoStats>,
    recorder: Arc<Recorder>,
}

impl SyncBackend {
    pub fn new() -> Self {
        Self::with_stats(Arc::new(IoStats::default()))
    }

    /// Report into an existing stats block (the platform passes
    /// `Metrics::io` so backend activity lands in the metrics report).
    pub fn with_stats(stats: Arc<IoStats>) -> Self {
        Self::with_observability(stats, Recorder::disabled())
    }

    /// Full observability hookup: stats block plus the platform's flight
    /// recorder (submit/complete instants on the global `io` ring).
    pub fn with_observability(stats: Arc<IoStats>, recorder: Arc<Recorder>) -> Self {
        Self { stats, recorder }
    }
}

impl Default for SyncBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl IoBackend for SyncBackend {
    fn execute(
        &self,
        file: &Arc<File>,
        runs: Vec<IoRun>,
        dir: IoDir,
        class: IoClass,
    ) -> Result<u64> {
        if runs.is_empty() {
            return Ok(0);
        }
        note_submission(&self.stats, &runs);
        let submitted: u64 = runs.iter().map(|r| r.bytes()).sum();
        trace_io(&self.recorder, EventKind::IoSubmit, submitted, class);
        let mut total = 0u64;
        for run in &runs {
            self.stats.inflight_add(run.bytes());
            let res = execute_run(file, run, dir);
            self.stats.inflight_sub(run.bytes());
            total += res?;
        }
        trace_io(&self.recorder, EventKind::IoComplete, total, class);
        Ok(total)
    }

    fn name(&self) -> &'static str {
        "sync"
    }

    fn stats(&self) -> &Arc<IoStats> {
        &self.stats
    }
}

/// One enqueued chunk: a bounded slice of a submission, bound for one
/// backing file, carrying its completion handle.
struct Chunk {
    file: Arc<File>,
    runs: Vec<IoRun>,
    dir: IoDir,
    bytes: u64,
    done: Arc<Completion>,
}

#[derive(Default)]
struct CompletionState {
    remaining: usize,
    bytes: u64,
    error: Option<anyhow::Error>,
}

struct Completion {
    state: Mutex<CompletionState>,
    cv: Condvar,
}

struct QueueState {
    latency: VecDeque<Chunk>,
    throughput: VecDeque<Chunk>,
    /// Bytes admitted (queued or executing). Mirrored into the stats gauge.
    inflight_bytes: u64,
    closed: bool,
}

struct BackendShared {
    state: Mutex<QueueState>,
    /// Workers wait here for submissions.
    work: Condvar,
    /// Throughput submitters wait here for in-flight budget.
    budget: Condvar,
    max_inflight_bytes: u64,
    stats: Arc<IoStats>,
    recorder: Arc<Recorder>,
}

/// `backend = batched`: a two-queue worker pool with strict latency
/// priority, bounded throughput chunks, and an in-flight byte budget (see
/// the module docs for the scheduling contract).
pub struct BatchedBackend {
    shared: Arc<BackendShared>,
    batch_pages: usize,
    workers: Vec<JoinHandle<()>>,
}

impl BatchedBackend {
    pub fn new(
        workers: usize,
        max_inflight_bytes: u64,
        batch_pages: usize,
        stats: Arc<IoStats>,
    ) -> Self {
        Self::with_observability(
            workers,
            max_inflight_bytes,
            batch_pages,
            stats,
            Recorder::disabled(),
        )
    }

    /// Full observability hookup: stats block plus the platform's flight
    /// recorder (submit/complete instants on the global `io` ring).
    pub fn with_observability(
        workers: usize,
        max_inflight_bytes: u64,
        batch_pages: usize,
        stats: Arc<IoStats>,
        recorder: Arc<Recorder>,
    ) -> Self {
        let shared = Arc::new(BackendShared {
            state: Mutex::new(QueueState {
                latency: VecDeque::new(),
                throughput: VecDeque::new(),
                inflight_bytes: 0,
                closed: false,
            }),
            work: Condvar::new(),
            budget: Condvar::new(),
            max_inflight_bytes: max_inflight_bytes.max(PAGE_SIZE as u64),
            stats,
            recorder,
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(shared))
            })
            .collect();
        Self {
            shared,
            batch_pages: batch_pages.max(1),
            workers: handles,
        }
    }

    /// Split a throughput submission into chunks of ≤ `batch_pages` pages,
    /// cutting runs mid-way where needed — every cut is a point where a
    /// queued wake may overtake.
    fn chop(&self, runs: Vec<IoRun>) -> Vec<Vec<IoRun>> {
        let cap = self.batch_pages;
        let mut out: Vec<Vec<IoRun>> = Vec::new();
        let mut cur: Vec<IoRun> = Vec::new();
        let mut cur_pages = 0usize;
        for mut run in runs {
            loop {
                let room = cap - cur_pages;
                if run.pages.len() <= room {
                    cur_pages += run.pages.len();
                    if !run.pages.is_empty() {
                        cur.push(run);
                    }
                    break;
                }
                if room == 0 {
                    out.push(std::mem::take(&mut cur));
                    cur_pages = 0;
                    continue;
                }
                let tail = run.pages.split_off(room);
                let tail_run = IoRun {
                    offset: run.offset + (room * PAGE_SIZE) as u64,
                    pages: tail,
                };
                cur.push(run);
                out.push(std::mem::take(&mut cur));
                cur_pages = 0;
                run = tail_run;
            }
        }
        if !cur.is_empty() {
            out.push(cur);
        }
        out
    }
}

impl IoBackend for BatchedBackend {
    fn execute(
        &self,
        file: &Arc<File>,
        runs: Vec<IoRun>,
        dir: IoDir,
        class: IoClass,
    ) -> Result<u64> {
        if runs.is_empty() {
            return Ok(0);
        }
        note_submission(&self.shared.stats, &runs);
        let submitted: u64 = runs.iter().map(|r| r.bytes()).sum();
        trace_io(&self.shared.recorder, EventKind::IoSubmit, submitted, class);
        let chunks: Vec<Vec<IoRun>> = match class {
            IoClass::Latency => vec![runs],
            IoClass::Throughput => self.chop(runs),
        };
        if chunks.len() > 1 {
            self.shared
                .stats
                .throughput_yields
                .fetch_add(chunks.len() as u64 - 1, Ordering::Relaxed);
        }
        let done = Arc::new(Completion {
            state: Mutex::new(CompletionState {
                remaining: chunks.len(),
                ..CompletionState::default()
            }),
            cv: Condvar::new(),
        });
        for part in chunks {
            let bytes: u64 = part.iter().map(|r| r.bytes()).sum();
            let mut st = self.shared.state.lock().unwrap();
            if matches!(class, IoClass::Throughput) {
                // Admission control on the *submitting* thread: a worker
                // never blocks on budget, so one is always free for a
                // wake. `inflight > 0` keeps a solo oversized chunk from
                // deadlocking — it degrades to serial instead.
                while st.inflight_bytes > 0
                    && st.inflight_bytes + bytes > self.shared.max_inflight_bytes
                {
                    st = self.shared.budget.wait(st).unwrap();
                }
            }
            st.inflight_bytes += bytes;
            self.shared.stats.inflight_add(bytes);
            let chunk = Chunk {
                file: file.clone(),
                runs: part,
                dir,
                bytes,
                done: done.clone(),
            };
            match class {
                IoClass::Latency => st.latency.push_back(chunk),
                IoClass::Throughput => st.throughput.push_back(chunk),
            }
            drop(st);
            self.shared.work.notify_one();
        }
        let mut st = done.state.lock().unwrap();
        while st.remaining > 0 {
            st = done.cv.wait(st).unwrap();
        }
        match st.error.take() {
            Some(e) => Err(e),
            None => {
                trace_io(&self.shared.recorder, EventKind::IoComplete, st.bytes, class);
                Ok(st.bytes)
            }
        }
    }

    fn name(&self) -> &'static str {
        "batched"
    }

    fn stats(&self) -> &Arc<IoStats> {
        &self.shared.stats
    }
}

impl Drop for BatchedBackend {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
        }
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<BackendShared>) {
    loop {
        let chunk = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(c) = st.latency.pop_front() {
                    if !st.throughput.is_empty() {
                        // A wake overtook queued deflation work.
                        shared.stats.priority_bypasses.fetch_add(1, Ordering::Relaxed);
                    }
                    break Some(c);
                }
                if let Some(c) = st.throughput.pop_front() {
                    break Some(c);
                }
                if st.closed {
                    // Queues are drained (nothing popped above): exit.
                    break None;
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        let Some(chunk) = chunk else { return };
        let mut moved = 0u64;
        let mut err: Option<anyhow::Error> = None;
        for run in &chunk.runs {
            match execute_run(&chunk.file, run, chunk.dir) {
                Ok(n) => moved += n,
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        // Release budget before completing, so a budget-blocked submitter
        // can admit its next chunk the moment capacity frees up.
        {
            let mut st = shared.state.lock().unwrap();
            st.inflight_bytes -= chunk.bytes;
            shared.stats.inflight_sub(chunk.bytes);
        }
        shared.budget.notify_all();
        let mut done = chunk.done.state.lock().unwrap();
        done.remaining -= 1;
        done.bytes += moved;
        if done.error.is_none() {
            done.error = err;
        }
        let finished = done.remaining == 0;
        drop(done);
        if finished {
            chunk.done.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> (PathBuf, Arc<File>) {
        let path = std::env::temp_dir().join(format!(
            "qh-iobackend-{tag}-{}",
            std::process::id()
        ));
        let f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        (path, Arc::new(f))
    }

    fn pages(n: usize, seed: u8) -> Vec<Vec<u8>> {
        (0..n)
            .map(|i| vec![seed.wrapping_add(i as u8); PAGE_SIZE])
            .collect()
    }

    fn items(bufs: &[Vec<u8>], offsets: impl Iterator<Item = u64>) -> Vec<(u64, PagePtr)> {
        offsets
            .zip(bufs)
            .map(|(off, b)| (off, PagePtr(b.as_ptr())))
            .collect()
    }

    #[test]
    fn plan_runs_sorts_and_coalesces() {
        let bufs = pages(5, 1);
        // Offsets 0,1,2 contiguous (submitted shuffled), then a gap, then 5,6.
        let offs = [2u64, 0, 5, 1, 6].map(|o| o * PAGE_SIZE as u64);
        let runs = plan_runs(items(&bufs, offs.into_iter()));
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].offset, 0);
        assert_eq!(runs[0].pages.len(), 3);
        assert_eq!(runs[1].offset, 5 * PAGE_SIZE as u64);
        assert_eq!(runs[1].pages.len(), 2);
        assert_eq!(runs[0].bytes(), 3 * PAGE_SIZE as u64);
    }

    fn roundtrip(backend: &dyn IoBackend, tag: &str, n: usize) {
        let (path, file) = tmpfile(tag);
        let bufs = pages(n, 7);
        let runs = plan_runs(items(&bufs, (0..n as u64).map(|i| i * PAGE_SIZE as u64)));
        let written = backend
            .execute(&file, runs, IoDir::Write, IoClass::Throughput)
            .unwrap();
        assert_eq!(written, (n * PAGE_SIZE) as u64);
        let mut out = vec![vec![0u8; PAGE_SIZE]; n];
        let read_runs = plan_runs(
            out.iter_mut()
                .enumerate()
                .map(|(i, b)| ((i * PAGE_SIZE) as u64, PagePtr(b.as_mut_ptr() as *const u8)))
                .collect(),
        );
        let read = backend
            .execute(&file, read_runs, IoDir::Read, IoClass::Latency)
            .unwrap();
        assert_eq!(read, written);
        assert_eq!(out, bufs);
        assert_eq!(
            backend.stats().inflight_bytes.load(Ordering::Relaxed),
            0,
            "gauge must settle to zero when idle"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn sync_backend_roundtrips() {
        roundtrip(&SyncBackend::new(), "sync", 300);
    }

    #[test]
    fn batched_backend_roundtrips_across_chunk_boundaries() {
        // batch_pages 64 with 300 pages forces multiple chunks (and
        // concurrent workers) on the write side.
        let b = BatchedBackend::new(3, 1 << 20, 64, Arc::new(IoStats::default()));
        roundtrip(&b, "batched", 300);
        assert!(
            b.stats().throughput_yields.load(Ordering::Relaxed) >= 4,
            "300 pages at batch_pages=64 must yield at ≥ 4 boundaries"
        );
    }

    #[test]
    fn batched_solo_oversized_chunk_proceeds_without_deadlock() {
        // Budget smaller than one chunk: the solo clause (inflight == 0)
        // must let it through serially instead of deadlocking.
        let b = BatchedBackend::new(1, PAGE_SIZE as u64, 8, Arc::new(IoStats::default()));
        roundtrip(&b, "tinybudget", 40);
    }

    #[test]
    fn batched_read_of_unwritten_region_surfaces_eof() {
        let b = BatchedBackend::new(2, 1 << 20, 64, Arc::new(IoStats::default()));
        let (path, file) = tmpfile("eof");
        let mut buf = vec![0u8; PAGE_SIZE];
        let runs = vec![IoRun {
            offset: 0,
            pages: vec![PagePtr(buf.as_mut_ptr() as *const u8)],
        }];
        let err = b
            .execute(&file, runs, IoDir::Read, IoClass::Latency)
            .unwrap_err();
        assert!(format!("{err:#}").contains("EOF"), "{err:#}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn chop_respects_batch_pages_and_preserves_offsets() {
        let b = BatchedBackend::new(1, 1 << 30, 4, Arc::new(IoStats::default()));
        let bufs = pages(10, 3);
        let runs = plan_runs(items(&bufs, (0..10u64).map(|i| i * PAGE_SIZE as u64)));
        assert_eq!(runs.len(), 1, "contiguous input is one run");
        let chunks = b.chop(runs);
        assert_eq!(chunks.len(), 3, "10 pages / batch 4 → 3 chunks");
        let mut expect_off = 0u64;
        let mut total_pages = 0usize;
        for chunk in &chunks {
            let chunk_pages: usize = chunk.iter().map(|r| r.pages.len()).sum();
            assert!(chunk_pages <= 4, "chunk exceeds batch_pages");
            for r in chunk {
                assert_eq!(r.offset, expect_off, "split must keep file offsets");
                expect_off += r.bytes();
            }
            total_pages += chunk_pages;
        }
        assert_eq!(total_pages, 10, "no page lost in the split");
    }

    #[test]
    fn transient_classification_follows_os_error_kind() {
        let interrupted = std::io::Error::from(std::io::ErrorKind::Interrupted);
        let e = classify_os_error(interrupted, "pwritev failed: interrupted".into());
        assert!(is_transient(&e), "EINTR must classify transient: {e:#}");
        assert!(
            format!("{e:#}").contains("pwritev failed"),
            "classification must not eat the message: {e:#}"
        );

        let denied = std::io::Error::from(std::io::ErrorKind::PermissionDenied);
        let e = classify_os_error(denied, "pread failed: denied".into());
        assert!(!is_transient(&e), "EACCES must classify permanent");

        // EOF and short-transfer errors built via bail! carry no marker.
        let eof = anyhow::anyhow!("vectored I/O hit EOF (offset 0)");
        assert!(!is_transient(&eof));
    }

    #[test]
    fn empty_submission_is_a_noop() {
        let b = BatchedBackend::new(1, 1 << 20, 8, Arc::new(IoStats::default()));
        let (path, file) = tmpfile("empty");
        assert_eq!(
            b.execute(&file, Vec::new(), IoDir::Write, IoClass::Throughput)
                .unwrap(),
            0
        );
        assert_eq!(b.stats().submissions.load(Ordering::Relaxed), 0);
        std::fs::remove_file(path).ok();
    }
}
