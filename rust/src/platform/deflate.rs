//! The off-lock deflation pipeline: a small worker pool that runs the
//! expensive half of hibernation ([`Sandbox::hibernate_finish`] — the
//! delta swap-out, file-page release and madvise passes) *off* the policy
//! tick, holding only the instance's own mutex.
//!
//! The split: the policy tick performs the cheap SIGSTOP state flip under
//! the shard lock (so the router immediately stops preferring the
//! instance), then submits a [`DeflateJob`] carrying the sandbox handle
//! and — crucially — the instance's RAII [`Reservation`]. The reservation
//! is what makes the pipeline safe: routing and policy both skip reserved
//! instances, so no request or eviction can race the in-flight deflation,
//! and it is released (dropped) only after the finish completes, at which
//! point the instance is a fully-deflated, routable `Hibernate` container.
//!
//! Ordering contract for determinism: a worker (1) folds the swap counters
//! into the shared [`Metrics`], (2) drops the reservation, and only then
//! (3) decrements the pending gauge. [`DeflationPool::drain`] therefore
//! guarantees that once pending hits zero, every deflated instance is
//! visible, unreserved, and fully accounted — which is what lets the
//! replay engine drain after each tick and stay bit-identical at any
//! worker count ([`crate::replay`]).
//!
//! Errors from a finish are stashed and surface at the next
//! [`DeflationPool::reap`]/[`DeflationPool::drain`] (i.e. the next policy
//! tick), mirroring how an async kernel writeback error surfaces later.

use super::metrics::Metrics;
use super::pool::Reservation;
use crate::container::sandbox::Sandbox;
use crate::simtime::Clock;
use anyhow::{Context as _, Result};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A deflation handed to the pool: the state flip already happened; the
/// reservation rides along and is released when the finish completes.
pub struct DeflateJob {
    pub workload: String,
    pub sandbox: Arc<Mutex<Sandbox>>,
    pub reservation: Reservation,
}

/// Test-only hook invoked by a worker before it starts a finish — lets a
/// stress test hold a deflation in flight deterministically.
pub type DeflateGate = Arc<dyn Fn() + Send + Sync>;

#[derive(Default)]
struct PoolState {
    /// Jobs queued or running.
    pending: usize,
    /// Finishes completed since the last reap.
    completed: u64,
    /// Errors collected since the last reap.
    errors: Vec<anyhow::Error>,
}

struct Shared {
    state: Mutex<PoolState>,
    idle: Condvar,
    metrics: Arc<Metrics>,
    gate: Mutex<Option<DeflateGate>>,
}

/// The deflation worker pool. With zero workers it is a pass-through:
/// [`DeflationPool::run_sync`] executes the finish inline (the baseline
/// the benches compare against).
pub struct DeflationPool {
    tx: Option<mpsc::Sender<DeflateJob>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl DeflationPool {
    pub fn new(workers: usize, metrics: Arc<Metrics>) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            idle: Condvar::new(),
            metrics,
            gate: Mutex::new(None),
        });
        if workers == 0 {
            return Self {
                tx: None,
                workers: Vec::new(),
                shared,
            };
        }
        let (tx, rx) = mpsc::channel::<DeflateJob>();
        // Deflations are low-rate (policy cadence), so a shared receiver
        // is fine here — contention is on job *arrival*, execution runs in
        // parallel once a worker holds its job.
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = rx.clone();
                let shared = shared.clone();
                std::thread::spawn(move || loop {
                    let job = match rx.lock().unwrap().recv() {
                        Ok(job) => job,
                        Err(_) => return, // channel closed: pool dropping
                    };
                    run_job(&shared, job);
                })
            })
            .collect();
        Self {
            tx: Some(tx),
            workers: handles,
            shared,
        }
    }

    /// Does this pool actually run deflations asynchronously?
    pub fn is_async(&self) -> bool {
        self.tx.is_some()
    }

    /// Queue a deflation. The pending gauge is bumped *before* the send so
    /// a concurrent [`Self::drain`] can never miss the job.
    pub fn submit(&self, job: DeflateJob) {
        let tx = self.tx.as_ref().expect("submit on a synchronous pool");
        self.shared.state.lock().unwrap().pending += 1;
        if let Err(mpsc::SendError(job)) = tx.send(job) {
            // Workers are only gone while the pool is being torn down;
            // finish inline rather than losing the deflation.
            run_job(&self.shared, job);
        }
    }

    /// Synchronous fallback (`deflate_workers = 0`): run the finish inline
    /// on the caller's thread. Same accounting, no queue.
    pub fn run_sync(&self, job: DeflateJob) -> Result<()> {
        let DeflateJob {
            workload,
            sandbox,
            reservation,
        } = job;
        let result = finish_one(&self.shared.metrics, &workload, &sandbox);
        drop(reservation);
        result
    }

    /// Jobs queued or in flight right now.
    pub fn pending(&self) -> usize {
        self.shared.state.lock().unwrap().pending
    }

    /// Non-blocking: collect completions since the last reap. All stashed
    /// errors are logged; the first is returned (annotated with how many
    /// more there were, so a batch of failures is never mistaken for a
    /// single one). Returns the number reaped on success.
    pub fn reap(&self) -> Result<u64> {
        let mut st = self.shared.state.lock().unwrap();
        let n = st.completed;
        st.completed = 0;
        let mut errors = std::mem::take(&mut st.errors);
        drop(st);
        if errors.is_empty() {
            return Ok(n);
        }
        for e in errors.iter().skip(1) {
            eprintln!("deflation error (additional): {e:#}");
        }
        let count = errors.len();
        let first = errors.swap_remove(0);
        Err(if count > 1 {
            first.context(format!(
                "plus {} more deflation error(s), logged to stderr",
                count - 1
            ))
        } else {
            first
        })
    }

    /// Block until every queued/in-flight deflation has completed, then
    /// reap. After this returns Ok, every submitted instance is deflated,
    /// unreserved and folded into the metrics.
    pub fn drain(&self) -> Result<u64> {
        let mut st = self.shared.state.lock().unwrap();
        while st.pending > 0 {
            st = self.shared.idle.wait(st).unwrap();
        }
        drop(st);
        self.reap()
    }

    /// Install (or clear) the test gate — see [`DeflateGate`].
    #[doc(hidden)]
    pub fn set_gate(&self, gate: Option<DeflateGate>) {
        *self.shared.gate.lock().unwrap() = gate;
    }
}

impl Drop for DeflationPool {
    fn drop(&mut self) {
        // Closing the channel lets each worker finish its backlog and exit
        // on Disconnected; joining guarantees no job outlives the pool.
        self.tx = None;
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn run_job(shared: &Shared, job: DeflateJob) {
    let gate = shared.gate.lock().unwrap().clone();
    if let Some(gate) = gate {
        gate();
    }
    let DeflateJob {
        workload,
        sandbox,
        reservation,
    } = job;
    let result = finish_one(&shared.metrics, &workload, &sandbox);
    // Release the instance before announcing completion: a drainer must
    // observe the deflated instance as routable the moment pending drops.
    drop(reservation);
    let mut st = shared.state.lock().unwrap();
    st.pending -= 1;
    st.completed += 1;
    if let Err(e) = result {
        st.errors.push(e);
    }
    drop(st);
    shared.idle.notify_all();
}

/// Run one [`Sandbox::hibernate_finish`] and fold its swap counters into
/// the metrics. Used by both the async workers and the sync fallback, so
/// the two modes are observationally identical.
pub(super) fn finish_one(
    metrics: &Metrics,
    workload: &str,
    sandbox: &Arc<Mutex<Sandbox>>,
) -> Result<()> {
    // Deflation's charged time belongs to no request — it runs on the
    // platform's dime, like kernel writeback.
    let clock = Clock::new();
    let mut sb = sandbox.lock().unwrap();
    let before = sb.swap_stats();
    sb.hibernate_finish(&clock)
        .with_context(|| format!("deflating an instance of `{workload}`"))?;
    let after = sb.swap_stats();
    if after.reap_swapouts > before.reap_swapouts {
        metrics
            .counters
            .reap_hibernations
            .fetch_add(1, Ordering::Relaxed);
    }
    metrics.counters.pages_swapped_out.fetch_add(
        (after.pages_swapped_out + after.reap_pages_out)
            - (before.pages_swapped_out + before.reap_pages_out),
        Ordering::Relaxed,
    );
    Ok(())
}
