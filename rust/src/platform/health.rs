//! Per-function health: a circuit breaker over request outcomes.
//!
//! Each function carries a sliding window of its last
//! `resilience.breaker_window` request outcomes. When
//! `resilience.breaker_failures` of them are failures the breaker
//! **opens**: the function is quarantined for `resilience.quarantine_ms`
//! *virtual* milliseconds — requests are rejected with a typed
//! [`Quarantined`] error and the policy layer stops spending anticipatory
//! wakes on it. When the quarantine expires the breaker goes **half-open**
//! and admits probe requests; `resilience.probe_successes` consecutive
//! probe successes close it again, a single probe failure re-opens it for
//! another quarantine period.
//!
//! ## Determinism
//!
//! All timing is virtual (`now_vns` from the replay clock), and each
//! function's breaker is only ever touched from the replay worker that
//! owns its control-plane shard — the same serialization argument the
//! chaos plan rests on ([`crate::replay::chaos`]) — so breaker
//! transitions are bit-identical at any worker count. The counters these
//! transitions feed live in
//! [`ResilienceStats`](super::metrics::ResilienceStats), outside the
//! replay fingerprint.

use crate::config::ResilienceConfig;
use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

/// Typed reject for a quarantined function: the breaker is open.
#[derive(Debug)]
pub struct Quarantined {
    pub workload: String,
    /// Virtual nanosecond at which the quarantine expires.
    pub until_ns: u64,
}

impl std::fmt::Display for Quarantined {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "workload {} is quarantined (circuit open until t={}ns)",
            self.workload, self.until_ns
        )
    }
}

impl std::error::Error for Quarantined {}

/// Typed reject for a queued request that outlived its deadline before a
/// server worker could serve it.
#[derive(Debug)]
pub struct TimedOut {
    pub workload: String,
    /// How long the submission waited before being shed (wall ns).
    pub waited_ns: u64,
}

impl std::fmt::Display for TimedOut {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "request for {} timed out in queue after {} ns",
            self.workload, self.waited_ns
        )
    }
}

impl std::error::Error for TimedOut {}

/// What [`HealthRegistry::admit`] decided for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Breaker closed: serve normally.
    Allow,
    /// Breaker half-open: serve as a probe. `entered` is true when this
    /// admission performed the open → half-open transition (emit the
    /// half-open event exactly once).
    Probe { entered: bool },
    /// Breaker open: reject with [`Quarantined`].
    Reject { until_ns: u64 },
}

/// A state-machine transition [`HealthRegistry::record`] performed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    /// The breaker opened (or re-opened from half-open): quarantined.
    Opened { until_ns: u64 },
    /// The breaker closed: the function is healthy again.
    Closed,
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum BreakerState {
    Closed,
    Open { until_ns: u64 },
    HalfOpen { successes: u64 },
}

#[derive(Debug)]
struct FnHealth {
    /// Last `breaker_window` outcomes, `true` = success.
    window: VecDeque<bool>,
    state: BreakerState,
}

/// Sharded-by-nothing registry: one mutex over the per-function map. The
/// map is touched once per request outcome — far off any inner loop — and
/// each key's state is only advanced from one replay worker (see the
/// module docs), so the lock serializes nothing that wasn't already
/// serial.
pub struct HealthRegistry {
    cfg: ResilienceConfig,
    funcs: Mutex<HashMap<String, FnHealth>>,
}

impl HealthRegistry {
    pub fn new(cfg: &ResilienceConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            funcs: Mutex::new(HashMap::new()),
        }
    }

    /// Breaker active at all? (`breaker_failures = 0` disables it.)
    pub fn enabled(&self) -> bool {
        self.cfg.breaker_failures > 0
    }

    /// Should `workload`'s next request be served, probed, or rejected?
    pub fn admit(&self, workload: &str, now_vns: u64) -> Admission {
        if !self.enabled() {
            return Admission::Allow;
        }
        let mut funcs = self.funcs.lock().unwrap();
        let Some(h) = funcs.get_mut(workload) else {
            return Admission::Allow;
        };
        match h.state {
            BreakerState::Closed => Admission::Allow,
            BreakerState::Open { until_ns } if now_vns < until_ns => {
                Admission::Reject { until_ns }
            }
            BreakerState::Open { .. } => {
                // Quarantine expired: half-open, admit this as a probe.
                h.state = BreakerState::HalfOpen { successes: 0 };
                Admission::Probe { entered: true }
            }
            BreakerState::HalfOpen { .. } => Admission::Probe { entered: false },
        }
    }

    /// Record one served request's outcome and advance the machine.
    pub fn record(&self, workload: &str, now_vns: u64, ok: bool) -> Option<Transition> {
        if !self.enabled() {
            return None;
        }
        let mut funcs = self.funcs.lock().unwrap();
        let h = funcs.entry(workload.to_string()).or_insert_with(|| FnHealth {
            window: VecDeque::with_capacity(self.cfg.breaker_window as usize),
            state: BreakerState::Closed,
        });
        let quarantine_ns = self.cfg.quarantine_ms.saturating_mul(1_000_000);
        match h.state {
            BreakerState::Closed => {
                h.window.push_back(ok);
                while h.window.len() as u64 > self.cfg.breaker_window {
                    h.window.pop_front();
                }
                let failures = h.window.iter().filter(|&&v| !v).count() as u64;
                if failures >= self.cfg.breaker_failures {
                    let until_ns = now_vns + quarantine_ns;
                    h.state = BreakerState::Open { until_ns };
                    h.window.clear();
                    return Some(Transition::Opened { until_ns });
                }
                None
            }
            BreakerState::HalfOpen { successes } => {
                if ok {
                    let successes = successes + 1;
                    if successes >= self.cfg.probe_successes {
                        h.state = BreakerState::Closed;
                        h.window.clear();
                        return Some(Transition::Closed);
                    }
                    h.state = BreakerState::HalfOpen { successes };
                    None
                } else {
                    // One failed probe re-opens for a full quarantine.
                    let until_ns = now_vns + quarantine_ns;
                    h.state = BreakerState::Open { until_ns };
                    return Some(Transition::Opened { until_ns });
                }
            }
            // A late outcome for a request admitted before the breaker
            // opened: the quarantine decision already stands.
            BreakerState::Open { .. } => None,
        }
    }

    /// Is `workload` currently unhealthy (open or probing)? The policy
    /// layer uses this to stop spending anticipatory wakes on it — wakes
    /// resume only once the breaker fully closes.
    pub fn is_unhealthy(&self, workload: &str) -> bool {
        if !self.enabled() {
            return false;
        }
        let funcs = self.funcs.lock().unwrap();
        funcs
            .get(workload)
            .map(|h| h.state != BreakerState::Closed)
            .unwrap_or(false)
    }

    /// Functions currently quarantined or probing (diagnostics).
    pub fn unhealthy_count(&self) -> usize {
        self.funcs
            .lock()
            .unwrap()
            .values()
            .filter(|h| h.state != BreakerState::Closed)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ResilienceConfig {
        ResilienceConfig {
            breaker_window: 4,
            breaker_failures: 3,
            quarantine_ms: 10, // 10 ms = 10_000_000 vns
            probe_successes: 2,
            ..ResilienceConfig::default()
        }
    }

    const Q: u64 = 10_000_000;

    #[test]
    fn window_accounting_opens_on_kth_failure_and_slides() {
        let reg = HealthRegistry::new(&cfg());
        // Two failures among four outcomes: under the bar, stays closed.
        assert_eq!(reg.record("w", 0, false), None);
        assert_eq!(reg.record("w", 1, true), None);
        assert_eq!(reg.record("w", 2, false), None);
        assert_eq!(reg.record("w", 3, true), None);
        assert_eq!(reg.admit("w", 4), Admission::Allow);
        // The window slides: the first failure (t=0) falls out, so two
        // more failures are needed — the second of them is the 3rd in
        // window and opens the breaker.
        assert_eq!(reg.record("w", 5, false), None);
        assert_eq!(
            reg.record("w", 6, false),
            Some(Transition::Opened { until_ns: 6 + Q })
        );
        assert!(reg.is_unhealthy("w"));
        // Other functions are unaffected.
        assert_eq!(reg.admit("other", 7), Admission::Allow);
        assert!(!reg.is_unhealthy("other"));
    }

    #[test]
    fn quarantine_rejects_until_expiry_then_probes() {
        let reg = HealthRegistry::new(&cfg());
        for t in 0..3 {
            reg.record("w", t, false);
        }
        let until = 2 + Q;
        assert_eq!(reg.admit("w", 3), Admission::Reject { until_ns: until });
        assert_eq!(
            reg.admit("w", until - 1),
            Admission::Reject { until_ns: until }
        );
        // Expiry: the first admission transitions to half-open…
        assert_eq!(reg.admit("w", until), Admission::Probe { entered: true });
        // …and later admissions are plain probes.
        assert_eq!(
            reg.admit("w", until + 1),
            Admission::Probe { entered: false }
        );
        assert!(reg.is_unhealthy("w"), "half-open still suppresses wakes");
    }

    #[test]
    fn probe_successes_close_and_probe_failure_reopens() {
        let reg = HealthRegistry::new(&cfg());
        for t in 0..3 {
            reg.record("w", t, false);
        }
        let until = 2 + Q;
        // Close path: two consecutive probe successes.
        assert_eq!(reg.admit("w", until), Admission::Probe { entered: true });
        assert_eq!(reg.record("w", until, true), None, "one probe not enough");
        assert_eq!(reg.record("w", until + 1, true), Some(Transition::Closed));
        assert_eq!(reg.admit("w", until + 2), Admission::Allow);
        assert!(!reg.is_unhealthy("w"));
        // The close cleared the window: it takes a full K new failures to
        // open again, not K minus the pre-quarantine backlog.
        assert_eq!(reg.record("w", until + 3, false), None);
        assert_eq!(reg.record("w", until + 4, false), None);
        assert!(matches!(
            reg.record("w", until + 5, false),
            Some(Transition::Opened { .. })
        ));
        // Reopen path: a failed probe quarantines again immediately.
        let until2 = until + 5 + Q;
        assert_eq!(reg.admit("w", until2), Admission::Probe { entered: true });
        assert_eq!(
            reg.record("w", until2 + 1, false),
            Some(Transition::Opened {
                until_ns: until2 + 1 + Q
            })
        );
        assert!(matches!(reg.admit("w", until2 + 2), Admission::Reject { .. }));
    }

    #[test]
    fn disabled_breaker_is_inert() {
        let reg = HealthRegistry::new(&ResilienceConfig {
            breaker_failures: 0,
            ..cfg()
        });
        assert!(!reg.enabled());
        for t in 0..50 {
            assert_eq!(reg.record("w", t, false), None);
        }
        assert_eq!(reg.admit("w", 100), Admission::Allow);
        assert!(!reg.is_unhealthy("w"));
        assert_eq!(reg.unhealthy_count(), 0);
    }

    #[test]
    fn late_outcomes_during_quarantine_do_not_perturb_the_machine() {
        let reg = HealthRegistry::new(&cfg());
        for t in 0..3 {
            reg.record("w", t, false);
        }
        let until = 2 + Q;
        // In-flight requests admitted before the open report afterwards:
        // ignored — the machine stays Open with its original deadline.
        assert_eq!(reg.record("w", 4, true), None);
        assert_eq!(reg.record("w", 5, false), None);
        assert_eq!(reg.admit("w", 6), Admission::Reject { until_ns: until });
    }

    #[test]
    fn quarantined_and_timed_out_errors_downcast_through_anyhow() {
        let q = anyhow::Error::new(Quarantined {
            workload: "w".into(),
            until_ns: 9,
        });
        assert!(q.chain().any(|c| c.downcast_ref::<Quarantined>().is_some()));
        assert!(q.to_string().contains("quarantined"));
        let t = anyhow::Error::new(TimedOut {
            workload: "w".into(),
            waited_ns: 5,
        });
        assert!(t.chain().any(|c| c.downcast_ref::<TimedOut>().is_some()));
        assert!(t.to_string().contains("timed out"));
    }
}
