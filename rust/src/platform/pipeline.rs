//! The off-tick instance-I/O pipeline: a small worker pool that runs the
//! expensive half of every instance lifecycle transition *off* the policy
//! tick, holding only the instance's own mutex. It is bidirectional —
//! and then some:
//!
//! * **Deflate** — [`Sandbox::hibernate_finish`]: the delta swap-out,
//!   file-page release and madvise passes;
//! * **Inflate** — [`Sandbox::wake_finish`]: the anticipatory REAP batch
//!   prefetch;
//! * **Teardown** — [`Sandbox::terminate`]: eviction's page/host-object
//!   release.
//!
//! The split: the policy tick performs the cheap state flip under the
//! shard lock (SIGSTOP → the router stops preferring the instance;
//! SIGCONT → the router ranks it WokenUp; evictions flip nothing — the
//! reservation alone fences them), then submits a [`PipelineJob`]
//! carrying the sandbox handle and — crucially — the instance's RAII
//! [`Reservation`]. The reservation is what makes the pipeline safe:
//! routing and policy both skip reserved instances, so no request or
//! competing action can race the in-flight I/O, and it is released
//! (dropped) only after the finish completes, at which point the instance
//! is a fully-transitioned, routable container.
//!
//! Besides the transition itself, a completing job refreshes the
//! instance's live-byte gauge (the [`pool`](super::pool) charge budget
//! accounting reads) and, for inflations, feeds the measured (charged)
//! `wake_finish` duration into the platform's learned wake leads
//! ([`WakeLeads`]) — the policy's adaptive SIGCONT lead.
//!
//! Ordering contract for determinism: a worker (1) folds the job's
//! counters into the shared [`Metrics`], (2) drops the reservation, and
//! only then (3) decrements the pending gauge. [`InstancePipeline::drain`]
//! therefore guarantees that once pending hits zero, every transitioned
//! instance is visible, unreserved, and fully accounted — which is what
//! lets the replay engine drain after each tick batch and stay
//! bit-identical at any worker count ([`crate::replay`]).
//!
//! Queued jobs are priority-classed: wake-path inflations sit on a
//! strict-priority latency queue that workers always drain before the
//! throughput queue holding deflations and teardowns. A deflation storm
//! can therefore delay a demand wake by at most the one job each worker
//! already has in hand — and each such jump is counted in the shared
//! [`IoStats::priority_bypasses`](super::metrics::IoStats) gauge that
//! the storm tests assert on. The same classes continue below the
//! pipeline: the [`io_backend`](super::io_backend) tags the resulting
//! swap-file I/O `Latency` vs `Throughput` so a batched backend keeps
//! honoring the split at the syscall level.
//!
//! Backpressure is the platform's job (it owns the shed policy — see
//! `policy.pipeline_queue_cap`); the pipeline exposes its queue depth
//! plus the surgery the shed policy needs:
//! [`InstancePipeline::steal_largest_deflation`] pulls the queued
//! deflation with the most deferred I/O per slot so the platform can run
//! *that* inline ([`InstancePipeline::run_inline`]) instead of the
//! (smaller) incoming job.
//!
//! Errors from a finish are stashed and surface at the next
//! [`InstancePipeline::reap`]/[`InstancePipeline::drain`] (i.e. the next
//! policy tick), mirroring how an async kernel writeback error surfaces
//! later.

use super::metrics::Metrics;
use super::policy::WakeLeads;
use super::pool::Reservation;
use crate::container::sandbox::Sandbox;
use crate::obs::EventKind;
use crate::replay::chaos::{ChaosPanic, JobFault};
use crate::simtime::Clock;
use crate::util::fnv1a;
use anyhow::{anyhow, Context as _, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Which expensive half a job runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// [`Sandbox::hibernate_finish`] — the state flip already happened.
    Deflate,
    /// [`Sandbox::wake_finish`] — the state flip already happened.
    Inflate,
    /// [`Sandbox::terminate`] — no prior flip; the reservation fences it.
    Teardown,
}

impl JobKind {
    fn verb(self) -> &'static str {
        match self {
            JobKind::Deflate => "deflating",
            JobKind::Inflate => "inflating",
            JobKind::Teardown => "evicting",
        }
    }

    /// Stable wire code carried in flight-recorder job events' `arg`
    /// (what keys a `job_start`/`job_done` pair to one trace span).
    pub fn code(self) -> u64 {
        match self {
            JobKind::Deflate => 0,
            JobKind::Inflate => 1,
            JobKind::Teardown => 2,
        }
    }
}

/// A lifecycle finish handed to the pipeline; the reservation rides along
/// and is released when the finish completes.
pub struct PipelineJob {
    pub workload: String,
    pub sandbox: Arc<Mutex<Sandbox>>,
    pub reservation: Reservation,
    pub kind: JobKind,
    /// The instance's live-byte gauge, refreshed when the finish
    /// completes.
    pub live_gauge: Arc<AtomicU64>,
    /// Estimated deferred I/O (the live-byte charge at submission) — what
    /// the shed policy sizes queued deflations by.
    pub est_bytes: u64,
    /// The sandbox's instance id, carried so job trace events don't have
    /// to take the sandbox mutex just to label themselves.
    pub instance_id: u64,
    /// Virtual time of the submitting tick — the job clock's anchor, so
    /// `job_start`/`job_done` events stamp absolute virtual nanoseconds.
    pub submitted_vns: u64,
    /// Wall-clock submission instant — the wake-path queue-wait sample
    /// ([`Metrics::record_queue_wait`]).
    pub enqueued_wall: Instant,
    /// Chaos fault assigned at dispatch time (on the shard owner's worker,
    /// so the assignment is deterministic at any pipeline/replay worker
    /// count): `Hang` burns virtual time into the job clock — watchdog
    /// food — and `Panic` unwinds mid-job — `catch_unwind`-fence food.
    pub chaos_fault: Option<JobFault>,
}

/// Test-only hook invoked by a worker before it starts a job — lets a
/// stress test hold a deflation or inflation in flight deterministically.
pub type PipelineGate = Arc<dyn Fn() + Send + Sync>;

#[derive(Default)]
struct PoolState {
    /// Jobs queued or running.
    pending: usize,
    /// Finishes completed since the last reap.
    completed: u64,
    /// Errors collected since the last reap.
    errors: Vec<anyhow::Error>,
    /// Wake-path (Inflate) jobs not yet picked up — always served before
    /// the throughput queue, so a deflation storm can never delay a wake
    /// by more than the job a worker already has in hand.
    latency: VecDeque<PipelineJob>,
    /// Deflate/Teardown jobs not yet picked up by a worker.
    throughput: VecDeque<PipelineJob>,
    /// Set when the pipeline is dropping: workers drain and exit.
    closed: bool,
}

impl PoolState {
    /// Pop the next runnable job, latency class first. Reports whether the
    /// pop jumped a non-empty throughput queue (the priority-bypass case).
    fn pop_next(&mut self) -> Option<(PipelineJob, bool)> {
        if let Some(job) = self.latency.pop_front() {
            return Some((job, !self.throughput.is_empty()));
        }
        self.throughput.pop_front().map(|job| (job, false))
    }
}

struct Shared {
    state: Mutex<PoolState>,
    idle: Condvar,
    work: Condvar,
    metrics: Arc<Metrics>,
    wake_leads: Arc<WakeLeads>,
    gate: Mutex<Option<PipelineGate>>,
    /// Watchdog budget in *virtual* nanoseconds (0 = off): a job whose
    /// charged clock exceeds this is cancelled — its instance retires and
    /// its reservation releases — instead of being trusted.
    watchdog_budget_ns: u64,
}

/// The instance-I/O worker pool. With zero workers it is a pass-through:
/// [`InstancePipeline::run_sync`] executes the finish inline (the baseline
/// the benches compare against, and the shed fallback).
pub struct InstancePipeline {
    async_mode: bool,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl InstancePipeline {
    pub fn new(
        workers: usize,
        metrics: Arc<Metrics>,
        wake_leads: Arc<WakeLeads>,
        watchdog_budget_ns: u64,
    ) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            idle: Condvar::new(),
            work: Condvar::new(),
            metrics,
            wake_leads,
            gate: Mutex::new(None),
            watchdog_budget_ns,
        });
        let handles = (0..workers)
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            if let Some((job, bypassed)) = st.pop_next() {
                                if bypassed {
                                    shared
                                        .metrics
                                        .io
                                        .priority_bypasses
                                        .fetch_add(1, Ordering::Relaxed);
                                }
                                break job;
                            }
                            if st.closed {
                                return;
                            }
                            st = shared.work.wait(st).unwrap();
                        }
                    };
                    run_job(&shared, job);
                })
            })
            .collect();
        Self {
            async_mode: workers > 0,
            workers: handles,
            shared,
        }
    }

    /// Does this pipeline actually run jobs asynchronously?
    pub fn is_async(&self) -> bool {
        self.async_mode
    }

    /// Queue a job. The pending gauge is bumped *before* the job becomes
    /// runnable so a concurrent [`Self::drain`] can never miss it.
    ///
    /// Panics on a synchronous (zero-worker) pipeline — nothing would
    /// ever run the job, leaking its reservation and hanging `drain`;
    /// callers must route through [`Self::run_sync`] instead.
    pub fn submit(&self, job: PipelineJob) {
        assert!(self.async_mode, "submit on a synchronous pipeline");
        if self.shared.metrics.recorder.is_enabled() {
            self.shared.metrics.recorder.emit_workload(
                EventKind::JobEnqueue,
                job.instance_id,
                fnv1a(&job.workload),
                job.kind.code(),
                job.submitted_vns,
            );
        }
        let mut st = self.shared.state.lock().unwrap();
        st.pending += 1;
        self.shared
            .metrics
            .counters
            .pipeline_depth
            .store(st.pending as u64, Ordering::Relaxed);
        if st.closed {
            // Workers are only gone while the pipeline is being torn down;
            // finish inline rather than losing the transition.
            drop(st);
            run_job(&self.shared, job);
            return;
        }
        // Wake-path inflations go to the strict-priority latency queue;
        // deflations and teardowns queue behind every pending wake.
        match job.kind {
            JobKind::Inflate => st.latency.push_back(job),
            JobKind::Deflate | JobKind::Teardown => st.throughput.push_back(job),
        }
        drop(st);
        self.shared.work.notify_one();
    }

    /// Synchronous fallback (`pipeline_workers = 0`, or a shed job): run
    /// the finish inline on the caller's thread. Same accounting — panic
    /// fence and watchdog included — no queue.
    pub fn run_sync(&self, job: PipelineJob) -> Result<()> {
        let result = execute(&self.shared, &job);
        drop(job.reservation);
        result
    }

    /// Pull the queued (not yet running) deflation with the largest
    /// estimated deferred I/O, if one exceeds `min_bytes`. The job stays
    /// counted as pending — the caller owes it a [`Self::run_inline`].
    /// Ties favor the oldest submission.
    pub fn steal_largest_deflation(&self, min_bytes: u64) -> Option<PipelineJob> {
        let mut st = self.shared.state.lock().unwrap();
        let mut best: Option<(usize, u64)> = None;
        // Deflations only ever live on the throughput queue; the latency
        // queue holds wakes, which the shed policy never steals.
        for (i, job) in st.throughput.iter().enumerate() {
            if job.kind != JobKind::Deflate || job.est_bytes <= min_bytes {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, bytes)) => job.est_bytes > bytes,
            };
            if better {
                best = Some((i, job.est_bytes));
            }
        }
        let (i, _) = best?;
        st.throughput.remove(i)
    }

    /// Run a previously [stolen](Self::steal_largest_deflation) job on the
    /// caller's thread with full worker accounting (pending decrement,
    /// completion count, drain wakeup). Errors return directly instead of
    /// being stashed — the shedding tick is synchronous anyway. The test
    /// gate is deliberately not consulted: the caller *is* the policy
    /// tick, and parking it on the gate would deadlock gated tests.
    pub fn run_inline(&self, job: PipelineJob) -> Result<()> {
        finish_job(&self.shared, job, false)
    }

    /// Jobs queued or in flight right now.
    pub fn pending(&self) -> usize {
        self.shared.state.lock().unwrap().pending
    }

    /// Non-blocking: collect completions since the last reap. All stashed
    /// errors are logged; the first is returned (annotated with how many
    /// more there were, so a batch of failures is never mistaken for a
    /// single one). Returns the number reaped on success.
    pub fn reap(&self) -> Result<u64> {
        let mut st = self.shared.state.lock().unwrap();
        let n = st.completed;
        st.completed = 0;
        let mut errors = std::mem::take(&mut st.errors);
        drop(st);
        if errors.is_empty() {
            return Ok(n);
        }
        for e in errors.iter().skip(1) {
            eprintln!("pipeline error (additional): {e:#}");
        }
        let count = errors.len();
        let first = errors.swap_remove(0);
        Err(if count > 1 {
            first.context(format!(
                "plus {} more pipeline error(s), logged to stderr",
                count - 1
            ))
        } else {
            first
        })
    }

    /// Block until every queued/in-flight job has completed, then reap.
    /// After this returns Ok, every submitted instance is transitioned,
    /// unreserved and folded into the metrics.
    pub fn drain(&self) -> Result<u64> {
        let mut st = self.shared.state.lock().unwrap();
        while st.pending > 0 {
            st = self.shared.idle.wait(st).unwrap();
        }
        drop(st);
        self.reap()
    }

    /// Install (or clear) the test gate — see [`PipelineGate`].
    #[doc(hidden)]
    pub fn set_gate(&self, gate: Option<PipelineGate>) {
        *self.shared.gate.lock().unwrap() = gate;
    }
}

impl Drop for InstancePipeline {
    fn drop(&mut self) {
        // Closing lets each worker finish the backlog and exit once the
        // queue runs dry; joining guarantees no job outlives the pool.
        self.shared.state.lock().unwrap().closed = true;
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn run_job(shared: &Shared, job: PipelineJob) {
    let gate = shared.gate.lock().unwrap().clone();
    if let Some(gate) = gate {
        gate();
    }
    let _ = finish_job(shared, job, true);
}

/// Complete one job: run the finish, release the instance, then announce.
/// With `stash` the error is queued for the next reap (the async worker
/// path); without it the error returns to the caller (the inline path).
/// Error stashing shares the completion critical section, so a drainer
/// can never observe the completion without the error.
fn finish_job(shared: &Shared, job: PipelineJob, stash: bool) -> Result<()> {
    let result = execute(shared, &job);
    // Release the instance before announcing completion: a drainer must
    // observe the transitioned instance as routable the moment pending
    // drops.
    drop(job.reservation);
    let mut st = shared.state.lock().unwrap();
    st.pending -= 1;
    st.completed += 1;
    shared
        .metrics
        .counters
        .pipeline_depth
        .store(st.pending as u64, Ordering::Relaxed);
    let out = match result {
        Err(e) if stash => {
            st.errors.push(e);
            Ok(())
        }
        other => other,
    };
    drop(st);
    shared.idle.notify_all();
    out
}

/// The fenced job executor every mode funnels through (async workers via
/// [`finish_job`], the inline shed path, the sync fallback): runs the
/// finish inside a `catch_unwind` fence, then holds the job's charged
/// virtual time against the watchdog budget. The caller still owes the
/// reservation drop and the pending-gauge bookkeeping — which is exactly
/// why the fence lives here: no matter how the finish dies, control
/// returns to the caller and the instance can never stay reserved or
/// `drain` hang on a decrement that never comes.
fn execute(shared: &Shared, job: &PipelineJob) -> Result<()> {
    // Lifecycle I/O's charged time belongs to no request — it runs on the
    // platform's dime, like kernel writeback. Anchoring at the submitting
    // tick's virtual time makes the job's trace events stamp absolute
    // virtual nanoseconds (worker-count independent). Created here, not in
    // `run_one`, so the watchdog can read the charge even when the finish
    // itself never returns.
    let clock = Clock::new();
    clock.set_base(job.submitted_vns);
    let metrics = &shared.metrics;
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_one(metrics, &shared.wake_leads, job, &clock)
    }));
    match result {
        Ok(finish) => {
            let budget = shared.watchdog_budget_ns;
            if budget > 0 && clock.charged_ns() > budget && finish.is_ok() {
                // The job blew its virtual budget (a hung inflation, a
                // stalled deflation): cancel it. In this model the overrun
                // is only observable once the finish returns, so "cancel"
                // means refusing to trust the result — the instance
                // retires through the degrade ladder and the platform
                // replaces it. Self-healed: not a pipeline error.
                metrics
                    .resilience
                    .watchdog_cancels
                    .fetch_add(1, Ordering::Relaxed);
                if metrics.recorder.is_enabled() {
                    metrics.recorder.emit_workload(
                        EventKind::Timeout,
                        job.instance_id,
                        fnv1a(&job.workload),
                        2,
                        clock.stamp_ns(),
                    );
                }
                retire_job_instance(job);
                return Ok(());
            }
            finish
        }
        Err(payload) => {
            // The finish unwound. The fence already saved the invariants
            // (reservation + gauge bookkeeping happen in our caller); the
            // instance itself is in an unknown state — retire it.
            metrics
                .resilience
                .panics_fenced
                .fetch_add(1, Ordering::Relaxed);
            retire_job_instance(job);
            if payload.downcast_ref::<ChaosPanic>().is_some() {
                // An injected panic proves the fence; recovery is the
                // outcome, not an error to surface.
                Ok(())
            } else {
                Err(anyhow!(
                    "pipeline worker panicked {} an instance of `{}`: {}",
                    job.kind.verb(),
                    job.workload,
                    panic_text(payload.as_ref())
                ))
            }
        }
    }
}

/// Post-fence cleanup: force the job's instance to `Dead` (releasing its
/// pages, swap files and host objects) and zero its gauge, so the next
/// sweep removes it and the platform cold-starts a replacement.
fn retire_job_instance(job: &PipelineJob) {
    // A panicking finish may have poisoned the sandbox mutex; the sandbox
    // is being retired either way, so the poison flag carries no
    // information — take the inner value.
    let mut sb = job
        .sandbox
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    if let Err(e) = sb.retire() {
        eprintln!(
            "pipeline: retiring instance {} of `{}` failed ({e:#})",
            sb.id, job.workload
        );
    }
    job.live_gauge.store(sb.live_bytes(), Ordering::Relaxed);
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else if let Some(c) = p.downcast_ref::<ChaosPanic>() {
        format!("chaos panic (workload {})", c.workload)
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one finish and fold its counters into the metrics. Used by the
/// async workers, the inline shed path and the sync fallback, so all
/// modes are observationally identical. The caller keeps ownership of
/// the job (it still owes the reservation drop).
fn run_one(
    metrics: &Metrics,
    wake_leads: &WakeLeads,
    job: &PipelineJob,
    clock: &Clock,
) -> Result<()> {
    // Chaos faults fire first, *before* the sandbox lock: an injected
    // panic that unwound while holding the instance mutex would poison it
    // for every later requester — the fault models a dying worker, not a
    // lock-corruption bug. A hang charges its stall onto the job clock
    // (virtual, so deterministic), which is what the watchdog in
    // [`execute`] measures.
    match job.chaos_fault {
        Some(JobFault::Panic) => std::panic::panic_any(ChaosPanic {
            workload: job.workload.clone(),
        }),
        Some(JobFault::Hang { ns }) => clock.charge(ns),
        None => {}
    }
    let kind = job.kind;
    let workload = job.workload.as_str();
    let whash = fnv1a(workload);
    let rec = &metrics.recorder;
    let mut sb = job.sandbox.lock().unwrap();
    if rec.is_enabled() {
        rec.emit_workload(
            EventKind::JobStart,
            job.instance_id,
            whash,
            kind.code(),
            clock.stamp_ns(),
        );
    }
    if kind == JobKind::Inflate {
        // How long the wake sat behind the queue (wall domain — a real
        // scheduling delay, not a modeled cost).
        metrics.record_queue_wait(job.enqueued_wall.elapsed().as_nanos() as u64);
    }
    let fail = || format!("{} an instance of `{workload}`", kind.verb());
    match kind {
        JobKind::Deflate => {
            let before = sb.swap_stats();
            sb.hibernate_finish(&clock).with_context(fail)?;
            let after = sb.swap_stats();
            if after.reap_swapouts > before.reap_swapouts {
                metrics
                    .counters
                    .reap_hibernations
                    .fetch_add(1, Ordering::Relaxed);
            }
            metrics.counters.pages_swapped_out.fetch_add(
                (after.pages_swapped_out + after.reap_pages_out)
                    - (before.pages_swapped_out + before.reap_pages_out),
                Ordering::Relaxed,
            );
        }
        JobKind::Inflate => {
            let prefetched = sb.wake_finish(&clock).with_context(fail)?;
            // The charged clock is exactly the prefetch's virtual
            // duration — the sample the adaptive wake lead learns from.
            // Only a *real* prefetch teaches it: an image-less wake (no
            // REAP record yet) charges ~nothing, and anchoring the EWMA
            // at 0 would collapse every later lead to the clamp floor.
            if prefetched > 0 {
                wake_leads.observe(workload, clock.charged_ns());
                metrics.record_inflate(clock.charged_ns());
            }
        }
        JobKind::Teardown => {
            sb.terminate().with_context(fail)?;
            metrics.counters.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }
    job.live_gauge.store(sb.live_bytes(), Ordering::Relaxed);
    if rec.is_enabled() {
        rec.emit_workload(
            EventKind::JobDone,
            job.instance_id,
            whash,
            kind.code(),
            clock.stamp_ns(),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharingConfig;
    use crate::container::sandbox::SandboxServices;
    use crate::container::NoopRunner;
    use crate::platform::pool::FunctionPool;
    use crate::simtime::CostModel;
    use crate::workloads::functionbench::{golang_hello, nodejs_hello, scaled_for_test};
    use std::sync::mpsc;
    use std::time::Duration;

    fn rig(tag: &str) -> (Arc<SandboxServices>, FunctionPool) {
        let svc = SandboxServices::new_local(
            1 << 30,
            CostModel::paper(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            tag,
        )
        .unwrap();
        (svc, FunctionPool::new())
    }

    /// Build a Deflate job for pool instance `idx`: flips
    /// `hibernate_begin` (the platform's in-tick step) and reserves the
    /// instance, exactly like `Platform::apply_hibernate`.
    fn deflate_job(pool: &FunctionPool, idx: usize, workload: &str) -> PipelineJob {
        let inst = &pool.instances[idx];
        let reservation = inst.try_reserve().expect("instance must be free");
        inst.sandbox.lock().unwrap().hibernate_begin().unwrap();
        PipelineJob {
            workload: workload.to_string(),
            sandbox: inst.sandbox.clone(),
            reservation,
            kind: JobKind::Deflate,
            live_gauge: inst.live_gauge.clone(),
            est_bytes: inst.live_bytes(),
            instance_id: idx as u64,
            submitted_vns: 0,
            enqueued_wall: Instant::now(),
            chaos_fault: None,
        }
    }

    #[test]
    fn steal_picks_the_largest_queued_deflation_and_inline_completes_it() {
        let (svc, mut pool) = rig("pipe-steal");
        let clock = crate::simtime::Clock::new();
        // Two differently-sized sandboxes: big (nodejs half-scale) ≫ tiny.
        let big = crate::container::sandbox::Sandbox::cold_start(
            1,
            scaled_for_test(nodejs_hello(), 2),
            svc.clone(),
            &clock,
        )
        .unwrap();
        let tiny = crate::container::sandbox::Sandbox::cold_start(
            2,
            scaled_for_test(golang_hello(), 64),
            svc.clone(),
            &clock,
        )
        .unwrap();
        pool.add(tiny, 0); // idx 0
        pool.add(big, 0); // idx 1
        assert!(
            pool.instances[1].live_bytes() > pool.instances[0].live_bytes(),
            "test premise: big must out-charge tiny"
        );

        let metrics = Arc::new(Metrics::new());
        let leads = Arc::new(WakeLeads::new(true));
        // One worker, parked on the gate with a sacrificial job so the
        // queue contents are deterministic.
        let pipeline = InstancePipeline::new(1, metrics.clone(), leads, 0);
        let (entered_tx, entered_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let entered_tx = Mutex::new(entered_tx);
        let release_rx = Mutex::new(release_rx);
        pipeline.set_gate(Some(Arc::new(move || {
            let _ = entered_tx.lock().unwrap().send(());
            let _ = release_rx.lock().unwrap().recv();
        })));
        pipeline.submit(deflate_job(&pool, 0, "tiny"));
        entered_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("worker must park on the sacrificial job");

        // Queue the big deflation behind the parked worker; nothing picks
        // it up, so the steal sees exactly one candidate.
        pipeline.submit(deflate_job(&pool, 1, "big"));
        assert_eq!(pipeline.pending(), 2);

        // A steal with a floor above big's size finds nothing.
        assert!(pipeline.steal_largest_deflation(u64::MAX).is_none());
        let victim = pipeline
            .steal_largest_deflation(0)
            .expect("the queued big deflation must be stealable");
        assert_eq!(victim.workload, "big");
        assert_eq!(pipeline.pending(), 2, "stolen jobs stay pending");
        let before = svc.host.committed_bytes();
        pipeline.run_inline(victim).unwrap();
        assert_eq!(pipeline.pending(), 1, "inline run completes the job");
        assert!(
            svc.host.committed_bytes() < before,
            "the inline deflation must actually free memory"
        );
        assert_eq!(
            pool.instances[1].sandbox.lock().unwrap().state(),
            crate::container::state::ContainerState::Hibernate
        );
        assert!(
            !pool.instances[1].is_reserved(),
            "inline completion releases the reservation"
        );
        assert_eq!(
            pool.instances[1].live_bytes(),
            pool.instances[1].sandbox.lock().unwrap().live_bytes(),
            "the completing job must refresh the live-byte gauge"
        );

        release_tx.send(()).unwrap();
        pipeline.set_gate(None);
        pipeline.drain().unwrap();
        assert_eq!(pipeline.pending(), 0);
    }

    #[test]
    fn inflation_jobs_teach_the_wake_lead_only_when_an_image_exists() {
        use crate::platform::policy::{
            WAKE_LEAD_MAX_NS, WAKE_LEAD_MIN_NS, WAKE_LEAD_SEED_NS,
        };
        let (svc, mut pool) = rig("pipe-lead");
        let clock = crate::simtime::Clock::new();
        let mut sb = crate::container::sandbox::Sandbox::cold_start(
            1,
            scaled_for_test(nodejs_hello(), 4),
            svc.clone(),
            &clock,
        )
        .unwrap();
        // First hibernate is the full (page-fault) path: no REAP image.
        sb.hibernate(&clock).unwrap();
        pool.add(sb, 0);
        let metrics = Arc::new(Metrics::new());
        let leads = Arc::new(WakeLeads::new(true));
        let pipeline = InstancePipeline::new(1, metrics, leads.clone(), 0);
        let submit_wake = |pool: &FunctionPool| {
            let inst = &pool.instances[0];
            let reservation = inst.try_reserve().unwrap();
            inst.sandbox
                .lock()
                .unwrap()
                .wake_begin(&crate::simtime::Clock::new())
                .unwrap();
            pipeline.submit(PipelineJob {
                workload: "w".into(),
                sandbox: inst.sandbox.clone(),
                reservation,
                kind: JobKind::Inflate,
                live_gauge: inst.live_gauge.clone(),
                est_bytes: inst.live_bytes(),
                instance_id: 0,
                submitted_vns: 0,
                enqueued_wall: Instant::now(),
                chaos_fault: None,
            });
        };

        // Image-less inflation: prefetches nothing, charges ~0 — it must
        // NOT anchor the EWMA (a 0 sample would clamp every later lead
        // to the 5 ms floor and silence anticipation at coarser ticks).
        submit_wake(&pool);
        pipeline.drain().unwrap();
        assert_eq!(
            leads.lead_ns("w"),
            WAKE_LEAD_SEED_NS,
            "a zero-page inflation must not poison the learned lead"
        );

        // Serve once (the sample request records the working set), then
        // hibernate again: the REAP image now exists, and the next
        // pipeline inflation is a real prefetch the lead learns from.
        {
            let mut sb = pool.instances[0].sandbox.lock().unwrap();
            sb.handle_request(&crate::simtime::Clock::new()).unwrap();
            sb.hibernate(&crate::simtime::Clock::new()).unwrap();
        }
        submit_wake(&pool);
        pipeline.drain().unwrap();
        let lead = leads.lead_ns("w");
        assert_ne!(
            lead, WAKE_LEAD_SEED_NS,
            "a measured REAP inflation must replace the seed"
        );
        assert!(
            (WAKE_LEAD_MIN_NS..=WAKE_LEAD_MAX_NS).contains(&lead),
            "{lead}"
        );
    }

    #[test]
    fn queued_inflation_bypasses_a_deflation_backlog() {
        let (svc, mut pool) = rig("pipe-prio");
        let clock = crate::simtime::Clock::new();
        for id in 1..=3 {
            let sb = crate::container::sandbox::Sandbox::cold_start(
                id,
                scaled_for_test(golang_hello(), 64),
                svc.clone(),
                &clock,
            )
            .unwrap();
            pool.add(sb, 0); // idx 0..2, warm — deflation fodder
        }
        let mut sleeper = crate::container::sandbox::Sandbox::cold_start(
            4,
            scaled_for_test(golang_hello(), 64),
            svc.clone(),
            &clock,
        )
        .unwrap();
        sleeper.hibernate(&clock).unwrap();
        pool.add(sleeper, 0); // idx 3, hibernated — the demand wake

        let metrics = Arc::new(Metrics::new());
        let leads = Arc::new(WakeLeads::new(true));
        // One worker, parked on the gate with a sacrificial deflation so
        // the queue contents at release time are deterministic.
        let pipeline = InstancePipeline::new(1, metrics.clone(), leads, 0);
        let (entered_tx, entered_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let entered_tx = Mutex::new(entered_tx);
        let release_rx = Mutex::new(release_rx);
        pipeline.set_gate(Some(Arc::new(move || {
            let _ = entered_tx.lock().unwrap().send(());
            let _ = release_rx.lock().unwrap().recv();
        })));
        pipeline.submit(deflate_job(&pool, 0, "sacrifice"));
        entered_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("worker must park on the sacrificial job");

        // Build the deflation backlog, then queue a demand wake behind it.
        pipeline.submit(deflate_job(&pool, 1, "storm-a"));
        pipeline.submit(deflate_job(&pool, 2, "storm-b"));
        {
            let inst = &pool.instances[3];
            let reservation = inst.try_reserve().unwrap();
            inst.sandbox
                .lock()
                .unwrap()
                .wake_begin(&crate::simtime::Clock::new())
                .unwrap();
            pipeline.submit(PipelineJob {
                workload: "wake".into(),
                sandbox: inst.sandbox.clone(),
                reservation,
                kind: JobKind::Inflate,
                live_gauge: inst.live_gauge.clone(),
                est_bytes: inst.live_bytes(),
                instance_id: 3,
                submitted_vns: 0,
                enqueued_wall: Instant::now(),
                chaos_fault: None,
            });
        }
        assert_eq!(pipeline.pending(), 4);
        assert_eq!(metrics.io.priority_bypasses.load(Ordering::Relaxed), 0);

        // Unpark. The next job the worker takes must be the wake, jumping
        // the two queued deflations — observable as exactly one bypass
        // (the subsequent deflation pops find the latency queue empty).
        pipeline.set_gate(None);
        release_tx.send(()).unwrap();
        pipeline.drain().unwrap();
        assert_eq!(pipeline.pending(), 0);
        assert_eq!(
            metrics.io.priority_bypasses.load(Ordering::Relaxed),
            1,
            "the wake must pop exactly once over a non-empty deflation backlog"
        );
        for idx in [0, 1, 2] {
            assert_eq!(
                pool.instances[idx].sandbox.lock().unwrap().state(),
                crate::container::state::ContainerState::Hibernate,
                "instance {idx} must still complete its deflation"
            );
            assert!(!pool.instances[idx].is_reserved());
        }
        assert!(
            !pool.instances[3].is_reserved(),
            "the completed wake releases its reservation"
        );
    }

    #[test]
    fn a_panicking_job_cannot_leak_its_reservation_or_hang_drain() {
        let (svc, mut pool) = rig("pipe-panic");
        let clock = crate::simtime::Clock::new();
        let sb = crate::container::sandbox::Sandbox::cold_start(
            1,
            scaled_for_test(golang_hello(), 64),
            svc.clone(),
            &clock,
        )
        .unwrap();
        pool.add(sb, 0);
        let metrics = Arc::new(Metrics::new());
        let leads = Arc::new(WakeLeads::new(true));
        let pipeline = InstancePipeline::new(1, metrics.clone(), leads, 0);
        let mut job = deflate_job(&pool, 0, "boom");
        job.chaos_fault = Some(JobFault::Panic);
        pipeline.submit(job);
        // The regression this pins: before the fence, the panic unwound
        // through the worker without ever decrementing `pending`, so this
        // drain hung forever. It must now complete — and without an error,
        // because an injected chaos panic is a self-healed outcome.
        pipeline.drain().unwrap();
        assert_eq!(pipeline.pending(), 0);
        assert_eq!(metrics.resilience.panics_fenced.load(Ordering::Relaxed), 1);
        assert!(
            !pool.instances[0].is_reserved(),
            "the fence must release the panicked job's reservation"
        );
        assert_eq!(
            pool.instances[0].sandbox.lock().unwrap().state(),
            crate::container::state::ContainerState::Dead,
            "the panicked job's instance retires"
        );
        // After the sweep the pool routes again — a fresh cold start, not
        // a permanently unroutable function.
        assert_eq!(pool.sweep_dead(), 1);
        assert!(matches!(
            crate::platform::router::route(&pool),
            crate::platform::router::Route::ColdStart
        ));
    }

    #[test]
    fn watchdog_cancels_a_job_exceeding_its_virtual_budget() {
        let (svc, mut pool) = rig("pipe-watchdog");
        let clock = crate::simtime::Clock::new();
        for id in 1..=2 {
            let sb = crate::container::sandbox::Sandbox::cold_start(
                id,
                scaled_for_test(golang_hello(), 64),
                svc.clone(),
                &clock,
            )
            .unwrap();
            pool.add(sb, 0);
        }
        let metrics = Arc::new(Metrics::new());
        let leads = Arc::new(WakeLeads::new(true));
        // 1 s virtual budget: a healthy small deflation charges far less,
        // a chaos hang burns 2 s and must trip the watchdog.
        let pipeline = InstancePipeline::new(1, metrics.clone(), leads, 1_000_000_000);
        let mut hung = deflate_job(&pool, 0, "hung");
        hung.chaos_fault = Some(JobFault::Hang { ns: 2_000_000_000 });
        pipeline.submit(hung);
        pipeline.submit(deflate_job(&pool, 1, "healthy"));
        pipeline.drain().unwrap();
        assert_eq!(
            metrics.resilience.watchdog_cancels.load(Ordering::Relaxed),
            1,
            "exactly the hung job is cancelled"
        );
        assert_eq!(
            pool.instances[0].sandbox.lock().unwrap().state(),
            crate::container::state::ContainerState::Dead,
            "the cancelled job's instance retires through the degrade ladder"
        );
        assert!(!pool.instances[0].is_reserved());
        assert_eq!(
            pool.instances[1].sandbox.lock().unwrap().state(),
            crate::container::state::ContainerState::Hibernate,
            "the healthy deflation completes untouched"
        );
        assert!(!pool.instances[1].is_reserved());
        assert_eq!(pool.sweep_dead(), 1);
    }
}
