//! The off-tick instance-I/O pipeline: a small worker pool that runs the
//! expensive half of every instance lifecycle transition *off* the policy
//! tick, holding only the instance's own mutex. It is bidirectional —
//! and then some:
//!
//! * **Deflate** — [`Sandbox::hibernate_finish`]: the delta swap-out,
//!   file-page release and madvise passes;
//! * **Inflate** — [`Sandbox::wake_finish`]: the anticipatory REAP batch
//!   prefetch;
//! * **Teardown** — [`Sandbox::terminate`]: eviction's page/host-object
//!   release.
//!
//! The split: the policy tick performs the cheap state flip under the
//! shard lock (SIGSTOP → the router stops preferring the instance;
//! SIGCONT → the router ranks it WokenUp; evictions flip nothing — the
//! reservation alone fences them), then submits a [`PipelineJob`]
//! carrying the sandbox handle and — crucially — the instance's RAII
//! [`Reservation`]. The reservation is what makes the pipeline safe:
//! routing and policy both skip reserved instances, so no request or
//! competing action can race the in-flight I/O, and it is released
//! (dropped) only after the finish completes, at which point the instance
//! is a fully-transitioned, routable container.
//!
//! Ordering contract for determinism: a worker (1) folds the job's
//! counters into the shared [`Metrics`], (2) drops the reservation, and
//! only then (3) decrements the pending gauge. [`InstancePipeline::drain`]
//! therefore guarantees that once pending hits zero, every transitioned
//! instance is visible, unreserved, and fully accounted — which is what
//! lets the replay engine drain after each tick batch and stay
//! bit-identical at any worker count ([`crate::replay`]).
//!
//! Backpressure is the platform's job (it owns the shed policy — see
//! `policy.pipeline_queue_cap`); the pipeline only exposes its queue
//! depth, mirrored into the metrics gauge so operators can watch it.
//!
//! Errors from a finish are stashed and surface at the next
//! [`InstancePipeline::reap`]/[`InstancePipeline::drain`] (i.e. the next
//! policy tick), mirroring how an async kernel writeback error surfaces
//! later.

use super::metrics::Metrics;
use super::pool::Reservation;
use crate::container::sandbox::Sandbox;
use crate::simtime::Clock;
use anyhow::{Context as _, Result};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Which expensive half a job runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// [`Sandbox::hibernate_finish`] — the state flip already happened.
    Deflate,
    /// [`Sandbox::wake_finish`] — the state flip already happened.
    Inflate,
    /// [`Sandbox::terminate`] — no prior flip; the reservation fences it.
    Teardown,
}

impl JobKind {
    fn verb(self) -> &'static str {
        match self {
            JobKind::Deflate => "deflating",
            JobKind::Inflate => "inflating",
            JobKind::Teardown => "evicting",
        }
    }
}

/// A lifecycle finish handed to the pipeline; the reservation rides along
/// and is released when the finish completes.
pub struct PipelineJob {
    pub workload: String,
    pub sandbox: Arc<Mutex<Sandbox>>,
    pub reservation: Reservation,
    pub kind: JobKind,
}

/// Test-only hook invoked by a worker before it starts a job — lets a
/// stress test hold a deflation or inflation in flight deterministically.
pub type PipelineGate = Arc<dyn Fn() + Send + Sync>;

#[derive(Default)]
struct PoolState {
    /// Jobs queued or running.
    pending: usize,
    /// Finishes completed since the last reap.
    completed: u64,
    /// Errors collected since the last reap.
    errors: Vec<anyhow::Error>,
}

struct Shared {
    state: Mutex<PoolState>,
    idle: Condvar,
    metrics: Arc<Metrics>,
    gate: Mutex<Option<PipelineGate>>,
}

/// The instance-I/O worker pool. With zero workers it is a pass-through:
/// [`InstancePipeline::run_sync`] executes the finish inline (the baseline
/// the benches compare against, and the shed fallback).
pub struct InstancePipeline {
    tx: Option<mpsc::Sender<PipelineJob>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl InstancePipeline {
    pub fn new(workers: usize, metrics: Arc<Metrics>) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            idle: Condvar::new(),
            metrics,
            gate: Mutex::new(None),
        });
        if workers == 0 {
            return Self {
                tx: None,
                workers: Vec::new(),
                shared,
            };
        }
        let (tx, rx) = mpsc::channel::<PipelineJob>();
        // Lifecycle I/O is low-rate (policy cadence), so a shared receiver
        // is fine here — contention is on job *arrival*, execution runs in
        // parallel once a worker holds its job.
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = rx.clone();
                let shared = shared.clone();
                std::thread::spawn(move || loop {
                    let job = match rx.lock().unwrap().recv() {
                        Ok(job) => job,
                        Err(_) => return, // channel closed: pool dropping
                    };
                    run_job(&shared, job);
                })
            })
            .collect();
        Self {
            tx: Some(tx),
            workers: handles,
            shared,
        }
    }

    /// Does this pipeline actually run jobs asynchronously?
    pub fn is_async(&self) -> bool {
        self.tx.is_some()
    }

    /// Queue a job. The pending gauge is bumped *before* the send so a
    /// concurrent [`Self::drain`] can never miss the job.
    pub fn submit(&self, job: PipelineJob) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.pending += 1;
            self.shared
                .metrics
                .counters
                .pipeline_depth
                .store(st.pending as u64, Ordering::Relaxed);
        }
        let tx = self.tx.as_ref().expect("submit on a synchronous pipeline");
        if let Err(mpsc::SendError(job)) = tx.send(job) {
            // Workers are only gone while the pipeline is being torn down;
            // finish inline rather than losing the transition.
            run_job(&self.shared, job);
        }
    }

    /// Synchronous fallback (`pipeline_workers = 0`, or a shed job): run
    /// the finish inline on the caller's thread. Same accounting, no queue.
    pub fn run_sync(&self, job: PipelineJob) -> Result<()> {
        let PipelineJob {
            workload,
            sandbox,
            reservation,
            kind,
        } = job;
        let result = run_one(&self.shared.metrics, kind, &workload, &sandbox);
        drop(reservation);
        result
    }

    /// Jobs queued or in flight right now.
    pub fn pending(&self) -> usize {
        self.shared.state.lock().unwrap().pending
    }

    /// Non-blocking: collect completions since the last reap. All stashed
    /// errors are logged; the first is returned (annotated with how many
    /// more there were, so a batch of failures is never mistaken for a
    /// single one). Returns the number reaped on success.
    pub fn reap(&self) -> Result<u64> {
        let mut st = self.shared.state.lock().unwrap();
        let n = st.completed;
        st.completed = 0;
        let mut errors = std::mem::take(&mut st.errors);
        drop(st);
        if errors.is_empty() {
            return Ok(n);
        }
        for e in errors.iter().skip(1) {
            eprintln!("pipeline error (additional): {e:#}");
        }
        let count = errors.len();
        let first = errors.swap_remove(0);
        Err(if count > 1 {
            first.context(format!(
                "plus {} more pipeline error(s), logged to stderr",
                count - 1
            ))
        } else {
            first
        })
    }

    /// Block until every queued/in-flight job has completed, then reap.
    /// After this returns Ok, every submitted instance is transitioned,
    /// unreserved and folded into the metrics.
    pub fn drain(&self) -> Result<u64> {
        let mut st = self.shared.state.lock().unwrap();
        while st.pending > 0 {
            st = self.shared.idle.wait(st).unwrap();
        }
        drop(st);
        self.reap()
    }

    /// Install (or clear) the test gate — see [`PipelineGate`].
    #[doc(hidden)]
    pub fn set_gate(&self, gate: Option<PipelineGate>) {
        *self.shared.gate.lock().unwrap() = gate;
    }
}

impl Drop for InstancePipeline {
    fn drop(&mut self) {
        // Closing the channel lets each worker finish its backlog and exit
        // on Disconnected; joining guarantees no job outlives the pool.
        self.tx = None;
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn run_job(shared: &Shared, job: PipelineJob) {
    let gate = shared.gate.lock().unwrap().clone();
    if let Some(gate) = gate {
        gate();
    }
    let PipelineJob {
        workload,
        sandbox,
        reservation,
        kind,
    } = job;
    let result = run_one(&shared.metrics, kind, &workload, &sandbox);
    // Release the instance before announcing completion: a drainer must
    // observe the transitioned instance as routable the moment pending
    // drops.
    drop(reservation);
    let mut st = shared.state.lock().unwrap();
    st.pending -= 1;
    st.completed += 1;
    shared
        .metrics
        .counters
        .pipeline_depth
        .store(st.pending as u64, Ordering::Relaxed);
    if let Err(e) = result {
        st.errors.push(e);
    }
    drop(st);
    shared.idle.notify_all();
}

/// Run one finish and fold its counters into the metrics. Used by both the
/// async workers and the sync fallback, so the two modes are
/// observationally identical.
fn run_one(
    metrics: &Metrics,
    kind: JobKind,
    workload: &str,
    sandbox: &Arc<Mutex<Sandbox>>,
) -> Result<()> {
    // Lifecycle I/O's charged time belongs to no request — it runs on the
    // platform's dime, like kernel writeback.
    let clock = Clock::new();
    let mut sb = sandbox.lock().unwrap();
    let fail = || format!("{} an instance of `{workload}`", kind.verb());
    match kind {
        JobKind::Deflate => {
            let before = sb.swap_stats();
            sb.hibernate_finish(&clock).with_context(fail)?;
            let after = sb.swap_stats();
            if after.reap_swapouts > before.reap_swapouts {
                metrics
                    .counters
                    .reap_hibernations
                    .fetch_add(1, Ordering::Relaxed);
            }
            metrics.counters.pages_swapped_out.fetch_add(
                (after.pages_swapped_out + after.reap_pages_out)
                    - (before.pages_swapped_out + before.reap_pages_out),
                Ordering::Relaxed,
            );
        }
        JobKind::Inflate => {
            sb.wake_finish(&clock).with_context(fail)?;
        }
        JobKind::Teardown => {
            sb.terminate().with_context(fail)?;
            metrics.counters.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }
    Ok(())
}
