//! Configuration system: a typed platform config loadable from a TOML-subset
//! file plus `key=value` CLI overrides.
//!
//! The offline registry has no serde/toml, so [`toml_lite`] implements the
//! subset the configs use: `[section]` headers, `key = value` with string /
//! integer / float / boolean / size-literal (`"512MiB"`) values, `#`
//! comments.

pub mod toml_lite;

use crate::simtime::CostModel;
use anyhow::{bail, Context, Result};
use std::path::Path;
use toml_lite::{Table, Value};

/// One tenant's budget row (`[tenants.<name>]`). Tenancy is parsed from
/// workload names by `platform::policy::tenant_of` (the `tNN-` prefix
/// convention).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantBudget {
    pub name: String,
    /// Explicit live-byte budget. `None` = the tenant shares what the
    /// host budget leaves after explicit grants, proportionally to
    /// `weight`.
    pub memory_budget: Option<u64>,
    /// Weight for the shared split (default 1.0; must be > 0).
    pub weight: f64,
}

/// Hibernation/keep-alive policy knobs.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Idle time after which a Warm container becomes a hibernate candidate.
    pub hibernate_idle_ms: u64,
    /// Idle time after which a Hibernate container is evicted entirely.
    pub evict_idle_ms: u64,
    /// Host memory budget for all sandboxes (bytes). Crossing it triggers
    /// hibernate-instead-of-evict deflation of idle Warm containers.
    pub memory_budget: u64,
    /// Fraction of the budget that triggers proactive deflation.
    pub pressure_watermark: f64,
    /// Enable the anticipatory wake-up predictor (SIGCONT path, Fig. 3 ⑤).
    pub predictive_wakeup: bool,
    /// Use REAP batch swap-in (vs page-fault swap-in) on wake.
    pub reap_enabled: bool,
    /// Incremental policy cadence: each [`policy_tick`] call covers only
    /// `ceil(shards / tick_stride)` shards, rotating round-robin, so at high
    /// function counts a single tick never freezes behind a full control
    /// plane walk. `1` (the default) = every tick covers every shard.
    ///
    /// [`policy_tick`]: crate::platform::Platform::policy_tick
    pub tick_stride: usize,
    /// Instance-pipeline worker threads: the policy tick performs only the
    /// cheap state flip per instance (SIGSTOP, SIGCONT — nothing at all
    /// for evictions) and hands the expensive I/O — deflation swap/release,
    /// anticipatory REAP prefetch, eviction teardown — to this pool (the
    /// instance's reservation keeps requests off it meanwhile; completions
    /// are reaped at the next tick). `0` = run the I/O synchronously
    /// inside the tick (the old behavior — useful as a baseline and for
    /// the bench comparison). The TOML key `deflate_workers` is accepted
    /// as a legacy alias.
    pub pipeline_workers: usize,
    /// Backpressure cap on the pipeline queue (jobs queued + in flight).
    /// On overflow the newest-idle submissions are shed: deflations and
    /// teardowns fall back to running inline on the tick (self-throttling
    /// the control loop instead of letting a pressure storm queue
    /// hundreds of instances), anticipatory inflations are skipped
    /// entirely (benign — the predicted request demand-wakes). `0` =
    /// unbounded. Sheds are counted in `metrics.counters.pipeline_sheds`;
    /// strict-determinism replay forces this to 0 (shed decisions depend
    /// on real-time queue depth).
    pub pipeline_queue_cap: usize,
    /// Which [`Policy`](crate::platform::policy::Policy) makes keep-alive
    /// decisions: `"hibernate"` (the paper's platform, the default),
    /// `"warm-only"` (the conventional evicting baseline) or
    /// `"tenant-fair"` (hibernate + per-tenant budget enforcement).
    pub kind: String,
    /// Learn the anticipatory wake lead per function (EWMA of measured
    /// inflation durations, clamped to [5 ms, 250 ms]); `false` pins the
    /// classic 50 ms constant. The constant seeds the EWMA either way, so
    /// the first wake of every function behaves identically.
    pub adaptive_wake_lead: bool,
    /// Split the host memory budget into per-shard *leases* (proportional
    /// to per-shard committed bytes at each reconciliation) and let every
    /// shard take pressure decisions against its lease plus its live
    /// local usage — deterministic at any replay worker count, and
    /// sharper under tight budgets than the epoch-stale global snapshot.
    pub pressure_leases: bool,
    /// Per-tenant budget rows (`[tenants.<name>]`), sorted by name.
    /// Tenants observed in workload names but not listed here get a
    /// weight-1.0 share of the unexplicit remainder.
    pub tenants: Vec<TenantBudget>,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            hibernate_idle_ms: 2_000,
            evict_idle_ms: 600_000,
            memory_budget: 2 << 30,
            pressure_watermark: 0.85,
            predictive_wakeup: true,
            reap_enabled: true,
            tick_stride: 1,
            pipeline_workers: 2,
            pipeline_queue_cap: 128,
            kind: "hibernate".to_string(),
            adaptive_wake_lead: true,
            pressure_leases: false,
            tenants: Vec::new(),
        }
    }
}

impl PolicyConfig {
    /// Does this config maintain the per-tenant ledger? True for the
    /// tenant-fair policy and whenever tenant budgets are configured.
    pub fn tracks_tenants(&self) -> bool {
        matches!(self.kind.as_str(), "tenant-fair" | "tenant_fair") || !self.tenants.is_empty()
    }

    /// The configured budget row for tenant `name`, if any.
    pub fn tenant_cfg(&self, name: &str) -> Option<&TenantBudget> {
        self.tenants
            .binary_search_by(|t| t.name.as_str().cmp(name))
            .ok()
            .map(|i| &self.tenants[i])
    }
}

/// Parallel trace-replay knobs (`[replay]` section) — see
/// [`crate::replay`] for the determinism model these feed.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Replay worker threads. `0` = auto: one per available CPU (clamped to
    /// the shard count — a worker without shards has nothing to do).
    pub workers: usize,
    /// Epoch barrier cadence in *virtual* milliseconds: global memory
    /// pressure is reconciled once per epoch, which is what keeps policy
    /// decisions reproducible across worker counts.
    pub epoch_ms: u64,
    /// Policy tick cadence in virtual milliseconds. `0` = derive from the
    /// policy (half the hibernate idle threshold, ≥ 1 ms) — the same rule
    /// single-threaded replay has always used.
    pub tick_ms: u64,
    /// Disable cross-sandbox file-page sharing for replay platforms. Shared
    /// page-cache hits depend on which sandbox faulted a page first — a
    /// worker-interleaving artifact — so bit-identical replay turns sharing
    /// off. Set to `false` to measure sharing effects (per-run results stay
    /// reproducible only at `workers = 1`).
    pub strict_determinism: bool,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            epoch_ms: 100,
            tick_ms: 0,
            strict_determinism: true,
        }
    }
}

/// I/O-backend knobs (`[io]` section) — see
/// [`crate::platform::io_backend`] for the scheduling contract these feed.
#[derive(Debug, Clone)]
pub struct IoConfig {
    /// Which backend executes the pipeline's batch slot-run I/O:
    /// `"sync"` (inline on the submitting thread — byte-for-byte the
    /// pre-backend behavior, the default) or `"batched"` (two-queue
    /// worker pool with strict latency priority, bounded batches, and an
    /// in-flight byte budget).
    pub backend: String,
    /// Batched-backend worker threads (clamped to ≥ 1).
    pub workers: usize,
    /// In-flight byte budget for throughput-class submissions: admission
    /// of the next deflation chunk waits while `inflight + chunk` would
    /// exceed this (a solo chunk always proceeds; latency-class work is
    /// never throttled).
    pub max_inflight_bytes: u64,
    /// Throughput submissions are chopped into chunks of at most this
    /// many pages; every boundary is a point where a queued wake may
    /// overtake (clamped to ≥ 1).
    pub batch_pages: u64,
}

impl Default for IoConfig {
    fn default() -> Self {
        Self {
            backend: "sync".to_string(),
            workers: 2,
            max_inflight_bytes: 32 << 20,
            batch_pages: 1024,
        }
    }
}

/// Observability knobs (`[obs]` section) — see [`crate::obs`] for the
/// flight recorder these feed.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Flight-recorder ring capacity, in events per shard (overwrite-oldest
    /// with a drop counter once full; clamped to ≥ 1). 64Ki 48-byte events
    /// ≈ 3 MiB per shard.
    pub ring_events: u64,
    /// Master switch for the flight recorder. Histogram latency metrics
    /// stay on regardless — only span-event recording is gated.
    pub enabled: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            ring_events: 64 << 10,
            enabled: true,
        }
    }
}

/// Durability knobs (`[durability]` section) — see `docs/durability.md`
/// for the checksum / manifest / retry / degrade-ladder contract these
/// feed.
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// Transient slot-file I/O failures (EINTR/EAGAIN/ETIMEDOUT class)
    /// are retried up to this many times with exponential backoff before
    /// the image is invalidated. `0` = fail on the first error.
    pub io_retries: u64,
    /// Backoff before retry attempt `n` (1-based) is
    /// `backoff_base_us << (n - 1)` microseconds, charged to the
    /// *virtual* clock so replay fingerprints stay worker-count
    /// independent.
    pub backoff_base_us: u64,
    /// Verify the recorded per-page checksum on every slot read. A
    /// mismatch is a typed integrity error — the page is never served.
    pub verify_checksums: bool,
    /// Scan the swap directory for image manifests at platform
    /// construction and re-register their instances as Hibernate, so a
    /// restarted host wakes instead of cold-starting.
    pub adopt_on_start: bool,
    /// After a REAP swap-out, compact the REAP file when live slots have
    /// fallen below this fraction of its high-water length (`0` =
    /// never compact).
    pub compact_min_live_frac: f64,
}

impl Default for DurabilityConfig {
    fn default() -> Self {
        Self {
            io_retries: 2,
            backoff_base_us: 50,
            verify_checksums: true,
            adopt_on_start: true,
            compact_min_live_frac: 0.5,
        }
    }
}

/// Deterministic fault-injection knobs (`[chaos]` section) — see
/// `docs/resilience.md` for the fault-plan contract these feed. The plan
/// is a pure function of `(seed, workload, fault kind, invocation index)`
/// and every injected fault is stamped on the virtual clock, so a chaos
/// run joins the 1-vs-N replay bit-identity sweep.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Master switch. Off by default: a disabled plan injects nothing and
    /// costs nothing on the request path.
    pub enabled: bool,
    /// Fault-plan seed, independent of the trace seed so the same traffic
    /// can be replayed under different fault plans.
    pub seed: u64,
    /// Per-mille of routed requests whose sandbox crashes mid-request
    /// (the guest dies; the platform re-adopts the hibernated image or
    /// cold-starts a replacement).
    pub crash_per_mille: u64,
    /// Per-mille of requests that fail with a typed `Poisoned` error —
    /// the "fails every Nth invocation" bad deploy, food for the circuit
    /// breaker.
    pub poison_per_mille: u64,
    /// Per-mille of requests charged `slow_io_ns` of extra virtual I/O
    /// latency (the PR 8 transient-I/O taxonomy, on the virtual clock).
    pub slow_io_per_mille: u64,
    /// Virtual nanoseconds one slow-I/O fault charges.
    pub slow_io_ns: u64,
    /// Per-mille of anticipatory inflation (wake) jobs that hang: the job
    /// charges `hang_ns` of virtual time and the pipeline watchdog
    /// cancels it.
    pub hang_per_mille: u64,
    /// Per-mille of deflation/teardown jobs that stall the same way.
    pub stall_per_mille: u64,
    /// Per-mille of pipeline jobs that panic mid-job (exercises the
    /// `catch_unwind` fence; the reservation must still release and
    /// `drain` must still complete).
    pub panic_per_mille: u64,
    /// Virtual nanoseconds a hung/stalled job burns before the watchdog
    /// sees it (must exceed `resilience.watchdog_budget_ms` to trip).
    pub hang_ns: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            seed: 0xC4A0_5EED,
            crash_per_mille: 0,
            poison_per_mille: 0,
            slow_io_per_mille: 0,
            slow_io_ns: 2_000_000,
            hang_per_mille: 0,
            stall_per_mille: 0,
            panic_per_mille: 0,
            hang_ns: 120_000_000_000,
        }
    }
}

impl ChaosConfig {
    /// Enable the plan under `seed`, filling in the default fault mix for
    /// any per-mille knob left at 0 — the `--chaos-seed` CLI path, which
    /// must light up every fault family without a config file.
    pub fn enable_with_seed(&mut self, seed: u64) {
        self.enabled = true;
        self.seed = seed;
        if self.crash_per_mille == 0
            && self.poison_per_mille == 0
            && self.slow_io_per_mille == 0
            && self.hang_per_mille == 0
            && self.stall_per_mille == 0
            && self.panic_per_mille == 0
        {
            self.crash_per_mille = 40;
            self.poison_per_mille = 60;
            self.slow_io_per_mille = 80;
            self.hang_per_mille = 120;
            self.stall_per_mille = 80;
            self.panic_per_mille = 60;
        }
    }

    /// Any fault family active?
    pub fn any_faults(&self) -> bool {
        self.enabled
            && (self.crash_per_mille > 0
                || self.poison_per_mille > 0
                || self.slow_io_per_mille > 0
                || self.hang_per_mille > 0
                || self.stall_per_mille > 0
                || self.panic_per_mille > 0)
    }
}

/// Self-healing knobs (`[resilience]` section): request deadlines, the
/// pipeline watchdog, and the per-function circuit breaker. All state
/// these feed is deterministic on the virtual clock; all counters stay
/// outside the replay fingerprint (like `DurabilityStats`).
#[derive(Debug, Clone)]
pub struct ResilienceConfig {
    /// Server-side request deadline (wall-clock milliseconds): a queued
    /// submission older than this is shed with a typed `TimedOut` error
    /// instead of being served. `0` = no deadline.
    pub request_deadline_ms: u64,
    /// Pipeline watchdog budget (virtual milliseconds): a pipeline job
    /// whose charged virtual time exceeds this is cancelled — its
    /// reservation releases and its instance retires through the degrade
    /// ladder. `0` = watchdog off.
    pub watchdog_budget_ms: u64,
    /// Circuit-breaker sliding window: the breaker looks at the last
    /// `breaker_window` request outcomes per function (clamped to ≥ 1).
    pub breaker_window: u64,
    /// Failures within the window that open the breaker (quarantine the
    /// function). `0` = breaker off.
    pub breaker_failures: u64,
    /// Quarantine duration in virtual milliseconds; after it the breaker
    /// goes half-open and admits probe requests.
    pub quarantine_ms: u64,
    /// Consecutive half-open probe successes that close the breaker
    /// (clamped to ≥ 1). A probe failure re-opens for another
    /// `quarantine_ms`.
    pub probe_successes: u64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self {
            request_deadline_ms: 0,
            watchdog_budget_ms: 30_000,
            breaker_window: 16,
            breaker_failures: 8,
            quarantine_ms: 2_000,
            probe_successes: 2,
        }
    }
}

/// Memory-sharing policy (§3.5): the paper shares the Quark runtime binary
/// across sandboxes and keeps language-runtime binaries private per tenant.
#[derive(Debug, Clone)]
pub struct SharingConfig {
    /// Share the container-runtime binary file pages across sandboxes.
    pub share_runtime_binary: bool,
    /// Share language-runtime binary pages (node/python/...). Off by
    /// default: cross-tenant side-channel risk; the §3.5 ablation turns it
    /// on to reproduce the 25 ms → 11 ms result.
    pub share_language_runtime: bool,
}

impl Default for SharingConfig {
    fn default() -> Self {
        Self {
            share_runtime_binary: true,
            share_language_runtime: false,
        }
    }
}

/// Top-level platform configuration.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Host "guest-physical" memory region size (bytes).
    pub host_memory: u64,
    /// Directory holding AOT artifacts (`*.hlo.txt` + manifest.json).
    pub artifacts_dir: String,
    /// Directory for per-sandbox swap/REAP files.
    pub swap_dir: String,
    /// Number of platform worker threads.
    pub workers: usize,
    /// Control-plane shards (per-shard pool/spec locking). `0` = auto: one
    /// shard per available CPU.
    pub shards: usize,
    /// Deterministic seed for traces and page content.
    pub seed: u64,
    /// Sidecar file for per-workload predictor arrival tracks (versioned
    /// CSV). Non-empty: loaded at platform construction, written by
    /// [`crate::platform::Platform::save_predictor_state`] (the threaded
    /// server saves on shutdown), so anticipatory wake-up survives
    /// restarts. Empty = persistence off.
    pub predictor_state_file: String,
    pub policy: PolicyConfig,
    pub sharing: SharingConfig,
    pub replay: ReplayConfig,
    pub io: IoConfig,
    pub obs: ObsConfig,
    pub durability: DurabilityConfig,
    pub chaos: ChaosConfig,
    pub resilience: ResilienceConfig,
    pub cost: CostModel,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        Self {
            host_memory: 4 << 30,
            artifacts_dir: "artifacts".to_string(),
            swap_dir: std::env::temp_dir()
                .join("quark-hibernate-swap")
                .to_string_lossy()
                .into_owned(),
            workers: 4,
            shards: 0,
            seed: 0xFEED_BEEF,
            predictor_state_file: String::new(),
            policy: PolicyConfig::default(),
            sharing: SharingConfig::default(),
            replay: ReplayConfig::default(),
            io: IoConfig::default(),
            obs: ObsConfig::default(),
            durability: DurabilityConfig::default(),
            chaos: ChaosConfig::default(),
            resilience: ResilienceConfig::default(),
            cost: CostModel::paper(),
        }
    }
}

fn get_u64(t: &Table, section: &str, key: &str, out: &mut u64) -> Result<()> {
    if let Some(v) = t.get2(section, key) {
        *out = v
            .as_u64()
            .with_context(|| format!("{section}.{key} must be an integer or size literal"))?;
    }
    Ok(())
}

fn get_f64(t: &Table, section: &str, key: &str, out: &mut f64) -> Result<()> {
    if let Some(v) = t.get2(section, key) {
        *out = v
            .as_f64()
            .with_context(|| format!("{section}.{key} must be a number"))?;
    }
    Ok(())
}

fn get_bool(t: &Table, section: &str, key: &str, out: &mut bool) -> Result<()> {
    if let Some(v) = t.get2(section, key) {
        *out = match v {
            Value::Bool(b) => *b,
            _ => bail!("{section}.{key} must be a boolean"),
        };
    }
    Ok(())
}

fn get_str(t: &Table, section: &str, key: &str, out: &mut String) -> Result<()> {
    if let Some(v) = t.get2(section, key) {
        *out = match v {
            Value::Str(s) => s.clone(),
            _ => bail!("{section}.{key} must be a string"),
        };
    }
    Ok(())
}

impl PlatformConfig {
    /// Load from a TOML-subset file, starting from defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_str(&text)
    }

    /// Parse from text (defaults + overrides).
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(text: &str) -> Result<Self> {
        let t = toml_lite::parse(text)?;
        let mut c = Self::default();
        c.apply_table(&t)?;
        Ok(c)
    }

    fn apply_table(&mut self, t: &Table) -> Result<()> {
        get_u64(t, "", "host_memory", &mut self.host_memory)?;
        get_str(t, "", "artifacts_dir", &mut self.artifacts_dir)?;
        get_str(t, "", "swap_dir", &mut self.swap_dir)?;
        let mut workers = self.workers as u64;
        get_u64(t, "", "workers", &mut workers)?;
        self.workers = workers.max(1) as usize;
        let mut shards = self.shards as u64;
        get_u64(t, "", "shards", &mut shards)?;
        self.shards = shards as usize;
        get_u64(t, "", "seed", &mut self.seed)?;
        get_str(t, "", "predictor_state_file", &mut self.predictor_state_file)?;

        get_u64(t, "policy", "hibernate_idle_ms", &mut self.policy.hibernate_idle_ms)?;
        get_u64(t, "policy", "evict_idle_ms", &mut self.policy.evict_idle_ms)?;
        get_u64(t, "policy", "memory_budget", &mut self.policy.memory_budget)?;
        get_f64(t, "policy", "pressure_watermark", &mut self.policy.pressure_watermark)?;
        get_bool(t, "policy", "predictive_wakeup", &mut self.policy.predictive_wakeup)?;
        get_bool(t, "policy", "reap_enabled", &mut self.policy.reap_enabled)?;
        let mut tick_stride = self.policy.tick_stride as u64;
        get_u64(t, "policy", "tick_stride", &mut tick_stride)?;
        self.policy.tick_stride = (tick_stride as usize).max(1);
        let mut pipeline_workers = self.policy.pipeline_workers as u64;
        // Legacy alias first, so the new key wins when both are present.
        get_u64(t, "policy", "deflate_workers", &mut pipeline_workers)?;
        get_u64(t, "policy", "pipeline_workers", &mut pipeline_workers)?;
        self.policy.pipeline_workers = pipeline_workers as usize;
        let mut pipeline_queue_cap = self.policy.pipeline_queue_cap as u64;
        get_u64(t, "policy", "pipeline_queue_cap", &mut pipeline_queue_cap)?;
        self.policy.pipeline_queue_cap = pipeline_queue_cap as usize;
        get_str(t, "policy", "kind", &mut self.policy.kind)?;
        get_bool(t, "policy", "adaptive_wake_lead", &mut self.policy.adaptive_wake_lead)?;
        get_bool(t, "policy", "pressure_leases", &mut self.policy.pressure_leases)?;

        // `[tenants.<name>]` sections (and the `tenants.<name>.<field>`
        // override spelling, which lands as section "tenants" with a
        // dotted key). Later tables — CLI overrides — update rows in
        // place.
        for (section, key, value) in t.iter() {
            let (name, field) = if let Some(rest) = section.strip_prefix("tenants.") {
                (rest, key)
            } else if section == "tenants" {
                match key.split_once('.') {
                    Some((name, field)) => (name, field),
                    None => bail!(
                        "tenants.{key}: tenant options are nested — use \
                         [tenants.{key}] memory_budget/weight (or the \
                         tenants.{key}.memory_budget override form)"
                    ),
                }
            } else {
                continue;
            };
            if name.is_empty() {
                bail!("[tenants.]: empty tenant name");
            }
            // Tenancy is parsed from workload names by
            // `platform::policy::tenant_of` — the lowercase `tNN-` prefix
            // convention. A row no workload can ever match would silently
            // do nothing while its explicit grant still shrank every real
            // tenant's weight share, so reject it here.
            let digits = name.strip_prefix('t').unwrap_or("");
            if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
                bail!(
                    "[tenants.{name}]: tenant names follow the workload \
                     prefix convention `t<digits>` (e.g. t00) — no \
                     workload would ever be charged to `{name}`"
                );
            }
            // Find-or-insert by index (binding the `find` borrow across
            // the insert arm is NLL problem case #3 — rejected).
            let idx = match self.policy.tenants.iter().position(|r| r.name == name) {
                Some(i) => i,
                None => {
                    self.policy.tenants.push(TenantBudget {
                        name: name.to_string(),
                        memory_budget: None,
                        weight: 1.0,
                    });
                    self.policy.tenants.len() - 1
                }
            };
            let row = &mut self.policy.tenants[idx];
            match field {
                "memory_budget" => {
                    row.memory_budget = Some(value.as_u64().with_context(|| {
                        format!("tenants.{name}.memory_budget must be an integer or size literal")
                    })?);
                }
                "weight" => {
                    let w = value
                        .as_f64()
                        .with_context(|| format!("tenants.{name}.weight must be a number"))?;
                    if w <= 0.0 {
                        bail!("tenants.{name}.weight must be > 0");
                    }
                    row.weight = w;
                }
                other => bail!("unknown tenant option tenants.{name}.{other}"),
            }
        }
        self.policy.tenants.sort_by(|a, b| a.name.cmp(&b.name));

        let mut replay_workers = self.replay.workers as u64;
        get_u64(t, "replay", "workers", &mut replay_workers)?;
        self.replay.workers = replay_workers as usize;
        get_u64(t, "replay", "epoch_ms", &mut self.replay.epoch_ms)?;
        get_u64(t, "replay", "tick_ms", &mut self.replay.tick_ms)?;
        get_bool(
            t,
            "replay",
            "strict_determinism",
            &mut self.replay.strict_determinism,
        )?;

        get_str(t, "io", "backend", &mut self.io.backend)?;
        let mut io_workers = self.io.workers as u64;
        get_u64(t, "io", "workers", &mut io_workers)?;
        self.io.workers = (io_workers as usize).max(1);
        get_u64(t, "io", "max_inflight_bytes", &mut self.io.max_inflight_bytes)?;
        get_u64(t, "io", "batch_pages", &mut self.io.batch_pages)?;
        self.io.batch_pages = self.io.batch_pages.max(1);

        get_u64(t, "obs", "ring_events", &mut self.obs.ring_events)?;
        self.obs.ring_events = self.obs.ring_events.max(1);
        get_bool(t, "obs", "enabled", &mut self.obs.enabled)?;

        get_u64(t, "durability", "io_retries", &mut self.durability.io_retries)?;
        get_u64(t, "durability", "backoff_base_us", &mut self.durability.backoff_base_us)?;
        get_bool(
            t,
            "durability",
            "verify_checksums",
            &mut self.durability.verify_checksums,
        )?;
        get_bool(t, "durability", "adopt_on_start", &mut self.durability.adopt_on_start)?;
        get_f64(
            t,
            "durability",
            "compact_min_live_frac",
            &mut self.durability.compact_min_live_frac,
        )?;

        get_bool(t, "chaos", "enabled", &mut self.chaos.enabled)?;
        get_u64(t, "chaos", "seed", &mut self.chaos.seed)?;
        get_u64(t, "chaos", "crash_per_mille", &mut self.chaos.crash_per_mille)?;
        get_u64(t, "chaos", "poison_per_mille", &mut self.chaos.poison_per_mille)?;
        get_u64(t, "chaos", "slow_io_per_mille", &mut self.chaos.slow_io_per_mille)?;
        get_u64(t, "chaos", "slow_io_ns", &mut self.chaos.slow_io_ns)?;
        get_u64(t, "chaos", "hang_per_mille", &mut self.chaos.hang_per_mille)?;
        get_u64(t, "chaos", "stall_per_mille", &mut self.chaos.stall_per_mille)?;
        get_u64(t, "chaos", "panic_per_mille", &mut self.chaos.panic_per_mille)?;
        get_u64(t, "chaos", "hang_ns", &mut self.chaos.hang_ns)?;

        get_u64(
            t,
            "resilience",
            "request_deadline_ms",
            &mut self.resilience.request_deadline_ms,
        )?;
        get_u64(
            t,
            "resilience",
            "watchdog_budget_ms",
            &mut self.resilience.watchdog_budget_ms,
        )?;
        get_u64(t, "resilience", "breaker_window", &mut self.resilience.breaker_window)?;
        self.resilience.breaker_window = self.resilience.breaker_window.max(1);
        get_u64(
            t,
            "resilience",
            "breaker_failures",
            &mut self.resilience.breaker_failures,
        )?;
        get_u64(t, "resilience", "quarantine_ms", &mut self.resilience.quarantine_ms)?;
        get_u64(
            t,
            "resilience",
            "probe_successes",
            &mut self.resilience.probe_successes,
        )?;
        self.resilience.probe_successes = self.resilience.probe_successes.max(1);

        get_bool(t, "sharing", "share_runtime_binary", &mut self.sharing.share_runtime_binary)?;
        get_bool(
            t,
            "sharing",
            "share_language_runtime",
            &mut self.sharing.share_language_runtime,
        )?;

        get_u64(t, "cost", "guest_host_switch_ns", &mut self.cost.guest_host_switch_ns)?;
        get_u64(t, "cost", "ssd_random_read_bw", &mut self.cost.ssd_random_read_bw)?;
        get_u64(t, "cost", "ssd_seq_read_bw", &mut self.cost.ssd_seq_read_bw)?;
        get_u64(t, "cost", "ssd_write_bw", &mut self.cost.ssd_write_bw)?;
        get_u64(t, "cost", "ssd_op_latency_ns", &mut self.cost.ssd_op_latency_ns)?;
        get_u64(t, "cost", "sandbox_startup_ns", &mut self.cost.sandbox_startup_ns)?;

        if self.policy.pressure_watermark <= 0.0 || self.policy.pressure_watermark > 1.0 {
            bail!("policy.pressure_watermark must be in (0, 1]");
        }
        if self.replay.epoch_ms == 0 {
            bail!("replay.epoch_ms must be ≥ 1");
        }
        if !matches!(self.io.backend.as_str(), "sync" | "batched") {
            bail!("io.backend must be \"sync\" or \"batched\", got `{}`", self.io.backend);
        }
        if !(0.0..=1.0).contains(&self.durability.compact_min_live_frac) {
            bail!("durability.compact_min_live_frac must be in [0, 1]");
        }
        for (name, v) in [
            ("crash_per_mille", self.chaos.crash_per_mille),
            ("poison_per_mille", self.chaos.poison_per_mille),
            ("slow_io_per_mille", self.chaos.slow_io_per_mille),
            ("hang_per_mille", self.chaos.hang_per_mille),
            ("stall_per_mille", self.chaos.stall_per_mille),
            ("panic_per_mille", self.chaos.panic_per_mille),
        ] {
            // 1000‰ crashes would retry-crash every recovered request
            // forever; cap every family below certainty.
            if v >= 1000 {
                bail!("chaos.{name} must be < 1000, got {v}");
            }
        }
        if self.resilience.breaker_failures > self.resilience.breaker_window {
            bail!(
                "resilience.breaker_failures ({}) cannot exceed breaker_window ({})",
                self.resilience.breaker_failures,
                self.resilience.breaker_window
            );
        }
        Ok(())
    }

    /// Apply `section.key=value` CLI overrides.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<()> {
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .with_context(|| format!("override `{ov}` must be key=value"))?;
            let text = if k.contains('.') {
                let (section, key) = k.split_once('.').unwrap();
                format!("[{section}]\n{key} = {v}\n")
            } else {
                format!("{k} = {v}\n")
            };
            let t = toml_lite::parse(&text)
                .with_context(|| format!("parsing override `{ov}`"))?;
            self.apply_table(&t)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = PlatformConfig::default();
        assert!(c.policy.memory_budget > 0);
        assert!(c.sharing.share_runtime_binary);
        assert!(!c.sharing.share_language_runtime);
    }

    #[test]
    fn parse_full_config() {
        let c = PlatformConfig::from_str(
            r#"
            host_memory = "1GiB"
            workers = 8
            shards = 16
            seed = 7

            [policy]
            hibernate_idle_ms = 500
            memory_budget = "256MiB"
            pressure_watermark = 0.9
            reap_enabled = false

            [sharing]
            share_language_runtime = true

            [cost]
            guest_host_switch_ns = 20000
            "#,
        )
        .unwrap();
        assert_eq!(c.host_memory, 1 << 30);
        assert_eq!(c.workers, 8);
        assert_eq!(c.shards, 16);
        assert_eq!(c.policy.hibernate_idle_ms, 500);
        assert_eq!(c.policy.memory_budget, 256 << 20);
        assert!(!c.policy.reap_enabled);
        assert!(c.sharing.share_language_runtime);
        assert_eq!(c.cost.guest_host_switch_ns, 20_000);
    }

    #[test]
    fn overrides_win() {
        let mut c = PlatformConfig::default();
        c.apply_overrides(&[
            "workers=2".to_string(),
            "policy.reap_enabled=false".to_string(),
            "policy.memory_budget=\"128MiB\"".to_string(),
        ])
        .unwrap();
        assert_eq!(c.workers, 2);
        assert!(!c.policy.reap_enabled);
        assert_eq!(c.policy.memory_budget, 128 << 20);
    }

    #[test]
    fn rejects_bad_watermark() {
        assert!(PlatformConfig::from_str("[policy]\npressure_watermark = 1.5\n").is_err());
    }

    #[test]
    fn replay_section_parses_with_defaults() {
        let c = PlatformConfig::default();
        assert_eq!(c.replay.workers, 0);
        assert_eq!(c.replay.epoch_ms, 100);
        assert_eq!(c.replay.tick_ms, 0);
        assert!(c.replay.strict_determinism);
        assert_eq!(c.policy.tick_stride, 1);
        assert!(c.predictor_state_file.is_empty());

        assert_eq!(c.policy.pipeline_workers, 2, "pipeline on by default");
        assert_eq!(c.policy.pipeline_queue_cap, 128, "bounded by default");

        let c = PlatformConfig::from_str(
            r#"
            predictor_state_file = "/tmp/tracks.csv"

            [policy]
            tick_stride = 4
            pipeline_workers = 0
            pipeline_queue_cap = 7

            [replay]
            workers = 8
            epoch_ms = 50
            tick_ms = 10
            strict_determinism = false
            "#,
        )
        .unwrap();
        assert_eq!(c.predictor_state_file, "/tmp/tracks.csv");
        assert_eq!(c.policy.tick_stride, 4);
        assert_eq!(c.policy.pipeline_workers, 0, "0 = synchronous pipeline");
        assert_eq!(c.policy.pipeline_queue_cap, 7);
        assert_eq!(c.replay.workers, 8);
        assert_eq!(c.replay.epoch_ms, 50);
        assert_eq!(c.replay.tick_ms, 10);
        assert!(!c.replay.strict_determinism);
    }

    #[test]
    fn deflate_workers_is_a_legacy_alias_for_pipeline_workers() {
        let c = PlatformConfig::from_str("[policy]\ndeflate_workers = 5\n").unwrap();
        assert_eq!(c.policy.pipeline_workers, 5);
        // When both appear, the new key wins.
        let c = PlatformConfig::from_str(
            "[policy]\ndeflate_workers = 5\npipeline_workers = 3\n",
        )
        .unwrap();
        assert_eq!(c.policy.pipeline_workers, 3);
    }

    #[test]
    fn rejects_zero_replay_epoch_and_clamps_stride() {
        assert!(PlatformConfig::from_str("[replay]\nepoch_ms = 0\n").is_err());
        let c = PlatformConfig::from_str("[policy]\ntick_stride = 0\n").unwrap();
        assert_eq!(c.policy.tick_stride, 1, "stride 0 clamps to 1");
    }

    #[test]
    fn io_section_parses_with_sync_default() {
        let c = PlatformConfig::default();
        assert_eq!(c.io.backend, "sync", "sync preserves pre-backend behavior");
        assert_eq!(c.io.workers, 2);
        assert_eq!(c.io.max_inflight_bytes, 32 << 20);
        assert_eq!(c.io.batch_pages, 1024);

        let c = PlatformConfig::from_str(
            r#"
            [io]
            backend = "batched"
            workers = 3
            max_inflight_bytes = "8MiB"
            batch_pages = 64
            "#,
        )
        .unwrap();
        assert_eq!(c.io.backend, "batched");
        assert_eq!(c.io.workers, 3);
        assert_eq!(c.io.max_inflight_bytes, 8 << 20);
        assert_eq!(c.io.batch_pages, 64);
        // Clamps: a zero worker pool or zero-page batch cannot make progress.
        let c = PlatformConfig::from_str("[io]\nworkers = 0\nbatch_pages = 0\n").unwrap();
        assert_eq!(c.io.workers, 1);
        assert_eq!(c.io.batch_pages, 1);
    }

    #[test]
    fn obs_section_parses_with_defaults() {
        let c = PlatformConfig::default();
        assert_eq!(c.obs.ring_events, 64 << 10);
        assert!(c.obs.enabled);

        let c = PlatformConfig::from_str("[obs]\nring_events = 128\nenabled = false\n").unwrap();
        assert_eq!(c.obs.ring_events, 128);
        assert!(!c.obs.enabled);
        // A zero ring cannot hold the event being emitted.
        let c = PlatformConfig::from_str("[obs]\nring_events = 0\n").unwrap();
        assert_eq!(c.obs.ring_events, 1);
    }

    #[test]
    fn durability_section_parses_with_defaults() {
        let c = PlatformConfig::default();
        assert_eq!(c.durability.io_retries, 2);
        assert_eq!(c.durability.backoff_base_us, 50);
        assert!(c.durability.verify_checksums);
        assert!(c.durability.adopt_on_start);
        assert_eq!(c.durability.compact_min_live_frac, 0.5);

        let c = PlatformConfig::from_str(
            r#"
            [durability]
            io_retries = 5
            backoff_base_us = 100
            verify_checksums = false
            adopt_on_start = false
            compact_min_live_frac = 0.25
            "#,
        )
        .unwrap();
        assert_eq!(c.durability.io_retries, 5);
        assert_eq!(c.durability.backoff_base_us, 100);
        assert!(!c.durability.verify_checksums);
        assert!(!c.durability.adopt_on_start);
        assert_eq!(c.durability.compact_min_live_frac, 0.25);
    }

    #[test]
    fn chaos_and_resilience_sections_parse_with_defaults() {
        let c = PlatformConfig::default();
        assert!(!c.chaos.enabled, "chaos off by default");
        assert!(!c.chaos.any_faults());
        assert_eq!(c.chaos.slow_io_ns, 2_000_000);
        assert_eq!(c.resilience.request_deadline_ms, 0, "no deadline by default");
        assert_eq!(c.resilience.watchdog_budget_ms, 30_000);
        assert_eq!(c.resilience.breaker_window, 16);
        assert_eq!(c.resilience.breaker_failures, 8);
        assert_eq!(c.resilience.quarantine_ms, 2_000);
        assert_eq!(c.resilience.probe_successes, 2);

        let c = PlatformConfig::from_str(
            r#"
            [chaos]
            enabled = true
            seed = 99
            crash_per_mille = 10
            poison_per_mille = 20
            slow_io_per_mille = 30
            slow_io_ns = 500000
            hang_per_mille = 40
            stall_per_mille = 50
            panic_per_mille = 60
            hang_ns = 7000000

            [resilience]
            request_deadline_ms = 250
            watchdog_budget_ms = 5000
            breaker_window = 8
            breaker_failures = 4
            quarantine_ms = 1000
            probe_successes = 3
            "#,
        )
        .unwrap();
        assert!(c.chaos.enabled);
        assert!(c.chaos.any_faults());
        assert_eq!(c.chaos.seed, 99);
        assert_eq!(c.chaos.crash_per_mille, 10);
        assert_eq!(c.chaos.poison_per_mille, 20);
        assert_eq!(c.chaos.slow_io_per_mille, 30);
        assert_eq!(c.chaos.slow_io_ns, 500_000);
        assert_eq!(c.chaos.hang_per_mille, 40);
        assert_eq!(c.chaos.stall_per_mille, 50);
        assert_eq!(c.chaos.panic_per_mille, 60);
        assert_eq!(c.chaos.hang_ns, 7_000_000);
        assert_eq!(c.resilience.request_deadline_ms, 250);
        assert_eq!(c.resilience.watchdog_budget_ms, 5_000);
        assert_eq!(c.resilience.breaker_window, 8);
        assert_eq!(c.resilience.breaker_failures, 4);
        assert_eq!(c.resilience.quarantine_ms, 1_000);
        assert_eq!(c.resilience.probe_successes, 3);

        // Clamps: a zero window or zero probe bar cannot make progress.
        let c =
            PlatformConfig::from_str("[resilience]\nbreaker_window = 0\nbreaker_failures = 0\n")
                .unwrap();
        assert_eq!(c.resilience.breaker_window, 1);
        let c = PlatformConfig::from_str("[resilience]\nprobe_successes = 0\n").unwrap();
        assert_eq!(c.resilience.probe_successes, 1);
    }

    #[test]
    fn chaos_enable_with_seed_fills_default_mix_once() {
        let mut c = ChaosConfig::default();
        c.enable_with_seed(7);
        assert!(c.enabled && c.any_faults());
        assert_eq!(c.seed, 7);
        assert!(c.crash_per_mille > 0 && c.panic_per_mille > 0);
        // An explicit mix is respected, not overwritten.
        let mut c = ChaosConfig {
            poison_per_mille: 5,
            ..ChaosConfig::default()
        };
        c.enable_with_seed(9);
        assert_eq!(c.poison_per_mille, 5);
        assert_eq!(c.crash_per_mille, 0, "explicit mix left alone");
    }

    #[test]
    fn rejects_certain_chaos_and_inverted_breaker() {
        assert!(PlatformConfig::from_str("[chaos]\ncrash_per_mille = 1000\n").is_err());
        assert!(
            PlatformConfig::from_str("[resilience]\nbreaker_window = 4\nbreaker_failures = 9\n")
                .is_err()
        );
    }

    #[test]
    fn rejects_bad_compact_fraction() {
        assert!(
            PlatformConfig::from_str("[durability]\ncompact_min_live_frac = 1.5\n").is_err()
        );
    }

    #[test]
    fn rejects_unknown_io_backend() {
        let err = PlatformConfig::from_str("[io]\nbackend = \"uring\"\n").unwrap_err();
        assert!(err.to_string().contains("io.backend"), "{err}");
    }

    #[test]
    fn rejects_bad_override() {
        let mut c = PlatformConfig::default();
        assert!(c.apply_overrides(&["nonsense".to_string()]).is_err());
    }

    #[test]
    fn policy_kind_and_lease_knobs_parse() {
        let c = PlatformConfig::default();
        assert_eq!(c.policy.kind, "hibernate");
        assert!(c.policy.adaptive_wake_lead);
        assert!(!c.policy.pressure_leases);
        assert!(c.policy.tenants.is_empty());
        assert!(!c.policy.tracks_tenants());

        let c = PlatformConfig::from_str(
            r#"
            [policy]
            kind = "tenant-fair"
            adaptive_wake_lead = false
            pressure_leases = true
            "#,
        )
        .unwrap();
        assert_eq!(c.policy.kind, "tenant-fair");
        assert!(!c.policy.adaptive_wake_lead);
        assert!(c.policy.pressure_leases);
        assert!(c.policy.tracks_tenants());
    }

    #[test]
    fn tenant_sections_parse_sorted_with_defaults() {
        let c = PlatformConfig::from_str(
            r#"
            [tenants.t03]
            weight = 2.5

            [tenants.t00]
            memory_budget = "64MiB"
            "#,
        )
        .unwrap();
        assert_eq!(c.policy.tenants.len(), 2);
        assert_eq!(c.policy.tenants[0].name, "t00", "rows sorted by name");
        assert_eq!(c.policy.tenants[0].memory_budget, Some(64 << 20));
        assert_eq!(c.policy.tenants[0].weight, 1.0);
        assert_eq!(c.policy.tenants[1].name, "t03");
        assert_eq!(c.policy.tenants[1].memory_budget, None);
        assert_eq!(c.policy.tenants[1].weight, 2.5);
        assert!(c.policy.tracks_tenants(), "tenant rows imply tracking");
        assert_eq!(c.policy.tenant_cfg("t03").unwrap().weight, 2.5);
        assert!(c.policy.tenant_cfg("t09").is_none());
    }

    #[test]
    fn tenant_overrides_update_rows_in_place() {
        let mut c = PlatformConfig::from_str("[tenants.t00]\nmemory_budget = \"8MiB\"\n").unwrap();
        c.apply_overrides(&[
            "tenants.t00.memory_budget=\"32MiB\"".to_string(),
            "tenants.t01.weight=3.0".to_string(),
        ])
        .unwrap();
        assert_eq!(c.policy.tenants.len(), 2);
        assert_eq!(c.policy.tenant_cfg("t00").unwrap().memory_budget, Some(32 << 20));
        assert_eq!(c.policy.tenant_cfg("t01").unwrap().weight, 3.0);
    }

    #[test]
    fn rejects_malformed_tenant_options() {
        assert!(PlatformConfig::from_str("[tenants.t00]\nweight = 0\n").is_err());
        assert!(PlatformConfig::from_str("[tenants.t00]\nbogus = 1\n").is_err());
        assert!(PlatformConfig::from_str("[tenants]\nt00 = 1\n").is_err());
        // Names no workload can ever match (the tNN- prefix convention)
        // are configuration errors, not silent dead rows.
        for bad in ["acme", "T00", "t0o", "t"] {
            let text = format!("[tenants.{bad}]\nweight = 2.0\n");
            assert!(PlatformConfig::from_str(&text).is_err(), "{bad}");
        }
    }
}
