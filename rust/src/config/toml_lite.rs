//! A TOML subset parser: `[section]` headers, `key = value`, `#` comments.
//!
//! Values: booleans, integers (with `_` separators), floats, quoted strings,
//! and **size literals** — quoted strings like `"512MiB"` / `"2GB"` that
//! `Value::as_u64` resolves to bytes, which configs use for memory budgets.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
}

impl Value {
    /// Integer or size-literal string → u64 bytes.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            Value::Str(s) => parse_size(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }
}

/// Parse `"512MiB"`-style size literals. Supports B, KB/KiB, MB/MiB,
/// GB/GiB, TB/TiB (decimal vs binary prefixes) and bare digits.
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !c.is_ascii_digit() && c != '.')?;
    let (num, unit) = s.split_at(split);
    let num: f64 = num.parse().ok()?;
    let mult: u64 = match unit.trim() {
        "B" | "" => 1,
        "KB" => 1_000,
        "KiB" => 1 << 10,
        "MB" => 1_000_000,
        "MiB" => 1 << 20,
        "GB" => 1_000_000_000,
        "GiB" => 1 << 30,
        "TB" => 1_000_000_000_000,
        "TiB" => 1 << 40,
        _ => return None,
    };
    Some((num * mult as f64).round() as u64)
}

/// Parsed document: flat map of (section, key) → value. The root section is
/// the empty string.
#[derive(Debug, Default, Clone)]
pub struct Table {
    entries: BTreeMap<(String, String), Value>,
}

impl Table {
    pub fn get2(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn insert(&mut self, section: &str, key: &str, v: Value) {
        self.entries
            .insert((section.to_string(), key.to_string()), v);
    }

    /// Iterate every `(section, key, value)` entry in sorted order — how
    /// dynamically-named sections (`[tenants.<name>]`) are discovered.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str, &Value)> {
        self.entries
            .iter()
            .map(|((s, k), v)| (s.as_str(), k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn parse_value(raw: &str, line_no: usize) -> Result<Value> {
    let raw = raw.trim();
    if raw.is_empty() {
        bail!("line {line_no}: missing value");
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("line {line_no}: unterminated string");
        };
        if inner.contains('"') {
            bail!("line {line_no}: embedded quote in string (escapes unsupported)");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    let cleaned: String = raw.chars().filter(|&c| c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {line_no}: cannot parse value `{raw}`");
}

/// Parse a document.
pub fn parse(text: &str) -> Result<Table> {
    let mut table = Table::default();
    let mut section = String::new();
    for (i, line) in text.lines().enumerate() {
        let line_no = i + 1;
        // Strip comments outside strings (naive: configs don't put '#' in strings).
        let line = match line.find('#') {
            Some(idx) if !line[..idx].contains('"') || line[..idx].matches('"').count() % 2 == 0 => {
                &line[..idx]
            }
            _ => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let Some(name) = inner.strip_suffix(']') else {
                bail!("line {line_no}: malformed section header");
            };
            section = name.trim().to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {line_no}: expected key = value");
        };
        let key = key.trim();
        if key.is_empty() {
            bail!("line {line_no}: empty key");
        }
        table.insert(&section, key, parse_value(value, line_no)?);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = parse(
            r#"
            # top comment
            a = 1
            b = 2.5          # trailing comment
            c = "hello"
            d = true
            big = 1_000_000

            [sec]
            e = false
            size = "512MiB"
            "#,
        )
        .unwrap();
        assert_eq!(t.get2("", "a"), Some(&Value::Int(1)));
        assert_eq!(t.get2("", "b").unwrap().as_f64(), Some(2.5));
        assert_eq!(t.get2("", "c"), Some(&Value::Str("hello".into())));
        assert_eq!(t.get2("", "d"), Some(&Value::Bool(true)));
        assert_eq!(t.get2("", "big").unwrap().as_u64(), Some(1_000_000));
        assert_eq!(t.get2("sec", "e"), Some(&Value::Bool(false)));
        assert_eq!(t.get2("sec", "size").unwrap().as_u64(), Some(512 << 20));
    }

    #[test]
    fn size_literals() {
        assert_eq!(parse_size("128MiB"), Some(128 << 20));
        assert_eq!(parse_size("1GB"), Some(1_000_000_000));
        assert_eq!(parse_size("4KiB"), Some(4096));
        assert_eq!(parse_size("1.5GiB"), Some(3 << 29));
        assert_eq!(parse_size("12"), None); // no unit split point
        assert_eq!(parse_size("xMiB"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("k = \"unterminated\n").is_err());
        assert!(parse("k = zzz\n").is_err());
    }
}
