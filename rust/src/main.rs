//! `repro` — the quark-hibernate launcher.
//!
//! Subcommands (hand-rolled parser; the offline registry has no clap):
//!
//! ```text
//! repro serve  [--config FILE] [--workers N] [--duration-ms N] [-o k=v ...]
//! repro replay [--config FILE] [--duration-ms N] [--mean-gap-ms N]
//!              [--trace FILE.csv] [--policy NAME] [-o k=v ...]
//! repro replay --scenario NAME [--funcs N] [--workers N] [--seed S]
//!              [--duration-ms N] [--policy NAME] [--report FILE.json]
//!              [--trace-out FILE.json]         # parallel replay
//!              [--chaos-seed S]   # inject a seeded, deterministic fault plan
//! repro replay --list-scenarios
//! repro fig6   [--quick]          # Figure 6: latency per container state
//! repro fig7   [--quick]          # Figure 7: PSS per container state
//! repro density [--budget-mib N]  # deployment-density experiment
//! repro fsck   [--dir DIR] [--config FILE]   # offline image validation
//! repro lint   [--dir rust/src] [--json]     # determinism-contract linter
//! repro list-artifacts            # show what the runtime can load
//! ```

use anyhow::{bail, Context, Result};
use quark_hibernate::config::PlatformConfig;
use quark_hibernate::container::{NoopRunner, PayloadRunner};
use quark_hibernate::platform::server::Server;
use quark_hibernate::platform::{trace, Platform};
use quark_hibernate::replay;
use quark_hibernate::runtime::PjrtRunner;
use quark_hibernate::util::{human_bytes, human_ns};
use quark_hibernate::workloads;
use std::sync::Arc;
use std::time::Duration;

/// Minimal flag parser: `--key value`, `--flag`, `-o k=v` (repeatable).
struct Args {
    flags: Vec<(String, Option<String>)>,
    overrides: Vec<String>,
}

impl Args {
    fn parse(mut argv: std::env::Args) -> (Option<String>, Args) {
        let _bin = argv.next();
        let cmd = argv.next();
        let mut flags = Vec::new();
        let mut overrides = Vec::new();
        let rest: Vec<String> = argv.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if a == "-o" {
                if let Some(v) = rest.get(i + 1) {
                    overrides.push(v.clone());
                    i += 2;
                    continue;
                }
            }
            if let Some(name) = a.strip_prefix("--") {
                let next_is_value = rest
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    flags.push((name.to_string(), Some(rest[i + 1].clone())));
                    i += 2;
                } else {
                    flags.push((name.to_string(), None));
                    i += 1;
                }
                continue;
            }
            i += 1;
        }
        (cmd, Args { flags, overrides })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}")),
        }
    }
}

fn load_config(args: &Args) -> Result<PlatformConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => PlatformConfig::from_file(path)?,
        None => PlatformConfig::default(),
    };
    cfg.apply_overrides(&args.overrides)?;
    Ok(cfg)
}

fn make_runner(cfg: &PlatformConfig) -> Arc<dyn PayloadRunner> {
    match PjrtRunner::new(&cfg.artifacts_dir) {
        Ok(r) => {
            eprintln!(
                "runtime: PJRT loaded, artifacts: {:?}",
                r.manifest().names()
            );
            Arc::new(r)
        }
        Err(e) => {
            eprintln!("runtime: artifacts unavailable ({e:#}); payloads disabled");
            Arc::new(NoopRunner)
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let workers = args.get_u64("workers", cfg.workers as u64)? as usize;
    let duration_ms = args.get_u64("duration-ms", 10_000)?;
    let mean_gap_ms = args.get_u64("mean-gap-ms", 300)?;
    let runner = make_runner(&cfg);
    let seed = cfg.seed;
    let platform = Arc::new(Platform::new(cfg, runner)?);
    for w in workloads::all_workloads() {
        platform.deploy(w)?;
    }
    let mut server = Server::start(platform.clone(), workers, Duration::from_millis(20));
    let events = trace::paper_mix(duration_ms * 1_000_000, mean_gap_ms, seed);
    println!("serving {} requests over {duration_ms} ms...", events.len());
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for ev in &events {
        let due = Duration::from_nanos(ev.at_ns);
        if let Some(sleep) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        pending.push(server.submit(&ev.workload)?);
    }
    let mut ok = 0u64;
    for rx in pending {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    println!("served {ok}/{} requests", events.len());
    println!("{}", platform.metrics.report());
    println!("host committed: {}", human_bytes(platform.memory_used()));
    server.shutdown();
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    if args.has("list-scenarios") {
        for (name, about) in replay::scenario::SCENARIOS {
            println!("{name:<18} {about}");
        }
        return Ok(());
    }
    if let Some(name) = args.get("scenario") {
        return cmd_replay_scenario(args, name);
    }
    let mut cfg = load_config(args)?;
    if let Some(kind) = args.get("policy") {
        cfg.policy.kind = kind.to_string();
    }
    let duration_ms = args.get_u64("duration-ms", 60_000)?;
    let mean_gap_ms = args.get_u64("mean-gap-ms", 500)?;
    let runner = make_runner(&cfg);
    let seed = cfg.seed;
    let platform = Platform::new(cfg, runner)?;
    for w in workloads::all_workloads() {
        platform.deploy(w)?;
    }
    let events = match args.get("trace") {
        Some(path) => quark_hibernate::platform::trace_file::load(path)?,
        None => trace::paper_mix(duration_ms * 1_000_000, mean_gap_ms, seed),
    };
    println!(
        "replaying {} events (virtual {duration_ms} ms)...",
        events.len()
    );
    let reports = platform.run_trace(&events)?;
    println!("{}", platform.metrics.report());
    let total: u64 = reports.iter().map(|r| r.latency_ns).sum();
    println!(
        "requests={} mean latency={}",
        reports.len(),
        human_ns(total / reports.len().max(1) as u64)
    );
    Ok(())
}

/// Parallel deterministic scenario replay (`--scenario NAME`): build the
/// seeded scenario, replay it across shard-affine workers, print the
/// report, optionally write it as JSON.
fn cmd_replay_scenario(args: &Args, name: &str) -> Result<()> {
    let mut cfg = load_config(args)?;
    // `--policy NAME` is sugar for `-o policy.kind=NAME` — the knob the
    // policy-search workflow sweeps.
    if let Some(kind) = args.get("policy") {
        cfg.policy.kind = kind.to_string();
    }
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    // `--chaos-seed S` arms the deterministic chaos engine: a default
    // fault mix (unless the config set explicit per-mille rates) drawn
    // from a plan that is a pure function of (S, workload, domain).
    if let Some(s) = args.get("chaos-seed") {
        let s = s
            .parse::<u64>()
            .or_else(|_| u64::from_str_radix(s.trim_start_matches("0x"), 16))
            .with_context(|| format!("invalid --chaos-seed `{s}`"))?;
        cfg.chaos.enable_with_seed(s);
    }
    let funcs = args.get_u64("funcs", 1000)? as usize;
    let duration_ms = args.get_u64("duration-ms", 300_000)?;
    let workers = args.get_u64("workers", 0)? as usize; // 0 = auto
    let run = replay::scenario::build(name, funcs, duration_ms * 1_000_000, cfg.seed)?;
    println!(
        "scenario {name} (policy {}): {} functions, {} events over virtual {duration_ms} ms",
        if cfg.policy.kind.is_empty() { "hibernate" } else { cfg.policy.kind.as_str() },
        run.specs.len(),
        run.events.len()
    );
    let (report, platform) = replay::run_scenario(&cfg, &run, workers)?;
    print!("{}", report.summary());
    if cfg.chaos.enabled {
        // The CI chaos-smoke job greps this line: zero leaked
        // reservations and a non-zero recovered-instances counter are
        // the self-healing acceptance gates.
        let r = &platform.metrics.resilience;
        let ld = std::sync::atomic::Ordering::Relaxed;
        println!(
            "chaos: faults={} crashes={} poison={} hangs={} stalls={} panics={} \
             watchdog_cancels={} breaker_opens={} quarantined={} \
             recovered_instances={} leaked_reservations={}",
            r.faults_injected.load(ld),
            r.injected_crashes.load(ld),
            r.injected_poison.load(ld),
            r.injected_hangs.load(ld),
            r.injected_stalls.load(ld),
            r.injected_panics.load(ld),
            r.watchdog_cancels.load(ld),
            r.breaker_opens.load(ld),
            r.requests_quarantined.load(ld),
            r.recovered_instances(),
            platform.leaked_reservations(),
        );
    }
    if let Some(path) = args.get("report") {
        report.save(path)?;
        println!("report written to {path}");
    }
    if let Some(path) = args.get("trace-out") {
        platform.dump_trace(path)?;
        println!("chrome trace written to {path} (load at ui.perfetto.dev)");
    }
    Ok(())
}

/// `repro fsck [--dir DIR]`: offline-validate every hibernated image under
/// the swap dir — manifest parse + trailer hash, slot-file lengths, every
/// recorded page checksum re-hashed. Prints one line per image
/// (ok / repairable / discard) and exits non-zero if anything is damaged,
/// so a deploy script can gate adoption on a clean tree. `--dir` overrides
/// the configured `swap_dir` (note: the argument parser takes flags only,
/// no bare positionals).
fn cmd_fsck(args: &Args) -> Result<()> {
    let dir = match args.get("dir") {
        Some(d) => d.to_string(),
        None => load_config(args)?.swap_dir,
    };
    let reports = quark_hibernate::swap::fsck_dir(std::path::Path::new(&dir))?;
    if reports.is_empty() {
        println!("fsck: no hibernated images under {dir}");
        return Ok(());
    }
    let mut damaged = 0usize;
    for r in &reports {
        // Pad the rendered status (width on a custom Display is ignored).
        let status = r.status.to_string();
        println!("{status:<12} {}  {}", r.manifest.display(), r.detail);
        if r.status != quark_hibernate::swap::FsckStatus::Ok {
            damaged += 1;
        }
    }
    println!(
        "fsck: {} image(s), {} damaged under {dir}",
        reports.len(),
        damaged
    );
    if damaged > 0 {
        bail!("{damaged} damaged image(s)");
    }
    Ok(())
}

/// `repro lint` — run the determinism-contract static analyzer over a
/// source tree (docs/static_analysis.md). Prints one `file:line [rule]
/// message` line per finding and exits non-zero if any survive pragma
/// suppression, so CI can gate on a clean tree.
fn cmd_lint(args: &Args) -> Result<()> {
    let dir = args.get("dir").unwrap_or("rust/src");
    let report = quark_hibernate::analysis::lint_tree(std::path::Path::new(dir))?;
    if args.has("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.to_text());
        if report.findings.is_empty() {
            println!(
                "lint: clean — {} file(s) scanned, {} pragma(s) in effect",
                report.files,
                report.pragmas.len()
            );
        }
    }
    if !report.findings.is_empty() {
        bail!("{} lint finding(s) under {dir}", report.findings.len());
    }
    Ok(())
}

fn cmd_list_artifacts(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let m = quark_hibernate::runtime::Manifest::load(&cfg.artifacts_dir)?;
    for a in &m.artifacts {
        println!(
            "{:<20} {} inputs={:?} outputs={:?}",
            a.name,
            a.path.display(),
            a.inputs,
            a.outputs
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let (cmd, args) = Args::parse(std::env::args());
    match cmd.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("replay") => cmd_replay(&args),
        Some("fig6") => {
            quark_hibernate::bench_support::fig6::run(args.has("quick"));
            Ok(())
        }
        Some("fig7") => {
            quark_hibernate::bench_support::fig7::run(args.has("quick"));
            Ok(())
        }
        Some("density") => {
            let budget = args.get_u64("budget-mib", 512)?;
            quark_hibernate::bench_support::density_exp::run(budget << 20, args.has("quick"));
            Ok(())
        }
        Some("fsck") => cmd_fsck(&args),
        Some("lint") => cmd_lint(&args),
        Some("list-artifacts") => cmd_list_artifacts(&args),
        Some(other) => bail!(
            "unknown command `{other}` (try serve|replay|fig6|fig7|density|fsck|lint|list-artifacts)"
        ),
        None => {
            eprintln!(
                "usage: repro <serve|replay|fig6|fig7|density|fsck|lint|list-artifacts> [--config FILE] [-o key=value]"
            );
            Ok(())
        }
    }
}
