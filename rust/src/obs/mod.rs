//! Flight recorder: per-shard ring buffers of compact lifecycle span
//! events, with Chrome-trace export ([`chrome_trace`]).
//!
//! The recorder answers the question the end-to-end summaries cannot:
//! *where* did a slow wake spend its time — the SIGCONT flip, the REAP
//! batch read, the pipeline queue, or the I/O backend? Every lifecycle
//! seam (cold-start phases, hibernate begin/finish, wake begin/finish,
//! pipeline job enqueue→start→done, I/O backend submit→complete, policy
//! decisions, request completions) emits a fixed-size [`SpanEvent`] into a
//! per-shard ring ([`config`](crate::config::ObsConfig) `obs.ring_events`
//! capacity, overwrite-oldest with a drop counter), cheap enough to stay
//! on in production.
//!
//! ## Clock domains
//!
//! Timestamps come from a [`TraceClock`]: live platforms use
//! [`WallTraceClock`] (monotonic nanoseconds since recorder creation —
//! `Date`-free), replay switches the recorder to [`VirtualTraceClock`]
//! which stamps the caller-provided virtual-time hint verbatim. Emission
//! sites thread the hint from [`crate::simtime::Clock::stamp_ns`] (anchor
//! + charged model time), so a replayed trace is a pure function of the
//! scenario: the same events with the same virtual timestamps at any
//! worker count.
//!
//! ## Fingerprint exclusion contract
//!
//! Like [`IoStats`](crate::platform::metrics::IoStats), the recorder and
//! every histogram live **outside** `Counters::snapshot()` and outside the
//! replay fingerprint: observability must never perturb the determinism
//! suite. Guard tests in `platform::metrics` and `replay::report` pin this.

pub mod chrome_trace;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Flag bit on [`SpanEvent::arg`] for `HibernateFinish` / `WakeFinish`
/// (the REAP path was used, vs the plain swap fallback) and for
/// `IoSubmit` / `IoComplete` (latency class, vs throughput). The low 63
/// bits carry the byte count.
pub const ARG_FLAG: u64 = 1 << 63;

/// Pack a `(verb, reason)` code pair into a [`EventKind::Decision`] arg.
pub fn pack_decision(verb: u8, reason: u8) -> u64 {
    ((verb as u64) << 8) | reason as u64
}

/// Unpack a [`EventKind::Decision`] arg back into `(verb, reason)` codes.
pub fn unpack_decision(arg: u64) -> (u8, u8) {
    ((arg >> 8) as u8, arg as u8)
}

/// What happened. Kept to one byte; the payload goes in [`SpanEvent::arg`]
/// (semantics per kind are documented in `docs/observability.md`).
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventKind {
    /// Cold start entered (arg: 0).
    ColdStartBegin = 0,
    /// Cold-start phase: host env (cgroup/netns/rootfs) + VM creation
    /// done (arg: phase charged ns).
    ColdPhaseEnv = 1,
    /// Cold-start phase: layout install + swap-file creation + image
    /// streaming done (arg: phase charged ns).
    ColdPhaseLayout = 2,
    /// Cold-start phase: runtime/app init done (arg: phase charged ns).
    ColdPhaseInit = 3,
    /// Cold start complete, container Warm (arg: total charged ns).
    ColdStartEnd = 4,
    /// SIGSTOP flip: container entered Hibernate (arg: 0).
    HibernateBegin = 5,
    /// Deflation I/O done (arg: bytes written | [`ARG_FLAG`] when REAP).
    HibernateFinish = 6,
    /// SIGCONT flip: container entered WokenUp (arg: 0).
    WakeBegin = 7,
    /// Inflation done (arg: bytes prefetched | [`ARG_FLAG`] when REAP).
    WakeFinish = 8,
    /// Pipeline job queued (arg: job-kind code 0=deflate 1=inflate
    /// 2=teardown).
    JobEnqueue = 9,
    /// Pipeline worker picked the job up (arg: job-kind code).
    JobStart = 10,
    /// Pipeline job finished (arg: job-kind code).
    JobDone = 11,
    /// I/O backend submission (arg: bytes | [`ARG_FLAG`] for the latency
    /// class). Recorded on the global ring — the backend sits below
    /// shard/instance context.
    IoSubmit = 12,
    /// I/O backend submission completed (arg: as `IoSubmit`).
    IoComplete = 13,
    /// Policy decision applied (arg: [`pack_decision`] of verb + typed
    /// `Reason` codes).
    Decision = 14,
    /// Request served (arg: end-to-end latency ns; `instance_id` is the
    /// serving sandbox).
    Request = 15,
    /// A transient slot-file I/O failure was retried with backoff
    /// (arg: retry attempt number, 1-based).
    IoRetry = 16,
    /// A slot read failed its recorded checksum — the page was **not**
    /// served (arg: byte offset of the failing slot).
    IntegrityFail = 17,
    /// The serving path dropped one rung down the degrade ladder
    /// (arg: rung — 1 = REAP image invalidated, fall back to per-page
    /// faults; 2 = per-page rescue from the swap file; 3 = image
    /// discarded, cold-start replacement).
    DegradeRung = 18,
    /// Image manifest persisted at hibernate (arg: manifest generation).
    ManifestWrite = 19,
    /// A manifest found on startup was adopted — the instance wakes
    /// instead of cold-starting (arg: manifest generation).
    ManifestAdopt = 20,
    /// A manifest failed validation or adoption and its image was
    /// discarded (arg: manifest generation, 0 when unparseable).
    ManifestReject = 21,
    /// The chaos plan injected a fault (arg: fault code — 1 = sandbox
    /// crash, 2 = poisoned request, 3 = slow I/O, 4 = hung inflation,
    /// 5 = stalled deflation/teardown, 6 = pipeline job panic).
    FaultInject = 22,
    /// Self-healing timeout fired (arg: 1 = server deadline shed a
    /// queued request, 2 = the pipeline watchdog cancelled an
    /// over-budget job).
    Timeout = 23,
    /// Circuit-breaker transition for a function (arg: 1 = opened /
    /// quarantined, 2 = half-open probing, 0 = closed / healthy again).
    Quarantine = 24,
    /// A crashed instance was recovered without operator input (arg:
    /// 1 = its hibernated image was re-adopted, 0 = replaced by cold
    /// start).
    InstanceRecover = 25,
}

impl EventKind {
    pub fn label(self) -> &'static str {
        match self {
            EventKind::ColdStartBegin => "cold_start_begin",
            EventKind::ColdPhaseEnv => "cold_phase_env",
            EventKind::ColdPhaseLayout => "cold_phase_layout",
            EventKind::ColdPhaseInit => "cold_phase_init",
            EventKind::ColdStartEnd => "cold_start_end",
            EventKind::HibernateBegin => "hibernate_begin",
            EventKind::HibernateFinish => "hibernate_finish",
            EventKind::WakeBegin => "wake_begin",
            EventKind::WakeFinish => "wake_finish",
            EventKind::JobEnqueue => "job_enqueue",
            EventKind::JobStart => "job_start",
            EventKind::JobDone => "job_done",
            EventKind::IoSubmit => "io_submit",
            EventKind::IoComplete => "io_complete",
            EventKind::Decision => "decision",
            EventKind::Request => "request",
            EventKind::IoRetry => "io_retry",
            EventKind::IntegrityFail => "integrity_fail",
            EventKind::DegradeRung => "degrade_rung",
            EventKind::ManifestWrite => "manifest_write",
            EventKind::ManifestAdopt => "manifest_adopt",
            EventKind::ManifestReject => "manifest_reject",
            EventKind::FaultInject => "fault_inject",
            EventKind::Timeout => "timeout",
            EventKind::Quarantine => "quarantine",
            EventKind::InstanceRecover => "instance_recover",
        }
    }
}

/// One recorded event: 48 bytes, fixed layout, no heap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Nanoseconds in the recorder's clock domain (wall or virtual).
    pub ts_ns: u64,
    /// Per-ring sequence number (emission order; canonicalized by
    /// [`Recorder::ring_events`] for deterministic export).
    pub seq: u64,
    /// Ring index: the owning control-plane shard, or the global ring
    /// ([`Recorder::global_ring`]) for shard-less emitters.
    pub shard: u32,
    pub kind: EventKind,
    /// Sandbox instance, 0 when not applicable.
    pub instance_id: u64,
    /// `fnv1a` of the workload name, 0 when not applicable.
    pub workload_hash: u64,
    /// Kind-specific payload (see [`EventKind`]).
    pub arg: u64,
}

impl SpanEvent {
    /// Content key for canonical ordering: everything except `seq`, so
    /// two replays that emitted the same events in different arrival
    /// orders (same-timestamp pipeline completions racing) sort
    /// identically.
    fn content_key(&self) -> (u64, u8, u64, u64, u64) {
        (
            self.ts_ns,
            self.kind as u8,
            self.instance_id,
            self.workload_hash,
            self.arg,
        )
    }
}

/// Timestamp source for the recorder. `hint_ns` is the emitter's virtual
/// position ([`crate::simtime::Clock::stamp_ns`]); the wall clock ignores
/// it, the virtual clock returns it verbatim.
pub trait TraceClock: Send + Sync {
    fn stamp(&self, hint_ns: u64) -> u64;
    fn is_virtual(&self) -> bool {
        false
    }
}

/// Live clock domain: monotonic nanoseconds since recorder creation
/// (`Instant`-based — no `Date`, no wall-calendar dependence).
pub struct WallTraceClock {
    epoch: Instant,
}

impl Default for WallTraceClock {
    fn default() -> Self {
        Self {
            epoch: Instant::now(),
        }
    }
}

impl TraceClock for WallTraceClock {
    fn stamp(&self, _hint_ns: u64) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

/// Replay clock domain: the emitter's virtual-time hint, verbatim.
#[derive(Default)]
pub struct VirtualTraceClock;

impl TraceClock for VirtualTraceClock {
    fn stamp(&self, hint_ns: u64) -> u64 {
        hint_ns
    }
    fn is_virtual(&self) -> bool {
        true
    }
}

/// One shard's ring: a bounded deque plus its overwrite counter.
struct Ring {
    inner: Mutex<RingInner>,
    dropped: AtomicU64,
}

struct RingInner {
    buf: VecDeque<SpanEvent>,
    next_seq: u64,
}

/// Canonically ordered contents of one ring ([`Recorder::ring_events`]).
#[derive(Debug, Clone)]
pub struct RingSnapshot {
    pub events: Vec<SpanEvent>,
    /// Events overwritten because the ring wrapped.
    pub dropped: u64,
}

/// The flight recorder: one fixed-capacity ring per control-plane shard
/// plus one global ring for emitters below shard context (the I/O
/// backend). Emission is wait-free against other shards (per-ring mutex)
/// and a no-op when disabled.
pub struct Recorder {
    rings: Vec<Ring>,
    /// Number of per-shard rings; `rings[shard_rings]` is the global ring.
    shard_rings: usize,
    capacity: usize,
    enabled: AtomicBool,
    clock: RwLock<Arc<dyn TraceClock>>,
}

impl Recorder {
    /// Recorder for `shards` control-plane shards, each ring holding up to
    /// `capacity` events, stamping wall time until [`Self::set_virtual`].
    pub fn new(shards: usize, capacity: usize, enabled: bool) -> Arc<Self> {
        let n = shards.max(1);
        Arc::new(Self {
            rings: (0..=n)
                .map(|_| Ring {
                    inner: Mutex::new(RingInner {
                        buf: VecDeque::new(),
                        next_seq: 0,
                    }),
                    dropped: AtomicU64::new(0),
                })
                .collect(),
            shard_rings: n,
            capacity: capacity.max(1),
            enabled: AtomicBool::new(enabled),
            clock: RwLock::new(Arc::new(WallTraceClock::default())),
        })
    }

    /// A recorder that records nothing — the default for test rigs built
    /// outside a platform.
    pub fn disabled() -> Arc<Self> {
        Self::new(1, 1, false)
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Switch to the virtual clock domain (replay). Existing events keep
    /// their stamps; call this before emitting.
    pub fn set_virtual(&self) {
        *self.clock.write().unwrap() = Arc::new(VirtualTraceClock);
    }

    pub fn is_virtual(&self) -> bool {
        self.clock.read().unwrap().is_virtual()
    }

    /// Number of per-shard rings.
    pub fn shard_count(&self) -> usize {
        self.shard_rings
    }

    /// Ring owning a workload — same `fnv1a(name) % shards` placement the
    /// control plane uses, so a shard's track shows its own functions.
    pub fn ring_for(&self, workload_hash: u64) -> u32 {
        (workload_hash % self.shard_rings as u64) as u32
    }

    /// The global ring, for emitters with no shard context.
    pub fn global_ring(&self) -> u32 {
        self.shard_rings as u32
    }

    /// Record one event. `hint_ns` is the emitter's virtual position
    /// (ignored in the wall domain). When the ring is full the oldest
    /// event is overwritten and the drop counter bumped.
    pub fn emit(
        &self,
        ring: u32,
        kind: EventKind,
        instance_id: u64,
        workload_hash: u64,
        arg: u64,
        hint_ns: u64,
    ) {
        if !self.is_enabled() {
            return;
        }
        let ts_ns = self.clock.read().unwrap().stamp(hint_ns);
        let idx = (ring as usize).min(self.rings.len() - 1);
        let ring = &self.rings[idx];
        let mut inner = ring.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if inner.buf.len() >= self.capacity {
            inner.buf.pop_front();
            ring.dropped.fetch_add(1, Ordering::Relaxed);
        }
        inner.buf.push_back(SpanEvent {
            ts_ns,
            seq,
            shard: idx as u32,
            kind,
            instance_id,
            workload_hash,
            arg,
        });
    }

    /// Shorthand: emit onto the ring owning `workload_hash`.
    pub fn emit_workload(
        &self,
        kind: EventKind,
        instance_id: u64,
        workload_hash: u64,
        arg: u64,
        hint_ns: u64,
    ) {
        self.emit(
            self.ring_for(workload_hash),
            kind,
            instance_id,
            workload_hash,
            arg,
            hint_ns,
        );
    }

    /// Total events currently held across all rings.
    pub fn len(&self) -> usize {
        self.rings
            .iter()
            .map(|r| r.inner.lock().unwrap().buf.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events overwritten across all rings.
    pub fn dropped(&self) -> u64 {
        self.rings
            .iter()
            .map(|r| r.dropped.load(Ordering::Relaxed))
            .sum()
    }

    /// One ring's events in canonical order — sorted by the content key
    /// `(ts_ns, kind, instance_id, workload_hash, arg)` with `seq`
    /// renumbered to that order. Emission `seq` breaks arrival-order ties
    /// only; canonicalizing makes the export independent of which pipeline
    /// thread's emission won a same-timestamp race, which is what makes
    /// replay traces byte-identical at any worker count.
    pub fn ring_events(&self, ring: u32) -> RingSnapshot {
        let r = &self.rings[(ring as usize).min(self.rings.len() - 1)];
        let mut events: Vec<SpanEvent> = r.inner.lock().unwrap().buf.iter().copied().collect();
        events.sort_by_key(|e| (e.content_key(), e.seq));
        for (i, e) in events.iter_mut().enumerate() {
            e.seq = i as u64;
        }
        RingSnapshot {
            events,
            dropped: r.dropped.load(Ordering::Relaxed),
        }
    }

    /// All rings (per-shard then global), canonically ordered.
    pub fn snapshot(&self) -> Vec<RingSnapshot> {
        (0..self.rings.len() as u32)
            .map(|i| self.ring_events(i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(rec: &Recorder, ring: u32, ts: u64, arg: u64) {
        rec.emit(ring, EventKind::Request, 1, 42, arg, ts);
    }

    #[test]
    fn wraparound_keeps_newest_and_counts_drops() {
        let rec = Recorder::new(1, 4, true);
        rec.set_virtual();
        for i in 0..6u64 {
            ev(&rec, 0, 100 + i, i);
        }
        let snap = rec.ring_events(0);
        assert_eq!(snap.dropped, 2, "two oldest events overwritten");
        assert_eq!(snap.events.len(), 4);
        let args: Vec<u64> = snap.events.iter().map(|e| e.arg).collect();
        assert_eq!(args, vec![2, 3, 4, 5], "newest four survive");
        // Canonical seq is 0..n in sorted order.
        let seqs: Vec<u64> = snap.events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn concurrent_emit_under_capacity_loses_nothing() {
        let rec = Recorder::new(4, 1 << 14, true);
        rec.set_virtual();
        let threads = 8;
        let per = 1000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let rec = &rec;
                s.spawn(move || {
                    for i in 0..per {
                        // Spread across all rings, unique (ts, arg) pairs.
                        rec.emit(
                            (i % 4) as u32,
                            EventKind::JobDone,
                            t,
                            t * 1_000_000 + i,
                            i,
                            i,
                        );
                    }
                });
            }
        });
        assert_eq!(rec.dropped(), 0, "capacity was sufficient");
        assert_eq!(rec.len(), (threads * per) as usize);
    }

    #[test]
    fn canonical_order_is_arrival_independent() {
        // The same multiset of events emitted in two different orders
        // must snapshot identically (seq renumbered).
        let make = |order: &[usize]| {
            let rec = Recorder::new(1, 64, true);
            rec.set_virtual();
            let evs = [(5u64, 1u64), (5, 2), (3, 9), (7, 0)];
            for &i in order {
                let (ts, arg) = evs[i];
                ev(&rec, 0, ts, arg);
            }
            rec.ring_events(0).events
        };
        let a = make(&[0, 1, 2, 3]);
        let b = make(&[3, 1, 0, 2]);
        assert_eq!(a, b);
    }

    #[test]
    fn disabled_recorder_is_a_noop() {
        let rec = Recorder::disabled();
        ev(&rec, 0, 1, 1);
        assert!(rec.is_empty());
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn virtual_clock_stamps_hint_wall_clock_ignores_it() {
        let rec = Recorder::new(1, 8, true);
        // Wall domain: the hint is ignored (stamps are monotonic-now).
        ev(&rec, 0, u64::MAX, 0);
        let wall_ts = rec.ring_events(0).events[0].ts_ns;
        assert!(wall_ts < 1 << 40, "wall stamp is elapsed-since-epoch");
        rec.set_virtual();
        assert!(rec.is_virtual());
        ev(&rec, 0, 123_456, 1);
        let snap = rec.ring_events(0);
        let virt = snap.events.iter().find(|e| e.arg == 1).unwrap();
        assert_eq!(virt.ts_ns, 123_456);
    }

    #[test]
    fn decision_packing_round_trips() {
        let arg = pack_decision(2, 4);
        assert_eq!(unpack_decision(arg), (2, 4));
    }
}
