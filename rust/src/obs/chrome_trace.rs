//! Chrome trace-event JSON export of the flight recorder.
//!
//! [`render`] turns a [`Recorder`] into the Chrome/Perfetto trace-event
//! format (JSON object form): one track (`tid`) per shard ring plus an
//! `io` track for the backend, complete (`"ph":"X"`) duration spans for
//! the paired lifecycle phases (cold start, hibernate, wake, pipeline
//! jobs) and instant (`"ph":"i"`) events for everything else (decisions,
//! requests, I/O submissions). Load the file at <https://ui.perfetto.dev>
//! or `chrome://tracing`.
//!
//! The output is a deterministic function of the recorder *contents*: the
//! events are canonically ordered ([`Recorder::ring_events`]) and the JSON
//! is built with fixed key order and integer-exact `µs.nnn` timestamp
//! formatting, so a virtual-time replay trace is byte-identical at any
//! worker count (as long as no ring wrapped — overwrite order under wrap
//! follows arrival order, which is scheduling-dependent).

use super::{unpack_decision, ARG_FLAG, EventKind, Recorder, SpanEvent};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Trace-event `ts`/`dur` are microseconds; keep nanosecond precision as
/// an exact 3-decimal fraction (no float formatting involved).
fn fmt_us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Begin/end pairing role of a kind: `(pair class, is_begin)`.
fn pair_role(kind: EventKind) -> Option<(u8, bool)> {
    match kind {
        EventKind::ColdStartBegin => Some((0, true)),
        EventKind::ColdStartEnd => Some((0, false)),
        EventKind::HibernateBegin => Some((1, true)),
        EventKind::HibernateFinish => Some((1, false)),
        EventKind::WakeBegin => Some((2, true)),
        EventKind::WakeFinish => Some((2, false)),
        EventKind::JobStart => Some((3, true)),
        EventKind::JobDone => Some((3, false)),
        _ => None,
    }
}

/// Jobs of different kinds for one instance may overlap in principle;
/// fold the job-kind code into the pair key so start/done match up.
fn pair_extra(e: &SpanEvent) -> u64 {
    match e.kind {
        EventKind::JobStart | EventKind::JobDone => e.arg & 0xff,
        _ => 0,
    }
}

fn span_name(class: u8, end: &SpanEvent) -> &'static str {
    match class {
        0 => "cold_start",
        1 => "hibernate",
        2 => "wake",
        _ => match end.arg & 0xff {
            0 => "job_deflate",
            1 => "job_inflate",
            _ => "job_teardown",
        },
    }
}

fn args_json(e: &SpanEvent) -> String {
    match e.kind {
        EventKind::HibernateFinish
        | EventKind::WakeFinish
        | EventKind::IoSubmit
        | EventKind::IoComplete => format!(
            "{{\"arg\":{},\"bytes\":{},\"flag\":{},\"instance\":{},\"workload\":\"{:#018x}\"}}",
            e.arg,
            e.arg & !ARG_FLAG,
            (e.arg >> 63) & 1,
            e.instance_id,
            e.workload_hash
        ),
        EventKind::Decision => {
            let (verb, reason) = unpack_decision(e.arg);
            format!(
                "{{\"arg\":{},\"instance\":{},\"reason\":{},\"verb\":{},\"workload\":\"{:#018x}\"}}",
                e.arg, e.instance_id, reason, verb, e.workload_hash
            )
        }
        _ => format!(
            "{{\"arg\":{},\"instance\":{},\"workload\":\"{:#018x}\"}}",
            e.arg, e.instance_id, e.workload_hash
        ),
    }
}

fn instant_json(e: &SpanEvent) -> String {
    format!(
        "{{\"args\":{},\"name\":\"{}\",\"ph\":\"i\",\"pid\":0,\"s\":\"t\",\"tid\":{},\"ts\":{}}}",
        args_json(e),
        e.kind.label(),
        e.shard,
        fmt_us(e.ts_ns)
    )
}

fn span_json(class: u8, begin: &SpanEvent, end: &SpanEvent) -> String {
    format!(
        "{{\"args\":{},\"dur\":{},\"name\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{}}}",
        args_json(end),
        fmt_us(end.ts_ns.saturating_sub(begin.ts_ns)),
        span_name(class, end),
        begin.shard,
        fmt_us(begin.ts_ns)
    )
}

/// Render the recorder as a Chrome trace-event JSON document.
pub fn render(rec: &Recorder) -> String {
    let rings = rec.snapshot();
    let dropped: u64 = rings.iter().map(|r| r.dropped).sum();
    let mut out = String::new();
    write!(
        out,
        "{{\"displayTimeUnit\":\"ns\",\"otherData\":{{\"dropped_events\":{dropped}}},\"traceEvents\":["
    )
    .unwrap();
    let mut first = true;
    let mut push = |out: &mut String, s: String| {
        if !std::mem::take(&mut first) {
            out.push(',');
        }
        out.push_str(&s);
    };
    for (tid, ring) in rings.iter().enumerate() {
        let track = if tid < rec.shard_count() {
            format!("shard-{tid}")
        } else {
            "io".to_string()
        };
        push(
            &mut out,
            format!(
                "{{\"args\":{{\"name\":\"{track}\"}},\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{tid}}}"
            ),
        );
        // Pair begin/end events into complete spans; everything else (and
        // any orphaned half) renders as an instant.
        let mut open: BTreeMap<(u8, u64, u64), SpanEvent> = BTreeMap::new();
        for e in &ring.events {
            match pair_role(e.kind) {
                Some((class, true)) => {
                    if let Some(orphan) = open.insert((class, e.instance_id, pair_extra(e)), *e) {
                        push(&mut out, instant_json(&orphan));
                    }
                }
                Some((class, false)) => {
                    match open.remove(&(class, e.instance_id, pair_extra(e))) {
                        Some(begin) => push(&mut out, span_json(class, &begin, e)),
                        None => push(&mut out, instant_json(e)),
                    }
                }
                None => push(&mut out, instant_json(e)),
            }
        }
        // Ends never arrived (ring wrapped past them, or work in flight
        // at snapshot time): deterministic order via the BTreeMap key.
        for begin in open.values() {
            push(&mut out, instant_json(begin));
        }
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn rig() -> std::sync::Arc<Recorder> {
        let rec = Recorder::new(2, 64, true);
        rec.set_virtual();
        rec
    }

    #[test]
    fn renders_valid_json_with_spans_and_instants() {
        let rec = rig();
        let h = 7u64; // ring 7 % 2 = 1
        rec.emit_workload(EventKind::WakeBegin, 3, h, 0, 1000);
        rec.emit_workload(EventKind::WakeFinish, 3, h, 4096 | ARG_FLAG, 5000);
        rec.emit_workload(EventKind::Decision, 0, h, super::super::pack_decision(2, 4), 900);
        rec.emit(rec.global_ring(), EventKind::IoSubmit, 0, 0, 8192, 0);
        let s = render(&rec);
        let doc = json::parse(&s).expect("chrome trace must be valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 thread_name metadata (2 shards + io) + 1 span + 2 instants.
        assert_eq!(events.len(), 6);
        let span = events
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .expect("paired wake must render as a complete span");
        assert_eq!(span.get("name").unwrap().as_str().unwrap(), "wake");
        assert_eq!(span.get("ts").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(span.get("dur").unwrap().as_f64().unwrap(), 4.0);
        let args = span.get("args").unwrap();
        assert_eq!(args.get("bytes").unwrap().as_u64().unwrap(), 4096);
        assert_eq!(args.get("flag").unwrap().as_u64().unwrap(), 1);
        let decision = events
            .iter()
            .find(|e| e.get("name").and_then(|p| p.as_str()) == Some("decision"))
            .unwrap();
        assert_eq!(decision.get("args").unwrap().get("verb").unwrap().as_u64(), Some(2));
        assert_eq!(decision.get("args").unwrap().get("reason").unwrap().as_u64(), Some(4));
    }

    #[test]
    fn render_is_arrival_order_independent() {
        let emit_all = |order: &[usize]| {
            let rec = rig();
            let evs = [
                (EventKind::JobStart, 1u64, 10u64, 1u64),
                (EventKind::JobDone, 1, 10, 1),
                (EventKind::Request, 2, 10, 555),
                (EventKind::HibernateBegin, 1, 20, 0),
            ];
            for &i in order {
                let (k, id, hint, arg) = evs[i];
                rec.emit_workload(k, id, 4, arg, hint);
            }
            render(&rec)
        };
        assert_eq!(emit_all(&[0, 1, 2, 3]), emit_all(&[3, 2, 1, 0]));
    }

    #[test]
    fn unpaired_begin_renders_as_instant() {
        let rec = rig();
        rec.emit_workload(EventKind::HibernateBegin, 9, 1, 0, 42);
        let s = render(&rec);
        let doc = json::parse(&s).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events
            .iter()
            .any(|e| e.get("name").and_then(|n| n.as_str()) == Some("hibernate_begin")
                && e.get("ph").and_then(|p| p.as_str()) == Some("i")));
    }
}
