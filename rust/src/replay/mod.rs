//! Parallel deterministic trace replay — the harness that exercises the
//! sharded control plane at Azure-trace scale (thousands of mostly-idle
//! functions) while keeping results bit-for-bit reproducible.
//!
//! # Determinism model
//!
//! A trace's events are partitioned by the owning control-plane shard
//! (the same FNV placement requests use, [`Platform::shard_index`]) onto
//! `workers` shard-affine replay workers. Each worker advances virtual
//! time independently for the shards it owns: events in time order,
//! interleaved with policy ticks on a fixed [`TickSchedule`] (multiples of
//! the tick period, exactly the cadence single-threaded replay has always
//! used). Because a shard's pools, specs and predictor are touched only by
//! its one owner, per-shard state evolution does not depend on how shards
//! are spread over workers.
//!
//! The only cross-shard input to policy decisions is the budget
//! hierarchy. Replay therefore runs in **epochs**: at each epoch boundary
//! every worker parks on a barrier, one leader reconciles a
//! [`BudgetFrame`] — host committed bytes, the per-tenant ledger, and
//! (with `policy.pressure_leases`) per-shard budget leases split
//! proportionally to per-shard committed bytes — and all ticks of the
//! next epoch use that frame. State at a barrier is
//! interleaving-independent (all events and ticks before it have run;
//! committed bytes are sums over per-shard state), so the frame — and
//! with it every policy decision — is the same at `--workers 1` and
//! `--workers 8`. Under leases a shard additionally reads its *own* live
//! committed bytes at each tick, which is still deterministic (a shard's
//! state is single-owner between barriers) and reacts to pressure within
//! the epoch instead of an epoch late.
//!
//! Deflations, anticipatory inflations and eviction teardowns run on the
//! platform's off-tick worker pool ([`crate::platform::pipeline`]), so a
//! policy tick only *submits* the expensive I/O. The engine **drains the
//! pool after every tick batch** (and thus before every event serve and
//! every epoch barrier): by the time anything can observe a shard, every
//! submitted instance is fully transitioned, unreserved and folded into
//! the counters, making results independent of both the replay worker
//! count *and* the pipeline worker count. (The backpressure cap is forced
//! off under strict determinism — shed decisions read the real-time queue
//! depth.)
//!
//! Two sources of nondeterminism are fenced off by configuration:
//! cross-sandbox file-page sharing (a cache hit depends on *which sandbox
//! faulted a page first* — an interleaving artifact), disabled for replay
//! platforms when `replay.strict_determinism` is set (the default, which
//! also ignores any `predictor_state_file` sidecar); and real measured
//! compute, absent because scenario replay runs on the [`NoopRunner`] —
//! latencies are purely charged model time.
//!
//! One boundary of the contract: the host page allocator is a real shared
//! resource, so *at memory capacity* whether a cold start's allocation
//! lands before or after another worker's tick-driven frees is a real-time
//! race — a replay sized to exhaust `host_memory` can fail at one worker
//! count and complete at another. Scenarios must leave allocation headroom
//! (pressure policy reacting to the *budget watermark* is fine — that is
//! virtual and reconciled, lease or no lease; physically running out of
//! host pages is not). `policy.pressure_leases` makes the watermark
//! response per-shard and within-epoch, which keeps budget-driven
//! deflation well ahead of physical capacity under tight budgets — but
//! the headroom requirement itself stands.
//!
//! [`Platform::run_trace`] is this engine at `workers = 1`.

pub mod chaos;
pub mod report;
pub mod scenario;

use crate::config::PlatformConfig;
use crate::container::NoopRunner;
use crate::platform::policy::BudgetFrame;
use crate::platform::trace::TraceEvent;
use crate::platform::{Platform, RequestReport};
use crate::simtime::TickSchedule;
use anyhow::Result;
use report::ReplayReport;
use scenario::ScenarioRun;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

/// What one replay run produced.
pub struct ReplayOutcome {
    /// Per-event reports, in trace (event) order.
    pub reports: Vec<RequestReport>,
    /// `(epoch_start_vns, committed_bytes)` — the memory-density timeline
    /// sampled at every epoch barrier.
    pub mem_timeline: Vec<(u64, u64)>,
    /// `(epoch_start_vns, [(tenant, live_bytes)])` — the per-tenant
    /// density timeline, sampled at the same barriers. Empty unless the
    /// config tracks tenants (`policy.kind = "tenant-fair"` or a
    /// `[tenants]` section).
    pub tenant_timeline: Vec<(u64, Vec<(String, u64)>)>,
    /// Worker threads actually used.
    pub workers: usize,
    /// Real wall-clock of the whole replay.
    pub wall_ns: u64,
}

/// The parallel replay engine, borrowed over a deployed [`Platform`].
pub struct ReplayEngine<'p> {
    platform: &'p Platform,
    workers: usize,
    epoch_ns: u64,
    tick_ns: u64,
}

impl<'p> ReplayEngine<'p> {
    /// Build an engine from the platform's `[replay]` config.
    /// `workers_override` (e.g. the CLI's `--workers`) takes precedence;
    /// `None`/`0` falls back to `replay.workers`, then to one per CPU. The
    /// count is clamped to the shard count — a worker owning no shards
    /// would have nothing to replay.
    pub fn new(platform: &'p Platform, workers_override: Option<usize>) -> Self {
        let rc = &platform.cfg.replay;
        let requested = match workers_override {
            Some(w) if w > 0 => w,
            _ if rc.workers > 0 => rc.workers,
            _ => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        };
        let tick_ns = if rc.tick_ms > 0 {
            rc.tick_ms * 1_000_000
        } else {
            // The rule single-threaded replay has always used: half the
            // hibernate idle threshold, at least 1 ms.
            (platform.cfg.policy.hibernate_idle_ms * 1_000_000 / 2).max(1_000_000)
        };
        Self {
            workers: requested.clamp(1, platform.shard_count()),
            epoch_ns: rc.epoch_ms.max(1) * 1_000_000,
            tick_ns,
            platform,
        }
    }

    /// The engine `run_trace` delegates to: one worker, same schedule.
    pub fn single_threaded(platform: &'p Platform) -> Self {
        Self::new(platform, Some(1))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Replay `events` to completion. Fails fast on the first request
    /// error (all workers wind down at the next epoch boundary and the
    /// first error is returned).
    ///
    /// Events are expected time-sorted (every in-repo producer sorts);
    /// an unsorted trace is still served completely — each shard serves
    /// its events in input order, like the old single-threaded loop —
    /// but the determinism contract is only stated for sorted input.
    pub fn run(&self, events: &[TraceEvent]) -> Result<ReplayOutcome> {
        // lint:allow(wall-clock): reporting-only wall_ns; never in the fingerprint
        let t0 = Instant::now();
        if events.is_empty() {
            return Ok(ReplayOutcome {
                reports: Vec::new(),
                mem_timeline: Vec::new(),
                tenant_timeline: Vec::new(),
                workers: self.workers,
                wall_ns: t0.elapsed().as_nanos() as u64,
            });
        }
        let n_workers = self.workers;
        let mut per_worker: Vec<Vec<usize>> = vec![Vec::new(); n_workers];
        for (i, ev) in events.iter().enumerate() {
            per_worker[self.platform.shard_index(&ev.workload) % n_workers].push(i);
        }
        // Max, not `last()`: an unsorted trace must not shrink the epoch
        // range, or every event beyond the final epoch would be silently
        // dropped.
        let duration_ns = events.iter().map(|e| e.at_ns).max().expect("non-empty") + 1;
        let n_epochs = duration_ns.div_ceil(self.epoch_ns);

        let barrier = Barrier::new(n_workers);
        let frame_slot: Mutex<Arc<BudgetFrame>> = Mutex::new(Arc::new(BudgetFrame::default()));
        let abort = AtomicBool::new(false);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let timeline: Mutex<Vec<(u64, u64)>> = Mutex::new(Vec::new());
        let tenant_timeline: Mutex<Vec<(u64, Vec<(String, u64)>)>> = Mutex::new(Vec::new());

        let collected: Vec<Vec<(usize, RequestReport)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_workers)
                .map(|w| {
                    let my_events = &per_worker[w];
                    let barrier = &barrier;
                    let frame_slot = &frame_slot;
                    let abort = &abort;
                    let first_err = &first_err;
                    let timeline = &timeline;
                    let tenant_timeline = &tenant_timeline;
                    scope.spawn(move || {
                        self.worker_loop(
                            w, my_events, events, n_epochs, barrier, frame_slot, abort,
                            first_err, timeline, tenant_timeline,
                        )
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("replay worker panicked"))
                .collect()
        });

        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        let mut indexed: Vec<(usize, RequestReport)> =
            collected.into_iter().flatten().collect();
        indexed.sort_by_key(|(i, _)| *i);
        Ok(ReplayOutcome {
            reports: indexed.into_iter().map(|(_, r)| r).collect(),
            mem_timeline: timeline.into_inner().unwrap(),
            tenant_timeline: tenant_timeline.into_inner().unwrap(),
            workers: n_workers,
            wall_ns: t0.elapsed().as_nanos() as u64,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn worker_loop(
        &self,
        w: usize,
        my_events: &[usize],
        events: &[TraceEvent],
        n_epochs: u64,
        barrier: &Barrier,
        frame_slot: &Mutex<Arc<BudgetFrame>>,
        abort: &AtomicBool,
        first_err: &Mutex<Option<anyhow::Error>>,
        timeline: &Mutex<Vec<(u64, u64)>>,
        tenant_timeline: &Mutex<Vec<(u64, Vec<(String, u64)>)>>,
    ) -> Vec<(usize, RequestReport)> {
        let owned: Vec<usize> = (0..self.platform.shard_count())
            .filter(|s| s % self.workers == w)
            .collect();
        let mut out = Vec::with_capacity(my_events.len());
        let mut sched = TickSchedule::new(self.tick_ns);
        let mut cursor = 0usize;
        // Every worker must reach every Barrier::wait, or the others hang
        // forever — so all fallible/panicking work between the waits is
        // fenced: errors AND unwinds are converted into the abort flag,
        // never an early exit from the epoch loop.
        let record_failure = |err: anyhow::Error| {
            abort.store(true, Ordering::Relaxed);
            let mut slot = first_err.lock().unwrap();
            if slot.is_none() {
                *slot = Some(err);
            }
        };
        for e in 0..n_epochs {
            let epoch_start = e * self.epoch_ns;
            let epoch_end = epoch_start + self.epoch_ns;
            // Reconcile the budget frame: one leader rebuilds it after
            // *every* worker finished the previous epoch, so each epoch's
            // policy ticks see the same host pressure, tenant ledger and
            // shard leases no matter how many workers replay the trace.
            if barrier.wait().is_leader() && !abort.load(Ordering::Relaxed) {
                let sampled = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let frame = Arc::new(self.platform.reconcile_budget());
                    timeline.lock().unwrap().push((epoch_start, frame.host_used));
                    if !frame.tenants.is_empty() {
                        tenant_timeline.lock().unwrap().push((
                            epoch_start,
                            frame
                                .tenants
                                .iter()
                                .map(|t| (t.name.clone(), t.used))
                                .collect(),
                        ));
                    }
                    *frame_slot.lock().unwrap() = frame;
                }));
                if let Err(p) = sampled {
                    record_failure(anyhow::anyhow!(
                        "replay leader panicked reconciling the budget: {}",
                        panic_message(&p)
                    ));
                }
            }
            barrier.wait();
            if abort.load(Ordering::Relaxed) {
                continue; // keep pacing the barriers so nobody deadlocks
            }
            let frame = frame_slot.lock().unwrap().clone();
            let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.run_epoch(&owned, my_events, events, epoch_end, &frame, &mut sched, &mut cursor, &mut out)
            }));
            match ran {
                Ok(Ok(())) => {}
                Ok(Err(err)) => record_failure(err),
                Err(p) => record_failure(anyhow::anyhow!(
                    "replay worker {w} panicked: {}",
                    panic_message(&p)
                )),
            }
        }
        out
    }

    /// One worker's slice of one epoch: serve its events due before
    /// `epoch_end`, running every policy tick that comes due on its shards
    /// first, then catch the tick schedule up to the epoch boundary.
    #[allow(clippy::too_many_arguments)]
    fn run_epoch(
        &self,
        owned: &[usize],
        my_events: &[usize],
        events: &[TraceEvent],
        epoch_end: u64,
        frame: &BudgetFrame,
        sched: &mut TickSchedule,
        cursor: &mut usize,
        out: &mut Vec<(usize, RequestReport)>,
    ) -> Result<()> {
        while *cursor < my_events.len() {
            let idx = my_events[*cursor];
            let ev = &events[idx];
            if ev.at_ns >= epoch_end {
                break;
            }
            while let Some(t) = sched.pop_due(ev.at_ns) {
                for &s in owned {
                    self.platform.policy_tick_shard(s, t, frame)?;
                }
                // Pipeline jobs (deflations, anticipatory inflations,
                // eviction teardowns) submitted by this tick run
                // concurrently on the pool; drain before anything can
                // observe the shards, so routing decisions (and the memory
                // they free or prefetch) never depend on real-time I/O
                // progress — the off-tick pipeline's determinism contract.
                self.platform.drain_pipeline()?;
            }
            match self.platform.request_at(&ev.workload, ev.at_ns) {
                Ok(rep) => out.push((idx, rep)),
                // Typed self-healing rejects (quarantined function,
                // poisoned invocation, shed deadline) are outcomes, not
                // replay failures: the platform already counted them, and
                // whether they fire is deterministic (breaker state and
                // the chaos plan both advance per-workload, serialized on
                // this worker). The event simply yields no report.
                Err(e) if crate::platform::is_resilience_reject(&e) => {}
                Err(e) => return Err(e),
            }
            *cursor += 1;
        }
        while let Some(t) = sched.pop_before(epoch_end) {
            for &s in owned {
                self.platform.policy_tick_shard(s, t, frame)?;
            }
            self.platform.drain_pipeline()?;
        }
        Ok(())
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run a scenario end-to-end on a fresh platform: apply the
/// strict-determinism fences to `cfg`, deploy the scenario's functions,
/// replay its trace with `workers` threads (`0` = auto), and build the
/// report. Returns the platform too so callers can inspect final pool
/// state.
pub fn run_scenario(
    cfg: &PlatformConfig,
    run: &ScenarioRun,
    workers: usize,
) -> Result<(ReplayReport, Platform)> {
    let mut cfg = cfg.clone();
    if cfg.replay.strict_determinism {
        // Shared file-page cache hits depend on which sandbox faulted
        // first — an interleaving artifact bit-identical replay can't
        // tolerate (see the module docs).
        cfg.sharing.share_runtime_binary = false;
        cfg.sharing.share_language_runtime = false;
        // Likewise a predictor sidecar would pre-seed arrival tracks from
        // whatever a previous process learned — external mutable state
        // that must not leak into a reproducible replay.
        cfg.predictor_state_file.clear();
        // Backpressure sheds key off the *real-time* pipeline queue depth
        // (how fast workers drain is a wall-clock race), so a capped queue
        // could shed different jobs at different worker counts. Replay
        // keeps the pipeline but unbounds the queue.
        cfg.policy.pipeline_queue_cap = 0;
    }
    let platform = Platform::new(cfg, std::sync::Arc::new(NoopRunner))?;
    // Replay stamps flight-recorder events with the virtual clock: every
    // emission passes an absolute virtual-nanosecond hint, so an exported
    // trace is identical at any `--workers` count once the export's
    // canonical per-ring sort runs (wall timestamps would be a wall-clock
    // race). See docs/observability.md.
    platform.metrics.recorder.set_virtual();
    for spec in &run.specs {
        platform.deploy(spec.clone())?;
    }
    let engine = ReplayEngine::new(
        &platform,
        if workers == 0 { None } else { Some(workers) },
    );
    let outcome = engine.run(&run.events)?;
    let report = ReplayReport::build(&run.name, run.seed, &platform, &outcome);
    Ok((report, platform))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::CostModel;
    use crate::workloads::functionbench::{golang_hello, scaled_for_test};
    use std::sync::Arc;

    fn test_cfg(tag: &str) -> PlatformConfig {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 512 << 20;
        cfg.cost = CostModel::paper();
        cfg.shards = 4;
        cfg.policy.hibernate_idle_ms = 20;
        cfg.policy.predictive_wakeup = false;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-replay-mod-{tag}-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        cfg
    }

    #[test]
    fn empty_trace_is_a_noop() {
        let p = Platform::new(test_cfg("empty"), Arc::new(NoopRunner)).unwrap();
        let out = ReplayEngine::new(&p, Some(2)).run(&[]).unwrap();
        assert!(out.reports.is_empty());
        assert!(out.mem_timeline.is_empty());
    }

    #[test]
    fn workers_clamped_to_shards() {
        let p = Platform::new(test_cfg("clamp"), Arc::new(NoopRunner)).unwrap();
        assert_eq!(ReplayEngine::new(&p, Some(64)).workers(), 4);
        assert_eq!(ReplayEngine::new(&p, Some(1)).workers(), 1);
    }

    #[test]
    fn unknown_workload_aborts_with_the_error() {
        let p = Platform::new(test_cfg("unknown"), Arc::new(NoopRunner)).unwrap();
        p.deploy(scaled_for_test(golang_hello(), 32)).unwrap();
        let events = vec![
            TraceEvent {
                at_ns: 0,
                workload: "golang-hello".into(),
            },
            TraceEvent {
                at_ns: 1_000_000,
                workload: "nope".into(),
            },
        ];
        let err = ReplayEngine::new(&p, Some(2)).run(&events).unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
    }

    #[test]
    fn unsorted_trace_is_still_served_completely() {
        let p = Platform::new(test_cfg("unsorted"), Arc::new(NoopRunner)).unwrap();
        p.deploy(scaled_for_test(golang_hello(), 32)).unwrap();
        // Last event is NOT the latest: the epoch range must come from the
        // max timestamp or the 900 ms event would be silently dropped.
        let events = vec![
            TraceEvent {
                at_ns: 900_000_000,
                workload: "golang-hello".into(),
            },
            TraceEvent {
                at_ns: 10_000_000,
                workload: "golang-hello".into(),
            },
        ];
        let out = ReplayEngine::new(&p, Some(1)).run(&events).unwrap();
        assert_eq!(out.reports.len(), 2, "no event may be dropped");
    }

    #[test]
    fn reports_come_back_in_event_order() {
        let p = Platform::new(test_cfg("order"), Arc::new(NoopRunner)).unwrap();
        for i in 0..4 {
            let mut s = scaled_for_test(golang_hello(), 32);
            s.name = format!("fn-{i}");
            p.deploy(s).unwrap();
        }
        let events: Vec<TraceEvent> = (0..40)
            .map(|i| TraceEvent {
                at_ns: i as u64 * 10_000_000,
                workload: format!("fn-{}", i % 4),
            })
            .collect();
        let out = ReplayEngine::new(&p, Some(4)).run(&events).unwrap();
        assert_eq!(out.reports.len(), events.len());
        for (r, ev) in out.reports.iter().zip(&events) {
            assert_eq!(r.workload, ev.workload, "reports must follow event order");
        }
        assert!(
            !out.mem_timeline.is_empty(),
            "epoch barriers must sample the density timeline"
        );
    }
}
