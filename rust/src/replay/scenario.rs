//! Scenario library: named, seeded workload mixes at Azure-trace scale.
//!
//! Every scenario layers N synthetic functions (default 1000) over the 8
//! paper workloads — function `i` inherits the memory/latency shape of
//! paper workload `i mod 8`, scaled down [`MEM_SCALE`]× so a
//! thousand-function host fits in a few hundred MiB — and binds an arrival
//! process to each. Generation is purely a function of `(name, funcs,
//! duration, seed)`, so a scenario can be rebuilt bit-identically anywhere
//! (the determinism tests and the CI smoke job rely on this).
//!
//! Shapes, per the workload studies the paper leans on (Shahrad et al.'s
//! Azure traces; the lognormal inter-arrival fits):
//!
//! * `azure-heavy-tail` — a few hot functions carry most invocations, a
//!   long tail is invoked rarely in bursts; the bread-and-butter density
//!   case Hibernate monetizes.
//! * `diurnal-wave` — sinusoidally modulated Poisson arrivals (thinning),
//!   four waves over the trace; exercises hibernate-on-ebb / wake-on-flow.
//! * `flash-crowd` — sparse background traffic, then a third of all
//!   functions burst at once mid-trace; exercises wake storms under
//!   pressure.
//! * `tenant-skewed` — functions grouped into 10 tenants with one tenant
//!   dominating traffic; the fixture the per-tenant budget policy is
//!   evaluated on. The `tNN-` name prefix is load-bearing: it is the
//!   convention [`crate::platform::policy::tenant_of`] parses tenancy
//!   from (and what the `[tenants]` config sections key on).
//! * `churn` — tenant cohorts arrive and depart mid-trace: one cohort's
//!   traffic stops at 60% of the trace (departure), another's starts at
//!   40% (arrival), and the two overlap in the middle. The replay
//!   harness deploys all specs up front (trace events carry no verbs),
//!   so churn is modeled as deterministic per-cohort activity windows —
//!   a departed tenant's functions go permanently idle and must ride the
//!   degrade ladder down, an arriving tenant's functions cold-start as a
//!   surge against a warm fleet. The chaos smoke job runs on this
//!   scenario because the fleet's instance population turns over
//!   mid-trace, exercising recovery against both fresh and aged images.
//! * `paper-mix` — just the 8 paper workloads with idle-heavy Poisson
//!   arrivals (the original small-scale replay, for continuity).

use crate::platform::trace::{generate, Arrival, TraceEvent, TraceSpec};
use crate::util::rng::Rng;
use crate::workloads::functionbench::scaled_for_test;
use crate::workloads::{all_workloads, WorkloadSpec};
use anyhow::{bail, Result};

/// Memory scale-down factor for synthetic functions (≈ 1/64 of the paper
/// workloads' footprints, so 1000+ functions fit one host).
pub const MEM_SCALE: u64 = 64;

/// Number of tenants in `tenant-skewed`.
pub const TENANTS: usize = 10;

/// Scenario directory: `(name, one-line description)`.
pub const SCENARIOS: &[(&str, &str)] = &[
    (
        "azure-heavy-tail",
        "hot head + rare bursty tail over N synthetic functions (the Azure shape)",
    ),
    (
        "diurnal-wave",
        "sinusoidally modulated arrivals, four waves over the trace",
    ),
    (
        "flash-crowd",
        "sparse background, then 1/3 of all functions burst at once mid-trace",
    ),
    (
        "tenant-skewed",
        "10 tenants, one dominating traffic (multi-tenant fixture)",
    ),
    (
        "memory-heavy",
        "fat-footprint functions under steady load — drives committed memory across the pressure watermark",
    ),
    (
        "churn",
        "tenant cohorts arrive/depart mid-trace (deploy/delete churn under load)",
    ),
    (
        "paper-mix",
        "the 8 paper workloads, idle-heavy Poisson (small-scale continuity)",
    ),
];

/// A built scenario: the functions to deploy and the trace to replay.
pub struct ScenarioRun {
    pub name: String,
    pub seed: u64,
    pub duration_ns: u64,
    pub specs: Vec<WorkloadSpec>,
    pub events: Vec<TraceEvent>,
}

/// Build scenario `name` with `funcs` synthetic functions over
/// `duration_ns` of virtual time. Unknown names list the directory.
pub fn build(name: &str, funcs: usize, duration_ns: u64, seed: u64) -> Result<ScenarioRun> {
    let funcs = funcs.max(1);
    let (specs, events) = match name {
        "azure-heavy-tail" => azure_heavy_tail(funcs, duration_ns, seed),
        "diurnal-wave" => diurnal_wave(funcs, duration_ns, seed),
        "flash-crowd" => flash_crowd(funcs, duration_ns, seed),
        "tenant-skewed" => tenant_skewed(funcs, duration_ns, seed),
        "memory-heavy" => memory_heavy(funcs, duration_ns, seed),
        "churn" => churn(funcs, duration_ns, seed),
        "paper-mix" => paper_mix(duration_ns, seed),
        _ => {
            let known: Vec<&str> = SCENARIOS.iter().map(|(n, _)| *n).collect();
            bail!("unknown scenario `{name}` (known: {})", known.join(", "));
        }
    };
    Ok(ScenarioRun {
        name: name.to_string(),
        seed,
        duration_ns,
        specs,
        events,
    })
}

/// N synthetic functions cycling through the 8 paper workloads, scaled
/// down [`MEM_SCALE`]×. Payloads are dropped: deterministic replay runs on
/// the no-op runner, so latency is purely charged model time.
fn synth_functions(funcs: usize) -> Vec<WorkloadSpec> {
    let bases = all_workloads();
    (0..funcs)
        .map(|i| {
            let mut s = scaled_for_test(bases[i % bases.len()].clone(), MEM_SCALE);
            s.name = format!("{}-{:04}", s.name, i);
            s.payload = None;
            s
        })
        .collect()
}

fn azure_heavy_tail(
    funcs: usize,
    duration_ns: u64,
    seed: u64,
) -> (Vec<WorkloadSpec>, Vec<TraceEvent>) {
    let specs = synth_functions(funcs);
    let traces: Vec<TraceSpec> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let rank = i as f64 / funcs as f64;
            let arrival = if rank < 0.02 {
                // The hot head: ~2% of functions, sub-second cadence.
                Arrival::Poisson {
                    mean_gap_ns: 80_000_000,
                }
            } else if rank < 0.10 {
                Arrival::Poisson {
                    mean_gap_ns: 800_000_000,
                }
            } else if rank < 0.40 {
                Arrival::Bursty {
                    median_gap_ns: 20_000_000_000,
                    sigma: 1.0,
                    burst: 4,
                }
            } else {
                // The long tail: rare, heavy-tailed, small bursts.
                Arrival::Bursty {
                    median_gap_ns: 120_000_000_000,
                    sigma: 1.5,
                    burst: 2,
                }
            };
            TraceSpec {
                workload: s.name.clone(),
                arrival,
            }
        })
        .collect();
    let events = generate(&traces, duration_ns, seed);
    (specs, events)
}

fn diurnal_wave(
    funcs: usize,
    duration_ns: u64,
    seed: u64,
) -> (Vec<WorkloadSpec>, Vec<TraceEvent>) {
    let specs = synth_functions(funcs);
    // Four waves over the trace; arrivals are generated at peak rate and
    // thinned by the wave's instantaneous intensity (classic thinning — the
    // accept draw is part of the same deterministic per-function stream).
    let period_ns = (duration_ns / 4).max(1);
    let mut events = Vec::new();
    for (i, s) in specs.iter().enumerate() {
        let mut rng = Rng::new(seed ^ ((i as u64 + 1) * 0x9E37_79B9));
        let peak_gap_ns: f64 = if i % 10 == 0 { 500e6 } else { 8e9 };
        let mut t = 0u64;
        loop {
            t = t.saturating_add((rng.exp(peak_gap_ns) as u64).max(1));
            if t >= duration_ns {
                break;
            }
            let phase = (t % period_ns) as f64 / period_ns as f64;
            let intensity = 0.5 * (1.0 - (2.0 * std::f64::consts::PI * phase).cos());
            if rng.chance(intensity.max(0.05)) {
                events.push(TraceEvent {
                    at_ns: t,
                    workload: s.name.clone(),
                });
            }
        }
    }
    events.sort_by_key(|e| e.at_ns);
    (specs, events)
}

fn flash_crowd(
    funcs: usize,
    duration_ns: u64,
    seed: u64,
) -> (Vec<WorkloadSpec>, Vec<TraceEvent>) {
    let specs = synth_functions(funcs);
    let background: Vec<TraceSpec> = specs
        .iter()
        .map(|s| TraceSpec {
            workload: s.name.clone(),
            arrival: Arrival::Poisson {
                mean_gap_ns: 30_000_000_000,
            },
        })
        .collect();
    let mut events = generate(&background, duration_ns, seed);
    // The crowd: a third of all functions fire an 8-deep burst within half
    // a second of the trace midpoint.
    let crowd_ns = duration_ns / 2;
    let mut rng = Rng::new(seed ^ 0xF1A5_4C20_3D);
    for (i, s) in specs.iter().enumerate() {
        if i % 3 != 0 {
            continue;
        }
        let start = crowd_ns + rng.below(500_000_000);
        for b in 0..8u64 {
            let at = start + b * 2_000_000;
            if at < duration_ns {
                events.push(TraceEvent {
                    at_ns: at,
                    workload: s.name.clone(),
                });
            }
        }
    }
    events.sort_by_key(|e| e.at_ns);
    (specs, events)
}

fn tenant_skewed(
    funcs: usize,
    duration_ns: u64,
    seed: u64,
) -> (Vec<WorkloadSpec>, Vec<TraceEvent>) {
    let mut specs = synth_functions(funcs);
    for (i, s) in specs.iter_mut().enumerate() {
        // The `tNN-` prefix is the tenancy contract —
        // `platform::policy::tenant_of` parses it.
        s.name = format!("t{:02}-{}", i % TENANTS, s.name);
    }
    let traces: Vec<TraceSpec> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let arrival = if i % TENANTS == 0 {
                // Tenant 0 dominates: every one of its functions is hot.
                Arrival::Poisson {
                    mean_gap_ns: 400_000_000,
                }
            } else {
                Arrival::Poisson {
                    mean_gap_ns: 45_000_000_000,
                }
            };
            TraceSpec {
                workload: s.name.clone(),
                arrival,
            }
        })
        .collect();
    let events = generate(&traces, duration_ns, seed);
    (specs, events)
}

/// Memory scale-down for `memory-heavy` functions: only 8× (vs the usual
/// 64×), so a modest function count holds enough committed memory to cross
/// a realistic pressure watermark.
pub const MEM_HEAVY_SCALE: u64 = 8;

fn memory_heavy(
    funcs: usize,
    duration_ns: u64,
    seed: u64,
) -> (Vec<WorkloadSpec>, Vec<TraceEvent>) {
    // Fat functions under steady, moderately-spaced Poisson load: most of
    // the fleet is warm at any instant, so committed memory climbs until
    // the pressure watermark forces deflation — the path this scenario
    // exists to exercise (idleness alone won't trigger under this cadence).
    let bases = all_workloads();
    let specs: Vec<WorkloadSpec> = (0..funcs)
        .map(|i| {
            let mut s =
                scaled_for_test(bases[i % bases.len()].clone(), MEM_HEAVY_SCALE);
            s.name = format!("mem-{}-{:04}", s.name, i);
            s.payload = None;
            s
        })
        .collect();
    let traces: Vec<TraceSpec> = specs
        .iter()
        .map(|s| TraceSpec {
            workload: s.name.clone(),
            arrival: Arrival::Poisson {
                mean_gap_ns: 3_000_000_000,
            },
        })
        .collect();
    let events = generate(&traces, duration_ns, seed);
    (specs, events)
}

/// Tenant cohort boundaries for `churn`: departing tenants fall silent at
/// 60% of the trace, arriving tenants start at 40% — the overlap is the
/// peak-population middle.
pub const CHURN_ARRIVE_FRAC: (u64, u64) = (4, 10);
/// See [`CHURN_ARRIVE_FRAC`].
pub const CHURN_DEPART_FRAC: (u64, u64) = (6, 10);

fn churn(funcs: usize, duration_ns: u64, seed: u64) -> (Vec<WorkloadSpec>, Vec<TraceEvent>) {
    // Tenant cohorts by tenant id: 0–3 resident for the whole trace,
    // 4–6 departing (traffic stops at 60%), 7–9 arriving (traffic starts
    // at 40%). The `tNN-` prefix keeps the tenancy machinery engaged, so
    // an arriving tenant is a *tenant-level* event for the budget policy,
    // not just N unrelated cold starts.
    let mut specs = synth_functions(funcs);
    for (i, s) in specs.iter_mut().enumerate() {
        s.name = format!("t{:02}-{}", i % TENANTS, s.name);
    }
    let arrive_ns = duration_ns / CHURN_ARRIVE_FRAC.1 * CHURN_ARRIVE_FRAC.0;
    let depart_ns = duration_ns / CHURN_DEPART_FRAC.1 * CHURN_DEPART_FRAC.0;
    let traces: Vec<TraceSpec> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            // Arriving tenants are hot (they show up as a surge); the
            // standing population idles enough to hibernate between calls.
            let arrival = if i % TENANTS >= 7 {
                Arrival::Poisson {
                    mean_gap_ns: 600_000_000,
                }
            } else {
                Arrival::Poisson {
                    mean_gap_ns: 2_500_000_000,
                }
            };
            TraceSpec {
                workload: s.name.clone(),
                arrival,
            }
        })
        .collect();
    let mut events = generate(&traces, duration_ns, seed);
    // Apply the activity windows. Cohort is a pure function of the name's
    // tenant prefix, so the filter is deterministic and order-preserving.
    let cohort = |w: &str| -> u8 {
        let t: usize = w[1..3].parse().unwrap_or(0);
        match t % TENANTS {
            0..=3 => 0, // resident
            4..=6 => 1, // departing
            _ => 2,     // arriving
        }
    };
    events.retain(|e| match cohort(&e.workload) {
        1 => e.at_ns < depart_ns,
        2 => e.at_ns >= arrive_ns,
        _ => true,
    });
    (specs, events)
}

fn paper_mix(duration_ns: u64, seed: u64) -> (Vec<WorkloadSpec>, Vec<TraceEvent>) {
    let specs: Vec<WorkloadSpec> = all_workloads()
        .into_iter()
        .map(|w| scaled_for_test(w, 16))
        .collect();
    let traces: Vec<TraceSpec> = specs
        .iter()
        .map(|s| TraceSpec {
            workload: s.name.clone(),
            arrival: Arrival::Poisson {
                mean_gap_ns: 1_000_000_000,
            },
        })
        .collect();
    let events = generate(&traces, duration_ns, seed);
    (specs, events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn sane(run: &ScenarioRun) {
        assert!(!run.events.is_empty(), "{}: empty trace", run.name);
        assert!(
            run.events.windows(2).all(|w| w[0].at_ns <= w[1].at_ns),
            "{}: trace must be time-sorted",
            run.name
        );
        assert!(
            run.events.iter().all(|e| e.at_ns < run.duration_ns),
            "{}: events must stay inside the trace window",
            run.name
        );
        let deployed: HashSet<&str> = run.specs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            deployed.len(),
            run.specs.len(),
            "{}: function names must be unique",
            run.name
        );
        assert!(
            run.events.iter().all(|e| deployed.contains(e.workload.as_str())),
            "{}: every event must target a deployed function",
            run.name
        );
        for s in &run.specs {
            s.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
        }
    }

    #[test]
    fn every_scenario_builds_sane_and_deterministic() {
        for (name, _) in SCENARIOS {
            let a = build(name, 64, 20_000_000_000, 7).unwrap();
            let b = build(name, 64, 20_000_000_000, 7).unwrap();
            let c = build(name, 64, 20_000_000_000, 8).unwrap();
            sane(&a);
            assert_eq!(a.events, b.events, "{name}: same seed, same trace");
            assert_ne!(a.events, c.events, "{name}: different seed, different trace");
        }
    }

    #[test]
    fn unknown_scenario_lists_the_directory() {
        let err = build("nope", 8, 1_000_000_000, 1).unwrap_err();
        assert!(err.to_string().contains("azure-heavy-tail"), "{err}");
    }

    #[test]
    fn heavy_tail_reaches_acceptance_scale() {
        // The acceptance shape: 1000 functions, ≥ 100k events over 300 s.
        let run = build("azure-heavy-tail", 1000, 300_000_000_000, 42).unwrap();
        assert_eq!(run.specs.len(), 1000);
        assert!(
            run.events.len() >= 100_000,
            "heavy-tail at full scale must produce ≥ 100k events, got {}",
            run.events.len()
        );
        // The head is hot: the top 2% of functions carry the majority.
        let head: HashSet<&str> = run
            .specs
            .iter()
            .take(20)
            .map(|s| s.name.as_str())
            .collect();
        let head_events = run
            .events
            .iter()
            .filter(|e| head.contains(e.workload.as_str()))
            .count();
        assert!(
            head_events * 2 > run.events.len(),
            "head must dominate: {head_events}/{}",
            run.events.len()
        );
    }

    #[test]
    fn flash_crowd_spikes_at_the_midpoint() {
        let run = build("flash-crowd", 90, 60_000_000_000, 3).unwrap();
        let mid = run.duration_ns / 2;
        let in_window = run
            .events
            .iter()
            .filter(|e| e.at_ns >= mid && e.at_ns < mid + 1_000_000_000)
            .count();
        // 30 functions × 8-deep bursts land inside [mid, mid+1s).
        assert!(in_window >= 200, "crowd must spike: {in_window}");
    }

    #[test]
    fn memory_heavy_functions_are_actually_fat() {
        let heavy = build("memory-heavy", 64, 20_000_000_000, 11).unwrap();
        let light = build("azure-heavy-tail", 64, 20_000_000_000, 11).unwrap();
        let mean_pages = |r: &ScenarioRun| {
            r.specs.iter().map(|s| s.init_anon_pages).sum::<u64>() / r.specs.len() as u64
        };
        assert!(
            mean_pages(&heavy) >= 4 * mean_pages(&light),
            "memory-heavy must carry a much larger anon footprint: {} vs {}",
            mean_pages(&heavy),
            mean_pages(&light)
        );
        // Steady cadence: every function is invoked repeatedly, so the
        // fleet stays warm and committed memory accumulates.
        let names: HashSet<&str> =
            heavy.events.iter().map(|e| e.workload.as_str()).collect();
        assert!(
            names.len() * 10 >= heavy.specs.len() * 9,
            "steady load must touch ~every function: {}/{}",
            names.len(),
            heavy.specs.len()
        );
    }

    #[test]
    fn tenant_names_parse_as_tenants() {
        // The policy layer's tenancy contract: every tenant-skewed
        // function name must resolve to its tenant, and no other
        // scenario's names may accidentally look tenanted.
        use crate::platform::policy::tenant_of;
        let run = build("tenant-skewed", 50, 10_000_000_000, 9).unwrap();
        for (i, s) in run.specs.iter().enumerate() {
            assert_eq!(
                tenant_of(&s.name),
                Some(format!("t{:02}", i % TENANTS).as_str()),
                "{}",
                s.name
            );
        }
        let plain = build("azure-heavy-tail", 16, 10_000_000_000, 9).unwrap();
        for s in &plain.specs {
            assert_eq!(tenant_of(&s.name), None, "{}", s.name);
        }
    }

    #[test]
    fn churn_cohorts_respect_their_activity_windows() {
        let run = build("churn", 100, 60_000_000_000, 13).unwrap();
        let arrive_ns = run.duration_ns / 10 * 4;
        let depart_ns = run.duration_ns / 10 * 6;
        let tenant = |w: &str| w[1..3].parse::<usize>().unwrap();
        let mut seen = [false; 3];
        for e in &run.events {
            match tenant(&e.workload) {
                0..=3 => seen[0] = true,
                t @ 4..=6 => {
                    seen[1] = true;
                    assert!(
                        e.at_ns < depart_ns,
                        "departed tenant t{t:02} invoked at {} ≥ {depart_ns}",
                        e.at_ns
                    );
                }
                t => {
                    seen[2] = true;
                    assert!(
                        e.at_ns >= arrive_ns,
                        "unarrived tenant t{t:02} invoked at {} < {arrive_ns}",
                        e.at_ns
                    );
                }
            }
        }
        assert_eq!(seen, [true; 3], "all three cohorts must carry traffic");
        // Every name still parses as a tenant (the budget policy engages).
        use crate::platform::policy::tenant_of;
        for s in &run.specs {
            assert!(tenant_of(&s.name).is_some(), "{}", s.name);
        }
    }

    #[test]
    fn tenant_skew_dominates_traffic() {
        let run = build("tenant-skewed", 100, 60_000_000_000, 5).unwrap();
        let t0 = run
            .events
            .iter()
            .filter(|e| e.workload.starts_with("t00-"))
            .count();
        assert!(
            t0 * 2 > run.events.len(),
            "tenant 0 must dominate: {t0}/{}",
            run.events.len()
        );
    }
}
