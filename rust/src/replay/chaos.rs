//! Deterministic chaos engine: a seeded fault plan that is a pure
//! function of `(seed, workload, fault domain, invocation index)`.
//!
//! ## Determinism contract
//!
//! The plan holds one invocation counter per `(workload, domain)` key.
//! Every call site that consults the plan for a workload is serialized by
//! the replay model — requests and policy decisions for a workload only
//! ever run on the replay worker owning its control-plane shard, in
//! virtual-time order — so each counter advances identically at any
//! worker count, and the fault sequence each workload experiences is
//! bit-identical at `--workers 1` vs `--workers 8`. Faults themselves are
//! stamped on the *virtual* clock (a slow-I/O fault charges virtual
//! nanoseconds, a hung job burns virtual budget), never on wall time, so
//! chaos runs join the replay fingerprint sweep unchanged.
//!
//! Fault families (see [`crate::config::ChaosConfig`]):
//! - **Crash** — the sandbox dies mid-request; the platform salvages the
//!   hibernated image's manifest when one still describes the on-disk
//!   image and re-adopts it, else cold-starts a replacement.
//! - **Poison** — the request fails with a typed [`Poisoned`] error (a
//!   bad deploy failing every Nth invocation); food for the circuit
//!   breaker.
//! - **SlowIo** — the request is charged extra virtual I/O latency (the
//!   PR 8 transient-I/O taxonomy, without the wall-clock sleep).
//! - **Hang / Stall** — a pipeline inflation (resp. deflation/teardown)
//!   job burns virtual time past the watchdog budget and is cancelled.
//! - **Panic** — a pipeline job panics mid-job via
//!   [`std::panic::panic_any`] with a [`ChaosPanic`] payload; the
//!   worker's `catch_unwind` fence must contain it.

use crate::config::ChaosConfig;
use crate::util::fnv1a;
use crate::util::rng::SplitMix64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Fault codes carried in [`crate::obs::EventKind::FaultInject`] args.
pub const FAULT_CRASH: u64 = 1;
pub const FAULT_POISON: u64 = 2;
pub const FAULT_SLOW_IO: u64 = 3;
pub const FAULT_HANG: u64 = 4;
pub const FAULT_STALL: u64 = 5;
pub const FAULT_PANIC: u64 = 6;

/// A fault the plan injects on the request path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestFault {
    /// The sandbox serving this request dies.
    Crash,
    /// The request fails with a typed [`Poisoned`] error.
    Poison,
    /// The request is charged this many extra virtual nanoseconds.
    SlowIo { ns: u64 },
}

/// A fault the plan injects on a pipeline job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobFault {
    /// The job burns this much virtual time (watchdog food).
    Hang { ns: u64 },
    /// The job panics mid-job (`catch_unwind` fence food).
    Panic,
}

impl JobFault {
    /// The [`FaultInject`](crate::obs::EventKind::FaultInject) arg code,
    /// split by pipeline direction (a hung inflation and a stalled
    /// deflation are distinct families).
    pub fn code(self, inflate: bool) -> u64 {
        match self {
            JobFault::Hang { .. } if inflate => FAULT_HANG,
            JobFault::Hang { .. } => FAULT_STALL,
            JobFault::Panic => FAULT_PANIC,
        }
    }
}

/// Typed payload a chaos-injected pipeline panic unwinds with
/// ([`std::panic::panic_any`]): the fence downcasts it to tell an
/// injected panic from a genuine bug.
#[derive(Debug)]
pub struct ChaosPanic {
    pub workload: String,
}

/// Typed request error for a poisoned function: the chaos plan's "fails
/// every Nth invocation" deploy. Recognized (downcast) by the circuit
/// breaker as a failure outcome and by replay as a non-fatal reject.
#[derive(Debug)]
pub struct Poisoned {
    pub workload: String,
}

impl std::fmt::Display for Poisoned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request poisoned by the chaos plan (workload {})", self.workload)
    }
}

impl std::error::Error for Poisoned {}

/// Per-`(workload, domain)` fault-plan state domains.
const DOMAIN_REQUEST: u64 = 0;
const DOMAIN_INFLATE: u64 = 1;
const DOMAIN_DEFLATE: u64 = 2;

/// The seeded fault plan. Construct via [`ChaosPlan::from_cfg`]; a
/// disabled config yields `None` so the hot path pays one `Option` check.
pub struct ChaosPlan {
    cfg: ChaosConfig,
    /// Invocation counters keyed by `(fnv1a(workload), domain)`. Each key
    /// is only ever advanced from the replay worker owning the workload's
    /// shard (see the module docs), so the map's lock is contention-only —
    /// the values it guards evolve deterministically.
    counters: Mutex<HashMap<(u64, u64), u64>>,
    /// Faults handed out (all families) — cheap liveness signal for
    /// assertions; authoritative counts live in
    /// [`crate::platform::metrics::ResilienceStats`].
    pub injected: AtomicU64,
}

impl ChaosPlan {
    /// Build the plan, or `None` when the config injects nothing.
    pub fn from_cfg(cfg: &ChaosConfig) -> Option<Arc<Self>> {
        if !cfg.any_faults() {
            return None;
        }
        Some(Arc::new(Self {
            cfg: cfg.clone(),
            counters: Mutex::new(HashMap::new()),
            injected: AtomicU64::new(0),
        }))
    }

    pub fn cfg(&self) -> &ChaosConfig {
        &self.cfg
    }

    /// Advance and return the invocation index for `(workload, domain)`.
    fn bump(&self, workload_hash: u64, domain: u64) -> u64 {
        let mut map = self.counters.lock().unwrap();
        let c = map.entry((workload_hash, domain)).or_insert(0);
        let idx = *c;
        *c += 1;
        idx
    }

    /// The pure draw: does fault `kind` fire for invocation `index` of
    /// `workload` in `domain`? A stateless hash of the full key against
    /// the family's per-mille threshold.
    fn draw(&self, workload_hash: u64, domain: u64, kind: u64, index: u64, per_mille: u64) -> bool {
        if per_mille == 0 {
            return false;
        }
        let key = self
            .cfg
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ workload_hash.rotate_left(17)
            ^ (domain << 56)
            ^ (kind << 48)
            ^ index.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        SplitMix64::new(key).next_u64() % 1000 < per_mille
    }

    /// Consult the plan for one routed request of `workload`. At most one
    /// fault fires per request; crash outranks poison outranks slow I/O.
    pub fn request_fault(&self, workload: &str) -> Option<RequestFault> {
        let h = fnv1a(workload);
        let idx = self.bump(h, DOMAIN_REQUEST);
        let fault = if self.draw(h, DOMAIN_REQUEST, 0, idx, self.cfg.crash_per_mille) {
            Some(RequestFault::Crash)
        } else if self.draw(h, DOMAIN_REQUEST, 1, idx, self.cfg.poison_per_mille) {
            Some(RequestFault::Poison)
        } else if self.draw(h, DOMAIN_REQUEST, 2, idx, self.cfg.slow_io_per_mille) {
            Some(RequestFault::SlowIo {
                ns: self.cfg.slow_io_ns,
            })
        } else {
            None
        };
        if fault.is_some() {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fault
    }

    /// Consult the plan for one dispatched pipeline job of `workload`.
    /// `inflate` selects the hang family (anticipatory wakes) vs the
    /// stall family (deflations/teardowns); panics can hit either.
    pub fn job_fault(&self, workload: &str, inflate: bool) -> Option<JobFault> {
        let h = fnv1a(workload);
        let domain = if inflate { DOMAIN_INFLATE } else { DOMAIN_DEFLATE };
        let idx = self.bump(h, domain);
        let per_mille = if inflate {
            self.cfg.hang_per_mille
        } else {
            self.cfg.stall_per_mille
        };
        let fault = if self.draw(h, domain, 0, idx, self.cfg.panic_per_mille) {
            Some(JobFault::Panic)
        } else if self.draw(h, domain, 1, idx, per_mille) {
            Some(JobFault::Hang {
                ns: self.cfg.hang_ns,
            })
        } else {
            None
        };
        if fault.is_some() {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fault
    }

    /// Total faults handed out so far (all families).
    pub fn injected_total(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(mix: impl FnOnce(&mut ChaosConfig)) -> Arc<ChaosPlan> {
        let mut cfg = ChaosConfig {
            enabled: true,
            seed: 0xD15EA5E,
            ..ChaosConfig::default()
        };
        mix(&mut cfg);
        ChaosPlan::from_cfg(&cfg).expect("faults configured")
    }

    #[test]
    fn disabled_or_faultless_config_builds_no_plan() {
        assert!(ChaosPlan::from_cfg(&ChaosConfig::default()).is_none());
        let enabled_but_empty = ChaosConfig {
            enabled: true,
            ..ChaosConfig::default()
        };
        assert!(ChaosPlan::from_cfg(&enabled_but_empty).is_none());
    }

    #[test]
    fn fault_sequence_is_a_pure_function_of_seed_and_workload() {
        let mk = || {
            plan(|c| {
                c.crash_per_mille = 50;
                c.poison_per_mille = 100;
                c.slow_io_per_mille = 200;
                c.hang_per_mille = 150;
                c.stall_per_mille = 150;
                c.panic_per_mille = 80;
            })
        };
        let (a, b) = (mk(), mk());
        for w in ["fn-0001", "fn-0002", "t03-fn-0007"] {
            for _ in 0..500 {
                assert_eq!(a.request_fault(w), b.request_fault(w));
                assert_eq!(a.job_fault(w, true), b.job_fault(w, true));
                assert_eq!(a.job_fault(w, false), b.job_fault(w, false));
            }
        }
        assert_eq!(a.injected_total(), b.injected_total());
        assert!(a.injected_total() > 0, "mix dense enough to fire");
    }

    #[test]
    fn interleaving_across_workloads_cannot_perturb_a_workloads_sequence() {
        // Workload A's fault sequence must not depend on how B's calls
        // interleave — the cross-worker-count determinism argument.
        let solo = plan(|c| c.poison_per_mille = 300);
        let seq_a: Vec<_> = (0..200).map(|_| solo.request_fault("a")).collect();
        let mixed = plan(|c| c.poison_per_mille = 300);
        let mut seq_b = Vec::new();
        for i in 0..200 {
            for _ in 0..(i % 3) {
                mixed.request_fault("b");
            }
            seq_b.push(mixed.request_fault("a"));
        }
        assert_eq!(seq_a, seq_b);
    }

    #[test]
    fn rates_land_near_the_configured_per_mille() {
        let p = plan(|c| c.poison_per_mille = 250);
        let n = 4000u64;
        let fired = (0..n)
            .filter(|_| p.request_fault("steady").is_some())
            .count() as u64;
        // 250‰ of 4000 = 1000 expected; accept a generous band.
        assert!((700..=1300).contains(&fired), "fired {fired}/4000");
    }

    #[test]
    fn crash_outranks_poison_and_families_stay_separated() {
        // With certainty-adjacent rates, every request faults and the
        // highest-priority family wins; job domains never see request
        // faults and hang/stall respect the pipeline direction.
        let p = plan(|c| {
            c.crash_per_mille = 999;
            c.poison_per_mille = 999;
        });
        for _ in 0..100 {
            assert_eq!(p.request_fault("w"), Some(RequestFault::Crash));
            assert_eq!(p.job_fault("w", true), None, "no hang family configured");
        }
        let p = plan(|c| c.hang_per_mille = 999);
        for _ in 0..100 {
            assert!(matches!(p.job_fault("w", true), Some(JobFault::Hang { .. })));
            assert_eq!(p.job_fault("w", false), None, "stall family separate");
        }
    }

    #[test]
    fn poisoned_error_downcasts_through_anyhow() {
        let err = anyhow::Error::new(Poisoned {
            workload: "w".into(),
        });
        assert!(err.chain().any(|c| c.downcast_ref::<Poisoned>().is_some()));
        assert!(err.to_string().contains("poisoned"));
    }
}
