//! Replay reporting: per-function and aggregate latency summaries,
//! lifecycle counters, the memory-density timeline, a deterministic
//! fingerprint (the bit-identity acceptance check compares these across
//! worker counts), and JSON export via [`crate::util::json`].

use super::ReplayOutcome;
use crate::platform::metrics::ServedFrom;
use crate::platform::Platform;
use crate::util::json::{obj, Json};
use crate::util::stats::{Histogram, Summary};
use crate::util::{fnv1a, human_bytes, human_ns};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One function's (or the aggregate's) replay summary.
///
/// Percentiles (p50/p99/p999) come from the exact-merge [`Histogram`],
/// so the aggregate row equals what a bucket-wise merge of the
/// per-function histograms would report — no sample-list lossiness.
/// Mean and max stay exact via [`Summary`]. All inputs are virtual-time
/// latencies, so every field is deterministic across worker counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FunctionRow {
    pub name: String,
    pub n: u64,
    pub mean_ns: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
    pub max_ns: u64,
    pub cold: u64,
    pub warm: u64,
    pub hibernate: u64,
    pub woken: u64,
}

impl FunctionRow {
    fn from_stats(name: &str, s: &Summary, h: &Histogram, paths: &[u64; 4]) -> Self {
        Self {
            name: name.to_string(),
            n: s.len() as u64,
            mean_ns: s.mean() as u64,
            p50_ns: h.p50(),
            p99_ns: h.p99(),
            p999_ns: h.p999(),
            max_ns: s.max(),
            cold: paths[0],
            warm: paths[1],
            hibernate: paths[2],
            woken: paths[3],
        }
    }

    fn write_canonical(&self, out: &mut String) {
        let _ = write!(
            out,
            "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{};",
            self.name,
            self.n,
            self.mean_ns,
            self.p50_ns,
            self.p99_ns,
            self.p999_ns,
            self.max_ns,
            self.cold,
            self.warm,
            self.hibernate,
            self.woken
        );
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("n", Json::Num(self.n as f64)),
            ("mean_ns", Json::Num(self.mean_ns as f64)),
            ("p50_ns", Json::Num(self.p50_ns as f64)),
            ("p99_ns", Json::Num(self.p99_ns as f64)),
            ("p999_ns", Json::Num(self.p999_ns as f64)),
            ("max_ns", Json::Num(self.max_ns as f64)),
            ("cold", Json::Num(self.cold as f64)),
            ("warm", Json::Num(self.warm as f64)),
            ("hibernate", Json::Num(self.hibernate as f64)),
            ("woken", Json::Num(self.woken as f64)),
        ])
    }
}

/// The full replay report.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub scenario: String,
    /// The policy that made the run's decisions (`policy.kind`).
    pub policy: String,
    pub seed: u64,
    pub workers: usize,
    pub events: usize,
    pub wall_ns: u64,
    /// Per-function rows, sorted by name.
    pub functions: Vec<FunctionRow>,
    /// All functions folded together.
    pub aggregate: FunctionRow,
    pub counters: Vec<(&'static str, u64)>,
    /// `(epoch_start_vns, committed_bytes)` density timeline.
    pub mem_timeline: Vec<(u64, u64)>,
    /// `(epoch_start_vns, [(tenant, live_bytes)])` per-tenant density
    /// timeline — empty unless the config tracks tenants.
    pub tenant_timeline: Vec<(u64, Vec<(String, u64)>)>,
    /// Final instance census: `(workload, state_label, count)`.
    pub final_states: Vec<(String, String, u64)>,
    /// Committed host bytes after the replay.
    pub final_committed: u64,
}

fn path_slot(from: ServedFrom) -> usize {
    match from {
        ServedFrom::ColdStart => 0,
        ServedFrom::Warm => 1,
        ServedFrom::Hibernate => 2,
        ServedFrom::WokenUp => 3,
    }
}

impl ReplayReport {
    /// Aggregate one replay's outcome against the platform it ran on.
    pub fn build(
        scenario: &str,
        seed: u64,
        platform: &Platform,
        outcome: &ReplayOutcome,
    ) -> Self {
        let mut per_fn: BTreeMap<String, (Summary, Histogram, [u64; 4])> = BTreeMap::new();
        let mut all = Summary::new();
        let mut all_hist = Histogram::new();
        let mut all_paths = [0u64; 4];
        for r in &outcome.reports {
            // get_mut, not entry(): entry() would clone the workload String
            // on every one of the ~100k reports when ~99% of lookups hit an
            // existing key; one lookup on the hit path, clone only on miss.
            match per_fn.get_mut(&r.workload) {
                Some((summary, hist, paths)) => {
                    summary.add(r.latency_ns);
                    hist.record(r.latency_ns);
                    paths[path_slot(r.served_from)] += 1;
                }
                None => {
                    let mut summary = Summary::new();
                    summary.add(r.latency_ns);
                    let mut hist = Histogram::new();
                    hist.record(r.latency_ns);
                    let mut paths = [0u64; 4];
                    paths[path_slot(r.served_from)] += 1;
                    per_fn.insert(r.workload.clone(), (summary, hist, paths));
                }
            }
            all.add(r.latency_ns);
            all_hist.record(r.latency_ns);
            all_paths[path_slot(r.served_from)] += 1;
        }
        let functions: Vec<FunctionRow> = per_fn
            .iter()
            .map(|(name, (summary, hist, paths))| {
                FunctionRow::from_stats(name, summary, hist, paths)
            })
            .collect();
        let aggregate = FunctionRow::from_stats("__all__", &all, &all_hist, &all_paths);

        let mut final_states = Vec::new();
        for (workload, _wake_lead, rows) in platform.pool_snapshot() {
            let mut by_state: BTreeMap<String, u64> = BTreeMap::new();
            for (state, _bytes) in rows {
                *by_state.entry(state.to_string()).or_default() += 1;
            }
            for (state, count) in by_state {
                final_states.push((workload.clone(), state, count));
            }
        }

        Self {
            scenario: scenario.to_string(),
            policy: platform.policy_name().to_string(),
            seed,
            workers: outcome.workers,
            events: outcome.reports.len(),
            wall_ns: outcome.wall_ns,
            functions,
            aggregate,
            counters: platform.metrics.counters.snapshot(),
            mem_timeline: outcome.mem_timeline.clone(),
            tenant_timeline: outcome.tenant_timeline.clone(),
            final_states,
            final_committed: platform.memory_used(),
        }
    }

    /// Deterministic fingerprint over everything virtual-time-derived:
    /// per-function rows, the aggregate, lifecycle counters, the density
    /// timeline and the final pool census — everything except wall-clock
    /// and worker count. Two replays of the same scenario at different
    /// `--workers` must produce equal fingerprints.
    pub fn fingerprint(&self) -> u64 {
        let mut canon = String::new();
        let _ = write!(canon, "{}@{}#{};", self.scenario, self.seed, self.events);
        for f in &self.functions {
            f.write_canonical(&mut canon);
        }
        self.aggregate.write_canonical(&mut canon);
        for (k, v) in &self.counters {
            let _ = write!(canon, "{k}={v};");
        }
        for (t, b) in &self.mem_timeline {
            let _ = write!(canon, "{t}:{b};");
        }
        // Tenant rows only when tracked, so non-tenant runs keep their
        // canonical form (and fingerprints) from before tenant accounting.
        for (t, rows) in &self.tenant_timeline {
            let _ = write!(canon, "T{t}[");
            for (name, used) in rows {
                let _ = write!(canon, "{name}={used};");
            }
            let _ = write!(canon, "];");
        }
        for (w, s, c) in &self.final_states {
            let _ = write!(canon, "{w}/{s}={c};");
        }
        let _ = write!(canon, "committed={}", self.final_committed);
        fnv1a(&canon)
    }

    /// JSON export (the CI smoke job uploads this as an artifact).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("policy", Json::Str(self.policy.clone())),
            // Hex string, not a JSON number: u64 seeds above 2^53 would
            // silently lose precision as f64, and the seed must replay the
            // scenario exactly.
            ("seed", Json::Str(format!("0x{:016x}", self.seed))),
            ("workers", Json::Num(self.workers as f64)),
            ("events", Json::Num(self.events as f64)),
            ("wall_ns", Json::Num(self.wall_ns as f64)),
            (
                "fingerprint",
                Json::Str(format!("{:016x}", self.fingerprint())),
            ),
            ("aggregate", self.aggregate.to_json()),
            (
                "functions",
                Json::Arr(self.functions.iter().map(|f| f.to_json()).collect()),
            ),
            (
                "counters",
                obj(self
                    .counters
                    .iter()
                    .map(|(k, v)| (*k, Json::Num(*v as f64)))
                    .collect()),
            ),
            (
                "mem_timeline",
                Json::Arr(
                    self.mem_timeline
                        .iter()
                        .map(|(t, b)| {
                            Json::Arr(vec![Json::Num(*t as f64), Json::Num(*b as f64)])
                        })
                        .collect(),
                ),
            ),
            (
                "tenant_timeline",
                Json::Arr(
                    self.tenant_timeline
                        .iter()
                        .map(|(t, rows)| {
                            obj(vec![
                                ("at_ns", Json::Num(*t as f64)),
                                (
                                    "tenants",
                                    Json::Arr(
                                        rows.iter()
                                            .map(|(name, used)| {
                                                obj(vec![
                                                    ("tenant", Json::Str(name.clone())),
                                                    ("live_bytes", Json::Num(*used as f64)),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "final_states",
                Json::Arr(
                    self.final_states
                        .iter()
                        .map(|(w, s, c)| {
                            obj(vec![
                                ("workload", Json::Str(w.clone())),
                                ("state", Json::Str(s.clone())),
                                ("count", Json::Num(*c as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("final_committed", Json::Num(self.final_committed as f64)),
        ])
    }

    /// Write the JSON report to `path`.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string())
            .with_context(|| format!("writing replay report {}", path.as_ref().display()))
    }

    /// Human summary: the aggregate, the busiest functions, counters and
    /// the density envelope.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "scenario {} policy {} seed {:#x}: {} events, {} functions, {} workers, wall {}",
            self.scenario,
            self.policy,
            self.seed,
            self.events,
            self.functions.len(),
            self.workers,
            human_ns(self.wall_ns),
        );
        let row = |out: &mut String, f: &FunctionRow| {
            let _ = writeln!(
                out,
                "{:<28} n={:<7} mean={:>10} p50={:>10} p99={:>10} p999={:>10} cold={} warm={} hib={} woken={}",
                f.name,
                f.n,
                human_ns(f.mean_ns),
                human_ns(f.p50_ns),
                human_ns(f.p99_ns),
                human_ns(f.p999_ns),
                f.cold,
                f.warm,
                f.hibernate,
                f.woken,
            );
        };
        row(&mut out, &self.aggregate);
        let mut busiest: Vec<&FunctionRow> = self.functions.iter().collect();
        busiest.sort_by_key(|f| std::cmp::Reverse(f.n));
        for f in busiest.iter().take(5) {
            row(&mut out, f);
        }
        let _ = write!(out, "counters:");
        for (k, v) in &self.counters {
            let _ = write!(out, " {k}={v}");
        }
        let _ = writeln!(out);
        if let (Some(min), Some(max)) = (
            self.mem_timeline.iter().map(|(_, b)| *b).min(),
            self.mem_timeline.iter().map(|(_, b)| *b).max(),
        ) {
            let _ = writeln!(
                out,
                "memory: {} … {} over {} epochs, final {}",
                human_bytes(min),
                human_bytes(max),
                self.mem_timeline.len(),
                human_bytes(self.final_committed),
            );
        }
        if let Some((_, last)) = self.tenant_timeline.last() {
            let _ = write!(out, "tenants (final epoch):");
            for (name, used) in last {
                let _ = write!(out, " {name}={}", human_bytes(*used));
            }
            let _ = writeln!(out);
        }
        let _ = writeln!(out, "fingerprint: {:016x}", self.fingerprint());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::sandbox::RequestOutcome;
    use crate::container::state::ContainerState;
    use crate::platform::RequestReport;

    fn fake_report(workload: &str, from: ServedFrom, latency_ns: u64) -> RequestReport {
        RequestReport {
            workload: workload.to_string(),
            served_from: from,
            latency_ns,
            charged_ns: latency_ns,
            measured_ns: 0,
            outcome: RequestOutcome {
                from: ContainerState::Warm,
                sample_request: false,
                anon_faults: 0,
                file_miss_bytes: 0,
                reap_prefetched: 0,
                admission_ns: 0,
            },
        }
    }

    fn fake_outcome(reports: Vec<RequestReport>) -> ReplayOutcome {
        ReplayOutcome {
            reports,
            mem_timeline: vec![(0, 100), (100_000_000, 200)],
            tenant_timeline: Vec::new(),
            workers: 2,
            wall_ns: 12345,
        }
    }

    fn rig_platform() -> Platform {
        let mut cfg = crate::config::PlatformConfig::default();
        cfg.host_memory = 128 << 20;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!("qh-report-test-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        Platform::new(cfg, std::sync::Arc::new(crate::container::NoopRunner)).unwrap()
    }

    #[test]
    fn rows_aggregate_and_sort() {
        let p = rig_platform();
        let outcome = fake_outcome(vec![
            fake_report("b", ServedFrom::Warm, 100),
            fake_report("a", ServedFrom::ColdStart, 1000),
            fake_report("b", ServedFrom::Hibernate, 300),
        ]);
        let r = ReplayReport::build("test", 7, &p, &outcome);
        assert_eq!(r.events, 3);
        assert_eq!(r.functions.len(), 2);
        assert_eq!(r.functions[0].name, "a");
        assert_eq!(r.functions[1].n, 2);
        assert_eq!(r.functions[1].warm, 1);
        assert_eq!(r.functions[1].hibernate, 1);
        assert_eq!(r.aggregate.n, 3);
        assert_eq!(r.aggregate.cold, 1);
        assert_eq!(r.aggregate.p99_ns, 1000);
    }

    #[test]
    fn fingerprint_ignores_wall_and_workers_but_not_results() {
        let p = rig_platform();
        let base = fake_outcome(vec![fake_report("a", ServedFrom::Warm, 100)]);
        let r1 = ReplayReport::build("test", 7, &p, &base);

        let mut faster = fake_outcome(vec![fake_report("a", ServedFrom::Warm, 100)]);
        faster.wall_ns = 1;
        faster.workers = 8;
        let r2 = ReplayReport::build("test", 7, &p, &faster);
        assert_eq!(r1.fingerprint(), r2.fingerprint());

        let changed = fake_outcome(vec![fake_report("a", ServedFrom::Warm, 101)]);
        let r3 = ReplayReport::build("test", 7, &p, &changed);
        assert_ne!(r1.fingerprint(), r3.fingerprint());
    }

    #[test]
    fn fingerprint_excludes_recorder_and_wake_histograms() {
        let p = rig_platform();
        let outcome = fake_outcome(vec![fake_report("a", ServedFrom::Warm, 100)]);
        let r1 = ReplayReport::build("test", 7, &p, &outcome);
        // Pollute every fingerprint-excluded observability surface: the
        // flight recorder and the wake-phase histograms. A rebuilt report
        // must hash identically — the exclusion contract of
        // docs/observability.md.
        assert!(p.metrics.recorder.is_enabled());
        p.metrics
            .recorder
            .emit_workload(crate::obs::EventKind::WakeBegin, 1, 42, 0, 5);
        p.metrics.record_queue_wait(1_000);
        p.metrics.record_inflate(2_000);
        p.metrics.record_admission(3_000);
        let r2 = ReplayReport::build("test", 7, &p, &outcome);
        assert_eq!(
            r1.fingerprint(),
            r2.fingerprint(),
            "recorder/histogram state must never enter the replay fingerprint"
        );
    }

    #[test]
    fn tenant_timeline_fingerprints_and_exports() {
        let p = rig_platform();
        let base = fake_outcome(vec![fake_report("t00-a", ServedFrom::Warm, 100)]);
        let r_plain = ReplayReport::build("test", 7, &p, &base);

        let mut with_tenants = fake_outcome(vec![fake_report("t00-a", ServedFrom::Warm, 100)]);
        with_tenants.tenant_timeline =
            vec![(0, vec![("t00".to_string(), 4096), ("t01".to_string(), 0)])];
        let r_tenants = ReplayReport::build("test", 7, &p, &with_tenants);
        assert_ne!(
            r_plain.fingerprint(),
            r_tenants.fingerprint(),
            "the tenant timeline must be part of the replay identity"
        );
        let text = r_tenants.to_json().to_string();
        let back = crate::util::json::parse(&text).unwrap();
        let tl = back.get("tenant_timeline").unwrap().as_arr().unwrap();
        assert_eq!(tl.len(), 1);
        assert_eq!(
            tl[0].get("tenants").unwrap().as_arr().unwrap().len(),
            2
        );
        assert!(r_tenants.summary().contains("tenants (final epoch):"));
        assert!(back.get("policy").unwrap().as_str().is_some());
    }

    #[test]
    fn json_round_trips_and_summary_renders() {
        let p = rig_platform();
        let outcome = fake_outcome(vec![
            fake_report("a", ServedFrom::Warm, 100),
            fake_report("a", ServedFrom::WokenUp, 150),
        ]);
        let r = ReplayReport::build("test", 7, &p, &outcome);
        let text = r.to_json().to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("events").unwrap().as_u64(), Some(2));
        assert_eq!(
            back.get("seed").unwrap().as_str(),
            Some("0x0000000000000007"),
            "seed must round-trip exactly (hex string, not f64)"
        );
        assert_eq!(
            back.get("functions").unwrap().as_arr().unwrap().len(),
            1
        );
        assert_eq!(
            back.get("mem_timeline").unwrap().as_arr().unwrap().len(),
            2
        );
        let s = r.summary();
        assert!(s.contains("__all__"));
        assert!(s.contains("fingerprint"));
    }
}
