//! The PJRT runtime: loads the AOT artifacts produced by `python/compile`
//! (HLO **text** — see `/opt/xla-example/README.md` for why not serialized
//! protos) and executes them on the request path. Python never runs here.
//!
//! * [`artifact`] — `artifacts/manifest.json` parsing + artifact metadata.
//! * [`executor`] — PJRT client wrapper, one compiled executable per
//!   artifact (compiled once, cached), typed f32 execution, and the
//!   [`crate::container::PayloadRunner`] implementation sandboxes call.

pub mod artifact;
pub mod executor;

pub use artifact::{Artifact, Manifest};
pub use executor::PjrtRunner;
