//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `artifacts/manifest.json` lists every compiled entry point with its input
//! shapes (all f32 tensors) and the HLO text file to load:
//!
//! ```json
//! {
//!   "format": "hlo-text-v1",
//!   "artifacts": [
//!     {"name": "float_operation", "file": "float_operation.hlo.txt",
//!      "inputs": [[256, 256]], "outputs": [[256, 256]]}
//!   ]
//! }
//! ```

use crate::util::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    pub name: String,
    /// HLO text path (absolute, resolved against the manifest dir).
    pub path: PathBuf,
    /// Input tensor shapes (f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output tensor shapes (f32).
    pub outputs: Vec<Vec<usize>>,
}

impl Artifact {
    pub fn input_elems(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }

    pub fn output_elems(&self, i: usize) -> usize {
        self.outputs[i].iter().product()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
}

fn shape_list(j: &Json, what: &str) -> Result<Vec<Vec<usize>>> {
    let arr = j
        .as_arr()
        .with_context(|| format!("{what} must be an array of shapes"))?;
    arr.iter()
        .map(|s| {
            s.as_arr()
                .with_context(|| format!("{what} entries must be arrays"))?
                .iter()
                .map(|d| {
                    d.as_u64()
                        .map(|v| v as usize)
                        .with_context(|| format!("{what} dims must be non-negative ints"))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse_with_dir(&text, dir)
    }

    /// Parse manifest text, resolving artifact files against `dir`.
    pub fn parse_with_dir(text: &str, dir: &Path) -> Result<Self> {
        let j = parse(text).map_err(|e| anyhow::anyhow!("manifest JSON: {e}"))?;
        match j.get("format").and_then(|f| f.as_str()) {
            Some("hlo-text-v1") => {}
            other => bail!("unsupported manifest format {other:?}"),
        }
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest missing `artifacts` array")?;
        let mut artifacts = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(|n| n.as_str())
                .context("artifact missing name")?
                .to_string();
            let file = a
                .get("file")
                .and_then(|f| f.as_str())
                .with_context(|| format!("artifact {name} missing file"))?;
            let inputs = shape_list(a.get("inputs").context("missing inputs")?, "inputs")?;
            let outputs = shape_list(a.get("outputs").context("missing outputs")?, "outputs")?;
            artifacts.push(Artifact {
                name,
                path: dir.join(file),
                inputs,
                outputs,
            });
        }
        Ok(Self { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text-v1",
        "artifacts": [
            {"name": "float_operation", "file": "float_operation.hlo.txt",
             "inputs": [[256, 256]], "outputs": [[256, 256]]},
            {"name": "tiny_lm", "file": "tiny_lm.hlo.txt",
             "inputs": [[4, 64]], "outputs": [[4, 64, 512]]}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse_with_dir(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let f = m.get("float_operation").unwrap();
        assert_eq!(f.path, PathBuf::from("/tmp/a/float_operation.hlo.txt"));
        assert_eq!(f.inputs, vec![vec![256, 256]]);
        assert_eq!(f.input_elems(0), 65536);
        let lm = m.get("tiny_lm").unwrap();
        assert_eq!(lm.output_elems(0), 4 * 64 * 512);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = r#"{"format": "v0", "artifacts": []}"#;
        assert!(Manifest::parse_with_dir(bad, Path::new("/")).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"format": "hlo-text-v1", "artifacts": [{"name": "x"}]}"#;
        assert!(Manifest::parse_with_dir(bad, Path::new("/")).is_err());
        let bad = r#"{"format": "hlo-text-v1"}"#;
        assert!(Manifest::parse_with_dir(bad, Path::new("/")).is_err());
    }

    #[test]
    fn names_listing() {
        let m = Manifest::parse_with_dir(SAMPLE, Path::new("/")).unwrap();
        assert_eq!(m.names(), vec!["float_operation", "tiny_lm"]);
    }
}
