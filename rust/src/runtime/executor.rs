//! PJRT execution: compile once, execute many, never touch Python.
//!
//! Mirrors `/opt/xla-example/src/bin/load_hlo.rs`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled lazily (first request for an artifact) and
//! cached for the life of the process; inputs are deterministic per
//! artifact so results are checkable.

use super::artifact::{Artifact, Manifest};
use crate::container::PayloadRunner;
use crate::simtime::Clock;
use crate::workloads::PayloadSpec;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// A compiled artifact ready to run.
struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    artifact: Artifact,
}

/// PJRT-backed payload runner shared by all sandboxes.
pub struct PjrtRunner {
    client: xla::PjRtClient,
    manifest: Manifest,
    loaded: Mutex<HashMap<String, &'static Loaded>>,
}

// SAFETY: the xla crate's client/executable types wrap PJRT handles that
// are safe to share across threads (the PJRT CPU client is thread-safe);
// the crate just doesn't declare it. We serialize compilation behind the
// mutex and PJRT serializes execution internally.
unsafe impl Send for PjrtRunner {}
unsafe impl Sync for PjrtRunner {}

impl PjrtRunner {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            loaded: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, name: &str) -> Result<&'static Loaded> {
        let mut loaded = self.loaded.lock().unwrap();
        if let Some(l) = loaded.get(name) {
            return Ok(l);
        }
        let artifact = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact `{name}` not in manifest"))?
            .clone();
        let path = artifact
            .path
            .to_str()
            .context("artifact path not UTF-8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        // Executables live for the process lifetime; leaking gives us a
        // stable &'static without self-referential structs.
        let entry: &'static Loaded = Box::leak(Box::new(Loaded { exe, artifact }));
        loaded.insert(name.to_string(), entry);
        Ok(entry)
    }

    /// Deterministic input tensor for an artifact (values in [0,1)).
    fn input_literal(shape: &[usize], seed: u64) -> Result<xla::Literal> {
        let n: usize = shape.iter().product();
        let mut vals = Vec::with_capacity(n);
        let mut x = seed ^ 0x9E37_79B9_7F4A_7C15;
        for _ in 0..n {
            x = x
                .wrapping_mul(0x2545_F491_4F6C_DD1D)
                .wrapping_add(0x1234_5678);
            vals.push(((x >> 40) as f32) / (1u64 << 24) as f32);
        }
        let lit = xla::Literal::vec1(&vals);
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims).context("reshaping input literal")
    }

    /// Execute an artifact once with deterministic inputs; returns the
    /// first output tensor flattened to f32.
    pub fn execute(&self, name: &str, seed: u64) -> Result<Vec<f32>> {
        let l = self.load(name)?;
        let inputs: Vec<xla::Literal> = l
            .artifact
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| Self::input_literal(s, seed.wrapping_add(i as u64)))
            .collect::<Result<_>>()?;
        let result = l.exe.execute::<xla::Literal>(&inputs)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let vals = out.to_vec::<f32>().context("reading f32 output")?;
        if !l.artifact.outputs.is_empty() {
            let expect: usize = l.artifact.outputs[0].iter().product();
            if vals.len() != expect {
                bail!(
                    "{name}: output has {} elems, manifest says {expect}",
                    vals.len()
                );
            }
        }
        Ok(vals)
    }

    /// Warm the executable cache (compile everything up front — used by the
    /// platform at boot so compilation never lands on a request).
    pub fn precompile_all(&self) -> Result<()> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect();
        for n in names {
            self.load(&n)?;
        }
        Ok(())
    }
}

impl PayloadRunner for PjrtRunner {
    fn run(&self, payload: &PayloadSpec, clock: &Clock) -> Result<()> {
        clock.time(|| -> Result<()> {
            for it in 0..payload.iterations {
                let out = self.execute(&payload.artifact, 0xC0DE + it as u64)?;
                // Results must be finite — a NaN here means the kernel or
                // the AOT path regressed.
                if let Some(bad) = out.iter().find(|v| !v.is_finite()) {
                    bail!("{}: non-finite output {bad}", payload.artifact);
                }
            }
            Ok(())
        })
    }
}
