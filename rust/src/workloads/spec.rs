//! Workload specification: the shape of a guest function.
//!
//! A workload is (a) a **language runtime profile** — binary size, init
//! time, init memory; (b) an **application profile** — anonymous memory and
//! the per-request working set; (c) a **payload** — the real compute, an
//! AOT-compiled JAX/Pallas artifact executed through PJRT on every request.
//!
//! The memory-phase parameters are the knobs DESIGN.md §5 calibrates to the
//! paper's Fig. 6/7; the invariants the paper's evaluation rests on (working
//! set is a stable 30–90% subset; hibernate drops anon + file pages; REAP
//! restores exactly the working set) all emerge from these.

use crate::PAGE_SIZE;

/// Guest language runtime (§4's four hello-world runtimes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lang {
    Python,
    NodeJs,
    Golang,
    Java,
}

impl Lang {
    pub fn name(self) -> &'static str {
        match self {
            Lang::Python => "python",
            Lang::NodeJs => "nodejs",
            Lang::Golang => "golang",
            Lang::Java => "java",
        }
    }

    /// The mmap'd runtime binary file name (one per language, so sandboxes
    /// of the same language can share pages when policy allows).
    pub fn binary_name(self) -> &'static str {
        match self {
            Lang::Python => "cpython-3.10.so",
            Lang::NodeJs => "node-v16-libv8.so",
            Lang::Golang => "golang-rt.a",
            Lang::Java => "jvm-17-libjvm.so",
        }
    }
}

/// The real compute bound to a request: which AOT artifact to execute and
/// with what batch of iterations.
#[derive(Debug, Clone, PartialEq)]
pub struct PayloadSpec {
    /// Artifact name in `artifacts/manifest.json` (e.g. `float_operation`).
    pub artifact: String,
    /// Executions per request (scales compute time).
    pub iterations: u32,
}

/// Full workload profile.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Unique name ("nodejs-hello", "video-processing", ...).
    pub name: String,
    pub lang: Lang,
    /// Language runtime binary size (bytes) — the §3.5 shareable mapping.
    pub binary_bytes: u64,
    /// Fraction of binary pages touched during runtime init.
    pub binary_init_frac: f64,
    /// Fraction of binary pages touched per request (code working set).
    pub binary_request_frac: f64,
    /// Virtual time for language-runtime + app initialization (ns).
    pub init_ns: u64,
    /// Anonymous pages committed during initialization (heap, arenas, JIT).
    pub init_anon_pages: u64,
    /// Fraction of init anon pages a request actually touches — the stable
    /// REAP working set (paper §3.4.1: 30–90%).
    pub request_ws_frac: f64,
    /// Fresh anon pages allocated per request and freed afterwards (these
    /// become the reclaimable free pages of deflation step #2).
    pub request_scratch_pages: u64,
    /// Virtual time for non-modeled request work (parsing, framework, ...).
    pub request_extra_ns: u64,
    /// The real compute payload (None = pure memory workload).
    pub payload: Option<PayloadSpec>,
    /// Guest processes (≥1; extra processes are clones sharing init pages
    /// COW — exercises refcounts and swap-out dedup).
    pub processes: usize,
}

impl WorkloadSpec {
    pub fn binary_pages(&self) -> u64 {
        self.binary_bytes.div_ceil(PAGE_SIZE as u64)
    }

    /// Pages of the binary touched during init.
    pub fn binary_init_pages(&self) -> u64 {
        ((self.binary_pages() as f64) * self.binary_init_frac).round() as u64
    }

    /// Pages of the binary a request touches.
    pub fn binary_request_pages(&self) -> u64 {
        ((self.binary_pages() as f64) * self.binary_request_frac).round() as u64
    }

    /// Anon pages of the init set a request touches (the REAP working set).
    pub fn request_ws_pages(&self) -> u64 {
        ((self.init_anon_pages as f64) * self.request_ws_frac).round() as u64
    }

    /// Rough expected warm anon footprint (bytes) — used in tests to sanity
    /// check calibration, not by the mechanism.
    pub fn expected_warm_anon_bytes(&self) -> u64 {
        self.init_anon_pages * PAGE_SIZE as u64
    }

    /// Validate parameter sanity.
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("empty workload name".into());
        }
        for (label, f) in [
            ("binary_init_frac", self.binary_init_frac),
            ("binary_request_frac", self.binary_request_frac),
            ("request_ws_frac", self.request_ws_frac),
        ] {
            if !(0.0..=1.0).contains(&f) {
                return Err(format!("{label} = {f} out of [0,1]"));
            }
        }
        if self.processes == 0 {
            return Err("processes must be ≥ 1".into());
        }
        if self.init_anon_pages == 0 {
            return Err("init_anon_pages must be > 0".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> WorkloadSpec {
        WorkloadSpec {
            name: "t".into(),
            lang: Lang::Python,
            binary_bytes: 10 * PAGE_SIZE as u64,
            binary_init_frac: 0.5,
            binary_request_frac: 0.2,
            init_ns: 1_000_000,
            init_anon_pages: 100,
            request_ws_frac: 0.4,
            request_scratch_pages: 10,
            request_extra_ns: 0,
            payload: None,
            processes: 1,
        }
    }

    #[test]
    fn page_math() {
        let s = spec();
        assert_eq!(s.binary_pages(), 10);
        assert_eq!(s.binary_init_pages(), 5);
        assert_eq!(s.binary_request_pages(), 2);
        assert_eq!(s.request_ws_pages(), 40);
        s.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_params() {
        let mut s = spec();
        s.request_ws_frac = 1.5;
        assert!(s.validate().is_err());
        let mut s = spec();
        s.processes = 0;
        assert!(s.validate().is_err());
        let mut s = spec();
        s.init_anon_pages = 0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn binary_pages_round_up() {
        let mut s = spec();
        s.binary_bytes = PAGE_SIZE as u64 + 1;
        assert_eq!(s.binary_pages(), 2);
    }
}
