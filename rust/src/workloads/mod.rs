//! The paper's evaluation workloads (§4): FunctionBench-style Python
//! micro-benchmarks plus four language-runtime hello-worlds, expressed as
//! [`spec::WorkloadSpec`] profiles whose *compute* is real (AOT-compiled
//! JAX/Pallas payloads executed through PJRT) and whose *memory shape*
//! (runtime binary size, init footprint, request working set) is calibrated
//! to the paper's Fig. 6/7 readings (see DESIGN.md §5).

pub mod functionbench;
pub mod spec;

pub use functionbench::{all_workloads, workload_by_name};
pub use spec::{Lang, PayloadSpec, WorkloadSpec};
