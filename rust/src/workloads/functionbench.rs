//! The paper's evaluation suite (§4), calibrated per DESIGN.md §5.
//!
//! Two groups:
//! * **FunctionBench micro-benchmarks** (Python): `float-operation`,
//!   `image-processing` with a 0.3 MB and a 2.6 MB input,
//!   `video-processing` (grayscale over a frame stack);
//! * **hello-world** services for Python, Node.js, Golang and Java.
//!
//! Memory profiles target the paper's Fig. 7 readings (warm PSS, hibernate
//! ratio 7–25%, woken-up ratio 28–90%) and the Fig. 6 latency bands
//! (REAP wake at 3–67% of cold start). Compute is real: each workload binds
//! a PJRT payload compiled from `python/compile` (grayscale / image
//! pipeline / float loop / tiny transformer).

use super::spec::{Lang, PayloadSpec, WorkloadSpec};
use crate::PAGE_SIZE;

const KB: u64 = 1024;
const MB: u64 = 1024 * 1024;
const MS: u64 = 1_000_000;

fn pages(bytes: u64) -> u64 {
    bytes / PAGE_SIZE as u64
}

/// python hello-world HTTP service.
/// Paper targets: warm ≈ 40 MB, hibernate ≈ 20%, REAP wake ≈ 3% of cold.
pub fn python_hello() -> WorkloadSpec {
    WorkloadSpec {
        name: "python-hello".into(),
        lang: Lang::Python,
        binary_bytes: 24 * MB,
        binary_init_frac: 0.55,
        binary_request_frac: 0.10,
        init_ns: 280 * MS,
        init_anon_pages: pages(26 * MB),
        request_ws_frac: 0.30,
        request_scratch_pages: pages(256 * KB),
        request_extra_ns: 400_000,
        payload: Some(PayloadSpec {
            artifact: "float_operation".into(),
            iterations: 1,
        }),
        processes: 1,
    }
}

/// node.js hello-world — the §3.5 sharing-ablation subject.
/// Paper targets: warm ≈ 50 MB, wokenup ≈ 28%, hibernate wake 25 ms
/// (11 ms with language-runtime sharing), ~10 MB out / ~4 MB back.
pub fn nodejs_hello() -> WorkloadSpec {
    WorkloadSpec {
        name: "nodejs-hello".into(),
        lang: Lang::NodeJs,
        binary_bytes: 40 * MB,
        binary_init_frac: 0.45,
        // ~350 binary pages per request: with sharing off these reload from
        // disk after deflation step #4 → the 25 ms hibernate wake; with
        // sharing on they are cache hits → ~11 ms.
        binary_request_frac: 0.035,
        init_ns: 320 * MS,
        init_anon_pages: pages(10 * MB),
        request_ws_frac: 0.40, // ~4 MB of the ~10 MB swapped out (§3.4.1)
        request_scratch_pages: pages(512 * KB),
        request_extra_ns: 500_000,
        payload: Some(PayloadSpec {
            artifact: "float_operation".into(),
            iterations: 1,
        }),
        processes: 1,
    }
}

/// golang hello-world.
/// Paper targets: warm = 16 MB, hibernate = 4 MB (25%), wokenup ≈ 9 MB;
/// REAP saves 296 ms vs cold (REAP ≈ 3% of cold ≈ 305 ms).
pub fn golang_hello() -> WorkloadSpec {
    WorkloadSpec {
        name: "golang-hello".into(),
        lang: Lang::Golang,
        binary_bytes: 8 * MB, // static binary, small mapped footprint
        binary_init_frac: 0.6,
        binary_request_frac: 0.08,
        init_ns: 255 * MS,
        init_anon_pages: pages(11 * MB),
        request_ws_frac: 0.45,
        request_scratch_pages: pages(128 * KB),
        request_extra_ns: 200_000,
        payload: Some(PayloadSpec {
            artifact: "float_operation".into(),
            iterations: 1,
        }),
        processes: 1,
    }
}

/// java (JVM) hello-world: the heavyweight runtime.
pub fn java_hello() -> WorkloadSpec {
    WorkloadSpec {
        name: "java-hello".into(),
        lang: Lang::Java,
        binary_bytes: 48 * MB,
        binary_init_frac: 0.5,
        binary_request_frac: 0.06,
        init_ns: 700 * MS,
        init_anon_pages: pages(90 * MB), // JVM heap + metaspace
        request_ws_frac: 0.20,
        request_scratch_pages: pages(1 * MB),
        request_extra_ns: 600_000,
        payload: Some(PayloadSpec {
            artifact: "float_operation".into(),
            iterations: 1,
        }),
        processes: 2, // JVM forks a compiler-ish helper: exercises COW dedup
    }
}

/// FunctionBench float-operation: small memory, tight compute loop.
pub fn float_operation() -> WorkloadSpec {
    WorkloadSpec {
        name: "float-operation".into(),
        lang: Lang::Python,
        binary_bytes: 24 * MB,
        binary_init_frac: 0.55,
        binary_request_frac: 0.12,
        init_ns: 300 * MS,
        init_anon_pages: pages(30 * MB),
        request_ws_frac: 0.35,
        request_scratch_pages: pages(1 * MB),
        request_extra_ns: 2 * MS,
        payload: Some(PayloadSpec {
            artifact: "float_operation".into(),
            iterations: 8,
        }),
        processes: 1,
    }
}

/// FunctionBench image-processing with the 0.3 MB input image.
pub fn image_processing_small() -> WorkloadSpec {
    WorkloadSpec {
        name: "image-0.3MB".into(),
        lang: Lang::Python,
        binary_bytes: 36 * MB, // CPython + Pillow
        binary_init_frac: 0.5,
        binary_request_frac: 0.15,
        init_ns: 450 * MS,
        init_anon_pages: pages(95 * MB),
        request_ws_frac: 0.55,
        request_scratch_pages: pages(4 * MB),
        request_extra_ns: 20 * MS,
        payload: Some(PayloadSpec {
            artifact: "image_processing".into(),
            iterations: 1,
        }),
        processes: 1,
    }
}

/// FunctionBench image-processing with the 2.6 MB input image.
/// Paper targets: warm = 281 MB, hibernate = 29 MB (10%), wokenup ≈ 90%;
/// REAP wake = 67% of cold (compute dominates).
pub fn image_processing_large() -> WorkloadSpec {
    WorkloadSpec {
        name: "image-2.6MB".into(),
        lang: Lang::Python,
        binary_bytes: 36 * MB,
        binary_init_frac: 0.5,
        binary_request_frac: 0.15,
        init_ns: 500 * MS,
        init_anon_pages: pages(230 * MB),
        request_ws_frac: 0.50, // large reload; the rest re-materializes during compute
        request_scratch_pages: pages(12 * MB),
        request_extra_ns: 120 * MS,
        payload: Some(PayloadSpec {
            artifact: "image_processing".into(),
            iterations: 4,
        }),
        processes: 1,
    }
}

/// FunctionBench video-processing: grayscale over a frame stack (OpenCV in
/// the paper; our Pallas grayscale kernel over frames).
/// Paper targets: warm = 226 MB, hibernate ≈ 7%, wokenup saving 151 MB;
/// REAP saves 2407 ms vs cold; process latency > 1000 ms.
pub fn video_processing() -> WorkloadSpec {
    WorkloadSpec {
        name: "video-processing".into(),
        lang: Lang::Python,
        binary_bytes: 44 * MB, // CPython + OpenCV
        binary_init_frac: 0.45,
        binary_request_frac: 0.12,
        init_ns: 900 * MS,
        init_anon_pages: pages(180 * MB),
        request_ws_frac: 0.33,
        request_scratch_pages: pages(16 * MB),
        request_extra_ns: 250 * MS,
        payload: Some(PayloadSpec {
            artifact: "video_processing".into(),
            iterations: 6,
        }),
        processes: 1,
    }
}

/// The tiny transformer LM served by the E2E demo (not part of the paper's
/// suite; exercises the full three-layer stack under batched serving).
pub fn tiny_lm_serving() -> WorkloadSpec {
    WorkloadSpec {
        name: "tiny-lm".into(),
        lang: Lang::Python,
        binary_bytes: 32 * MB,
        binary_init_frac: 0.5,
        binary_request_frac: 0.1,
        init_ns: 400 * MS,
        init_anon_pages: pages(60 * MB),
        request_ws_frac: 0.6,
        request_scratch_pages: pages(1 * MB),
        request_extra_ns: 0,
        payload: Some(PayloadSpec {
            artifact: "tiny_lm".into(),
            iterations: 1,
        }),
        processes: 1,
    }
}

/// The paper's eight evaluation workloads, Fig. 6/7 order.
pub fn all_workloads() -> Vec<WorkloadSpec> {
    vec![
        python_hello(),
        nodejs_hello(),
        golang_hello(),
        java_hello(),
        float_operation(),
        image_processing_small(),
        image_processing_large(),
        video_processing(),
    ]
}

/// Look a workload up by name (CLI / config entry point).
pub fn workload_by_name(name: &str) -> Option<WorkloadSpec> {
    let mut all = all_workloads();
    all.push(tiny_lm_serving());
    all.into_iter().find(|w| w.name == name)
}

/// Scaled-down variants for fast tests: same shape, ~1/16 the pages.
pub fn scaled_for_test(mut spec: WorkloadSpec, factor: u64) -> WorkloadSpec {
    spec.init_anon_pages = (spec.init_anon_pages / factor).max(8);
    spec.request_scratch_pages = (spec.request_scratch_pages / factor).max(2);
    spec.binary_bytes = (spec.binary_bytes / factor).max(PAGE_SIZE as u64 * 4);
    spec.init_ns /= factor;
    spec.request_extra_ns /= factor;
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_specs_validate() {
        for w in all_workloads() {
            w.validate().unwrap_or_else(|e| panic!("{}: {e}", w.name));
        }
        tiny_lm_serving().validate().unwrap();
    }

    #[test]
    fn lookup_by_name() {
        assert!(workload_by_name("video-processing").is_some());
        assert!(workload_by_name("tiny-lm").is_some());
        assert!(workload_by_name("nope").is_none());
    }

    #[test]
    fn working_set_fractions_in_paper_band() {
        // §3.4.1: 30–90% of swapped pages are reloaded per request.
        for w in all_workloads() {
            assert!(
                (0.20..=0.90).contains(&w.request_ws_frac),
                "{}: ws frac {}",
                w.name,
                w.request_ws_frac
            );
        }
    }

    #[test]
    fn golang_is_smallest_java_video_image_largest() {
        // Fig. 7 ordering sanity.
        let go = golang_hello().expected_warm_anon_bytes();
        let img = image_processing_large().expected_warm_anon_bytes();
        let vid = video_processing().expected_warm_anon_bytes();
        assert!(go < img && go < vid);
        assert!(img > vid, "image-2.6MB is the biggest warm footprint");
    }

    #[test]
    fn scaling_preserves_shape() {
        let w = scaled_for_test(video_processing(), 16);
        w.validate().unwrap();
        assert!(w.init_anon_pages >= 8);
        assert_eq!(w.request_ws_frac, video_processing().request_ws_frac);
    }
}
