//! A Quark sandbox: the unit the platform schedules and the paper
//! hibernates.
//!
//! Owns a per-sandbox Bitmap Page Allocator (each sandbox is its own
//! QKernel instance drawing 4 MiB blocks from the global heap), its guest
//! processes' address spaces, the Swapping Manager with its two files, and
//! the REAP recorder. Implements:
//!
//! * **cold start**: sandbox startup + runtime/app init (Fig. 3 ①);
//! * **request handling** from Warm *and* from Hibernate/WokenUp (②⑥⑦);
//! * **the 4-step deflation** of §3.2 (pause → reclaim freed pages →
//!   swap out committed anon pages → drop file-backed mmap pages);
//! * **the 2 wake triggers**: demand (a request lands on a Hibernate
//!   container and the parked runtime thread unblocks) and anticipatory
//!   (platform SIGCONT, Fig. 3 ⑤).

use super::app::{anon_content_seed, AppLayout, GuestProcess};
use super::hostenv::{HostEnv, HostEnvCost, HostEnvRegistry};
use super::signal::{ControlSignal, SignalQueue};
use super::state::{ContainerState, Event};
use super::PayloadRunner;
use crate::config::{DurabilityConfig, SharingConfig};
use crate::mem::bitmap_alloc::BitmapPageAllocator;
use crate::mem::buddy::BuddyAllocator;
use crate::mem::host::HostMemory;
use crate::mem::mmap_file::{FileClass, FilePageCache, FileRegistry};
use crate::mem::page_table::{PageTable, Pte};
use crate::mem::pss::{pss, PssBreakdown};
use crate::mem::vma::VmaKind;
use crate::mem::{Gpa, Gva};
use crate::obs::{ARG_FLAG, EventKind, Recorder};
use crate::platform::io_backend::{IoBackend, SyncBackend};
use crate::platform::metrics::DurabilityStats;
use crate::simtime::{Clock, CostModel};
use crate::swap::file::{SwapFileSet, SwapSlot};
use crate::swap::manifest::{ImageManifest, ManifestPage};
use crate::swap::{DurabilityCtx, ReapRecorder, SwapMgr};
use crate::workloads::WorkloadSpec;
use crate::PAGE_SIZE;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// The Quark runtime binary every sandbox maps (qkernel + qvisor image).
pub const QUARK_BINARY_NAME: &str = "quark-qkernel.bin";
/// Size of the runtime binary mapping.
pub const QUARK_BINARY_BYTES: u64 = 12 << 20;
/// Fraction of the runtime binary touched by a running sandbox.
pub const QUARK_BINARY_TOUCH_FRAC: f64 = 0.4;
/// QKernel's own resident heap (kernel stacks, task structs, page-metadata
/// arrays): a base plus a per-guest-page component. These pages are what
/// Hibernate *keeps* — "Host OS objects … consume little system memory but
/// keeping them alive saves much reinitialization cost" (§1) — and are the
/// floor under the paper's 7–25 %-of-Warm hibernate footprint.
pub const KERNEL_BASE_PAGES: u64 = 512; // 2 MiB
pub const KERNEL_PER_ANON_FRAC: f64 = 0.05;

/// Host-side services shared by all sandboxes on a node.
pub struct SandboxServices {
    pub host: Arc<HostMemory>,
    pub heap: Arc<BuddyAllocator>,
    pub cache: Arc<FilePageCache>,
    pub registry: Arc<FileRegistry>,
    pub cost: CostModel,
    pub sharing: SharingConfig,
    pub swap_dir: PathBuf,
    pub runner: Arc<dyn PayloadRunner>,
    /// Policy: may sandboxes use REAP batch swap-in?
    pub reap_enabled: bool,
    /// Host-object registry (cgroups, netns, rootfs mounts).
    pub hostenv: Arc<HostEnvRegistry>,
    /// Node-wide I/O backend every sandbox's swap files submit their batch
    /// slot runs through (`[io]` config: sync or batched).
    pub io: Arc<dyn IoBackend>,
    /// Durability policy every sandbox's swap manager runs under
    /// (`[durability]` config: checksum verification, retry budget,
    /// compaction threshold).
    pub durability: DurabilityConfig,
    /// Node-wide durability counters (fingerprint-excluded, like
    /// [`crate::platform::io_backend::IoStats`]): shared by every swap
    /// manager's retry/verify paths and the platform's adoption scan.
    pub durability_stats: Arc<DurabilityStats>,
    /// Flight recorder lifecycle seams emit into ([`crate::obs`]). Local
    /// rigs get a disabled recorder (emission is a no-op); the platform
    /// injects its own per-shard-ring recorder.
    pub recorder: Arc<Recorder>,
}

impl SandboxServices {
    /// Build a full service rig over a fresh host region (tests, examples),
    /// with the default synchronous I/O backend.
    pub fn new_local(
        host_bytes: usize,
        cost: CostModel,
        sharing: SharingConfig,
        runner: Arc<dyn PayloadRunner>,
        swap_tag: &str,
    ) -> Result<Arc<Self>> {
        Self::new_local_with_io(
            host_bytes,
            cost,
            sharing,
            runner,
            swap_tag,
            Arc::new(SyncBackend::new()),
        )
    }

    /// [`Self::new_local`] with an explicit I/O backend (fault-injection
    /// rigs wrap one; batched-backend tests pass a
    /// [`crate::platform::io_backend::BatchedBackend`]).
    pub fn new_local_with_io(
        host_bytes: usize,
        cost: CostModel,
        sharing: SharingConfig,
        runner: Arc<dyn PayloadRunner>,
        swap_tag: &str,
        io: Arc<dyn IoBackend>,
    ) -> Result<Arc<Self>> {
        let host = Arc::new(HostMemory::new(host_bytes)?);
        let len = host.size() as u64;
        let heap = Arc::new(BuddyAllocator::new(host.clone(), 0, len)?);
        // The file page cache draws from its own allocator (platform-level
        // page cache, not owned by any sandbox).
        let cache_alloc = Arc::new(BitmapPageAllocator::new(host.clone(), heap.clone()));
        let cache = Arc::new(FilePageCache::new(cache_alloc));
        let swap_dir = std::env::temp_dir().join(format!(
            "quark-hibernate-{}-{}",
            swap_tag,
            std::process::id()
        ));
        Ok(Arc::new(Self {
            host,
            heap,
            cache,
            registry: Arc::new(FileRegistry::new()),
            cost,
            sharing,
            swap_dir,
            runner,
            reap_enabled: true,
            hostenv: HostEnvRegistry::new(),
            io,
            durability: DurabilityConfig::default(),
            durability_stats: Arc::new(DurabilityStats::default()),
            recorder: Recorder::disabled(),
        }))
    }

    fn share_file(&self, class: FileClass) -> bool {
        match class {
            FileClass::QuarkRuntime => self.sharing.share_runtime_binary,
            FileClass::LanguageRuntime => self.sharing.share_language_runtime,
            FileClass::AppData => false,
        }
    }
}

/// Report of one deflation (§3.2's four steps).
#[derive(Debug, Clone, Copy, Default)]
pub struct HibernateReport {
    /// Step 2: freed pages returned to the host.
    pub freed_pages_reclaimed: u64,
    /// Step 3: unique anon pages written (swap or REAP file).
    pub pages_swapped_out: u64,
    /// Step 3: used the REAP batch path?
    pub used_reap: bool,
    /// Step 4: file-backed pages dropped from this sandbox's tables.
    pub file_pages_released: u64,
}

/// Per-request outcome (latency lives on the caller's clock).
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub from: ContainerState,
    /// Was this the REAP sample request?
    pub sample_request: bool,
    /// Anon pages faulted in from the swap file.
    pub anon_faults: u64,
    /// File-backed bytes re-read from the image (cache misses).
    pub file_miss_bytes: u64,
    /// Working-set pages prefetched by REAP before processing.
    pub reap_prefetched: u64,
    /// Demand-wake admission overhead (dispatch + thread unpark) charged
    /// on this request's clock; 0 unless served from Hibernate. Feeds the
    /// wake-phase admission histogram.
    pub admission_ns: u64,
}

/// What expensive I/O a deferred signal drain left owed
/// ([`Sandbox::drain_signals_deferred`]): the cheap state flip already
/// happened inside the policy tick; the finish belongs on the platform's
/// instance-I/O pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PendingIo {
    /// [`Sandbox::hibernate_begin`] ran; [`Sandbox::hibernate_finish`]
    /// (the deflation swap/release I/O) is owed.
    Deflate,
    /// [`Sandbox::wake_begin`] ran; [`Sandbox::wake_finish`] (the REAP
    /// batch prefetch) is owed.
    Inflate,
}

/// A sandboxed container instance.
pub struct Sandbox {
    pub id: u64,
    spec: WorkloadSpec,
    /// `fnv1a(spec.name)` — the flight-recorder ring key, cached so every
    /// emission avoids rehashing the workload name.
    workload_hash: u64,
    svc: Arc<SandboxServices>,
    state: ContainerState,
    alloc: Arc<BitmapPageAllocator>,
    procs: Vec<GuestProcess>,
    layout: AppLayout,
    /// Quark runtime binary mapping (own VMA in process 0).
    quark_base: Gva,
    quark_pages: u64,
    swap: SwapMgr,
    reap: ReapRecorder,
    /// QKernel resident heap: buddy chunk start + page count. Committed at
    /// cold start, survives hibernation, released at termination.
    kernel_chunk: Gpa,
    kernel_pages: u64,
    /// Host OS objects (cgroup/netns/rootfs) — created at cold start,
    /// *kept alive* across hibernation (§1), released at termination.
    env: Option<HostEnv>,
    /// Pending control signals from the platform (SIGSTOP/SIGCONT).
    pub signals: SignalQueue,
    requests_served: u64,
    paused: bool,
    /// Generation of the last image manifest this sandbox wrote (0 before
    /// any, the adopted manifest's generation after a restart adoption) —
    /// the monotone counter stale-manifest detection keys on.
    manifest_generation: u64,
}

impl Sandbox {
    /// Cold start (Fig. 3 ①): sandbox startup + runtime & app init. On
    /// return the container is Warm and fully initialized.
    pub fn cold_start(
        id: u64,
        spec: WorkloadSpec,
        svc: Arc<SandboxServices>,
        clock: &Clock,
    ) -> Result<Sandbox> {
        Self::cold_start_inner(id, spec, svc, clock, None)
    }

    /// [`Self::cold_start`] with an optionally pre-opened swap file set.
    /// Adoption passes the `SwapFileSet` it re-opened from a persisted
    /// manifest — creating one here would truncate the very image being
    /// adopted, since a restarted host may hand a fresh instance the same
    /// id the manifest's files are named by. Cold start performs no swap
    /// I/O, so an adopted (non-empty) file pair is safe to carry through.
    fn cold_start_inner(
        id: u64,
        spec: WorkloadSpec,
        svc: Arc<SandboxServices>,
        clock: &Clock,
        adopted_files: Option<SwapFileSet>,
    ) -> Result<Sandbox> {
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        let workload_hash = crate::util::fnv1a(&spec.name);
        let rec = svc.recorder.clone();
        let t_begin = clock.charged_ns();
        rec.emit_workload(EventKind::ColdStartBegin, id, workload_hash, 0, clock.stamp_ns());
        // Container runtime startup. The host-object components (cgroup,
        // netns, rootfs, threads) are charged itemized by the registry; the
        // remainder is VM creation (KVM fd, memory region, vCPU setup).
        let env_cost = HostEnvCost::default_split();
        clock.charge(
            svc.cost
                .sandbox_startup_ns
                .saturating_sub(env_cost.total_ns()),
        );
        let env = svc.hostenv.create(
            id,
            &["quark-base.img", spec.lang.binary_name()],
            (spec.init_anon_pages + spec.request_scratch_pages) * PAGE_SIZE as u64 * 2,
            env_cost,
            clock,
        )?;
        rec.emit_workload(
            EventKind::ColdPhaseEnv,
            id,
            workload_hash,
            clock.charged_ns() - t_begin,
            clock.stamp_ns(),
        );
        let t_env = clock.charged_ns();

        let alloc = Arc::new(BitmapPageAllocator::new(svc.host.clone(), svc.heap.clone()));
        let binary_file = svc.registry.get_or_register(
            spec.lang.binary_name(),
            spec.binary_bytes,
            FileClass::LanguageRuntime,
        );
        let quark_file = svc.registry.get_or_register(
            QUARK_BINARY_NAME,
            QUARK_BINARY_BYTES,
            FileClass::QuarkRuntime,
        );

        let mut proc0 = GuestProcess::new();
        let share_lang = svc.share_file(FileClass::LanguageRuntime);
        let layout = AppLayout::install(&spec, &mut proc0.asp, binary_file, share_lang)?;
        let quark_pages = QUARK_BINARY_BYTES / PAGE_SIZE as u64;
        let share_quark = svc.share_file(FileClass::QuarkRuntime);
        let quark_base = proc0.asp.mmap_file(
            quark_file,
            0,
            quark_pages * PAGE_SIZE as u64,
            share_quark,
            QUARK_BINARY_NAME,
        )?;

        let files = match adopted_files {
            Some(f) => f,
            None => SwapFileSet::create_with_backend(&svc.swap_dir, id, svc.io.clone())
                .context("creating sandbox swap files")?,
        };
        let swap = SwapMgr::with_durability(
            files,
            svc.cost.clone(),
            DurabilityCtx {
                policy: svc.durability.clone(),
                stats: svc.durability_stats.clone(),
                recorder: svc.recorder.clone(),
                instance_id: id,
                workload_hash,
            },
        );
        let reap = ReapRecorder::new(svc.reap_enabled);

        // QKernel's resident heap: committed now, never deflated.
        let kernel_pages =
            KERNEL_BASE_PAGES + (spec.init_anon_pages as f64 * KERNEL_PER_ANON_FRAC) as u64;
        let kernel_chunk = svc
            .heap
            .alloc_bytes(kernel_pages * PAGE_SIZE as u64)
            .map_err(|e| anyhow::anyhow!("kernel heap: {e}"))?;
        for i in 0..kernel_pages {
            svc.host
                .fill_page(Gpa(kernel_chunk.0 + i * PAGE_SIZE as u64), id ^ i)?;
        }

        let mut sb = Sandbox {
            id,
            spec,
            workload_hash,
            svc,
            state: ContainerState::ColdStarting,
            alloc,
            procs: vec![proc0],
            layout,
            quark_base,
            quark_pages,
            swap,
            reap,
            kernel_chunk,
            kernel_pages,
            env: Some(env),
            signals: SignalQueue::new(),
            requests_served: 0,
            paused: false,
            manifest_generation: 0,
        };

        // --- Init phase: touch runtime + binary + heap. ---
        let mut miss_bytes = 0u64;
        let quark_touch = ((quark_pages as f64) * QUARK_BINARY_TOUCH_FRAC).round() as u64;
        for i in 0..quark_touch {
            let gva = Gva(sb.quark_base.0 + i * PAGE_SIZE as u64);
            sb.fault_file(0, gva, clock, &mut miss_bytes)?;
        }
        for i in 0..sb.spec.binary_init_pages() {
            let gva = sb.layout.binary_page(i);
            sb.fault_file(0, gva, clock, &mut miss_bytes)?;
        }
        // Cold image loads stream from the registry (container image on
        // local disk): sequential, not scattered.
        clock.charge(sb.svc.cost.seq_read_ns(miss_bytes));
        rec.emit_workload(
            EventKind::ColdPhaseLayout,
            id,
            workload_hash,
            clock.charged_ns() - t_env,
            clock.stamp_ns(),
        );
        let t_layout = clock.charged_ns();
        for i in 0..sb.layout.heap_pages {
            sb.fault_anon(0, sb.layout.heap_page(i), true, clock)?;
        }
        clock.charge(sb.spec.init_ns);

        // --- Clones: fork children COW-sharing the init heap. ---
        for _ in 1..sb.spec.processes {
            sb.clone_process()?;
        }
        rec.emit_workload(
            EventKind::ColdPhaseInit,
            id,
            workload_hash,
            clock.charged_ns() - t_layout,
            clock.stamp_ns(),
        );

        sb.state = sb.state.transition(Event::ColdStartDone)?;
        rec.emit_workload(
            EventKind::ColdStartEnd,
            id,
            workload_hash,
            clock.charged_ns() - t_begin,
            clock.stamp_ns(),
        );
        Ok(sb)
    }

    /// Emit a flight-recorder event on this sandbox's workload ring,
    /// stamped at the clock's current virtual position.
    fn trace(&self, kind: EventKind, arg: u64, clock: &Clock) {
        if self.svc.recorder.is_enabled() {
            self.svc
                .recorder
                .emit_workload(kind, self.id, self.workload_hash, arg, clock.stamp_ns());
        }
    }

    pub fn state(&self) -> ContainerState {
        self.state
    }

    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    pub fn requests_served(&self) -> u64 {
        self.requests_served
    }

    pub fn swap_stats(&self) -> crate::swap::SwapStats {
        self.swap.stats()
    }

    pub fn reap_recorder(&self) -> &ReapRecorder {
        &self.reap
    }

    /// Fork a guest process: map every *present anon* heap page COW into the
    /// child (refcount++), downgrading the parent's PTE to read-only COW.
    fn clone_process(&mut self) -> Result<()> {
        let mut child = GuestProcess::new();
        let mut shares: Vec<(Gva, Pte)> = Vec::new();
        self.procs[0].asp.pt.for_each(|gva, pte| {
            if pte.present() && !pte.is_file() {
                shares.push((gva, pte));
            }
        });
        for (gva, pte) in shares {
            let gpa = pte.gpa();
            self.alloc.inc_ref(gpa);
            let cow = Pte::new_present(gpa, Pte::COW);
            self.procs[0].asp.pt.map(gva, cow);
            child.asp.pt.map(gva, cow);
        }
        self.procs.push(child);
        Ok(())
    }

    /// Anonymous page fault (or plain access) at `gva` of process `p`.
    fn fault_anon(&mut self, p: usize, gva: Gva, write: bool, clock: &Clock) -> Result<()> {
        let pte = self.procs[p].asp.pt.get(gva);
        if pte.is_empty() {
            // First touch: allocate from the Bitmap Page Allocator in the
            // page-fault handler (§3.3) and fill deterministic content.
            // The fill is a write, so the entry starts DIRTY (the delta
            // swap-out keys off the bit).
            let gpa = self.alloc.alloc_page()?;
            self.svc
                .host
                .fill_page(gpa, anon_content_seed(self.id, gva))?;
            self.procs[p]
                .asp
                .pt
                .map(gva, Pte::new_present(gpa, Pte::WRITABLE | Pte::DIRTY));
            clock.charge(
                self.svc.cost.page_fault_handling_ns + self.svc.cost.host_commit_per_page_ns,
            );
            return Ok(());
        }
        // Bit-#9 swapped pages fault in from the swap file; so do
        // *rescue* pages — present PTEs whose frames the last REAP
        // swap-out discarded and whose image was then lost with the REAP
        // file (degrade rung 2): their data survives only in the
        // per-page swap mirrors.
        if pte.swapped() || (pte.present() && self.swap.needs_rescue(pte.gpa())) {
            let Sandbox { swap, procs, svc, reap, .. } = self;
            swap.fault_swap_in(&mut procs[p].asp.pt, gva, &svc.host, clock)?;
            reap.on_fault_in();
            // fall through for the COW/write handling on the restored pte
        }
        let pte = self.procs[p].asp.pt.get(gva);
        debug_assert!(pte.present());
        if write && pte.is_cow() {
            let gpa = pte.gpa();
            if self.alloc.refcount(gpa) > 1 {
                // COW break: copy to a private page.
                let new_gpa = self.alloc.alloc_page()?;
                let mut buf = vec![0u8; PAGE_SIZE];
                self.svc.host.read_page(gpa, &mut buf)?;
                self.svc.host.write_page(new_gpa, &buf)?;
                self.alloc.dec_ref(gpa);
                self.procs[p]
                    .asp
                    .pt
                    .map(gva, Pte::new_present(new_gpa, Pte::WRITABLE | Pte::DIRTY));
                clock.charge(
                    self.svc.cost.page_fault_handling_ns
                        + self.svc.cost.host_commit_per_page_ns,
                );
                return Ok(());
            }
            // Last owner: take the page back exclusively.
            self.procs[p]
                .asp
                .pt
                .update(gva, |q| q.without(Pte::COW).with(Pte::WRITABLE));
        }
        // touch_page modifies the frame (it is a write access), so mark the
        // entry DIRTY like the MMU would — the delta swap-out must rewrite
        // this page's slot image.
        self.procs[p].asp.pt.update(gva, |q| q.with(Pte::DIRTY));
        self.svc.host.touch_page(pte.gpa())?;
        Ok(())
    }

    /// File-backed page fault at `gva` of process `p`. Accumulates cache
    /// misses in `miss_bytes` (charged by the caller as one scattered or
    /// sequential read, modelling readahead batching).
    fn fault_file(
        &mut self,
        p: usize,
        gva: Gva,
        clock: &Clock,
        miss_bytes: &mut u64,
    ) -> Result<()> {
        let pte = self.procs[p].asp.pt.get(gva);
        if pte.present() {
            self.svc.host.touch_page(pte.gpa())?;
            return Ok(());
        }
        let (shared, file_id, page_no) = {
            let vma = self.procs[p]
                .asp
                .find_vma(gva)
                .with_context(|| format!("file fault outside any vma at {gva:?}"))?;
            let VmaKind::File { shared, .. } = &vma.kind else {
                bail!("fault_file on anon vma at {gva:?}");
            };
            let (file_id, page_no) = vma.file_page(gva).unwrap();
            (*shared, file_id, page_no)
        };
        let file = self.svc.registry.get(file_id);
        let gpa = if shared {
            let (gpa, hit) = self.svc.cache.map_shared(&file, page_no)?;
            if !hit {
                *miss_bytes += PAGE_SIZE as u64;
            }
            gpa
        } else {
            *miss_bytes += PAGE_SIZE as u64;
            self.svc.cache.map_private_for(&file, page_no, &self.alloc)?
        };
        self.procs[p].asp.pt.map(gva, Pte::new_present(gpa, Pte::FILE));
        // Minor fault: guest fault handling + one guest/host switch.
        clock.charge(self.svc.cost.page_fault_handling_ns + self.svc.cost.guest_host_switch_ns);
        Ok(())
    }

    /// Handle one request (Fig. 3 ②⑥⑦): touch the stable working set, run
    /// the real payload, release scratch memory, transition back (③⑧).
    pub fn handle_request(&mut self, clock: &Clock) -> Result<RequestOutcome> {
        let from = self.state;
        self.state = self.state.transition(Event::Request)?;
        let mut outcome = RequestOutcome {
            from,
            sample_request: false,
            anon_faults: 0,
            file_miss_bytes: 0,
            reap_prefetched: 0,
            admission_ns: 0,
        };
        if from == ContainerState::Hibernate {
            // Demand wake. The REAP batch read is issued the moment the
            // request is admitted, and the admission work — dispatch plus
            // unparking the runtime host thread (sys_accept returning) —
            // proceeds concurrently with it, so the serve path pays
            // max(admission, prefetch) instead of their sum: the request
            // no longer waits out the whole batch read up front.
            self.paused = false;
            self.trace(EventKind::WakeBegin, 0, clock);
            // The image is about to go stale (pages fault back, slots
            // rewrite): the persisted manifest no longer describes it.
            self.swap.files_mut().discard_manifest();
            let admission_ns =
                self.svc.cost.request_dispatch_ns + self.svc.cost.thread_wake_ns;
            if self.swap.has_reap_image() {
                let prefetch = Clock::new();
                match self.swap.reap_swap_in(&self.svc.host, &prefetch) {
                    Ok(n) => {
                        outcome.reap_prefetched = n;
                        clock.charge(admission_ns.max(prefetch.charged_ns()));
                        self.trace(
                            EventKind::WakeFinish,
                            (n * PAGE_SIZE as u64) | ARG_FLAG,
                            clock,
                        );
                    }
                    Err(e) => {
                        // Degrade rung 1: the batch prefetch failed
                        // (retries exhausted or a slot failed its
                        // checksum). Drop the REAP image and serve the
                        // request anyway — every page it touches either
                        // faults from its swap slot or rescues from its
                        // swap mirror (rung 2). Charged time covers the
                        // attempted read including its retry backoff.
                        eprintln!(
                            "sandbox {}: REAP prefetch failed ({e:#}); \
                             degrading to per-page swap-in",
                            self.id
                        );
                        self.swap.invalidate_reap_image(clock);
                        clock.charge(admission_ns.max(prefetch.charged_ns()));
                        self.trace(EventKind::WakeFinish, 0, clock);
                    }
                }
            } else {
                clock.charge(admission_ns);
                self.trace(EventKind::WakeFinish, 0, clock);
            }
            outcome.admission_ns = admission_ns;
            outcome.sample_request = self.reap.on_wake_request();
        } else {
            clock.charge(self.svc.cost.request_dispatch_ns);
            if from == ContainerState::WokenUp {
                outcome.sample_request = self.reap.on_wake_request();
            }
        }

        // Touch the stable anon working set.
        let faults_before = self.swap.stats().pages_faulted_in;
        let anon_ws: Vec<Gva> = self.layout.request_anon_ws(&self.spec).collect();
        for gva in anon_ws {
            self.fault_anon(0, gva, false, clock)?;
        }
        outcome.anon_faults = self.swap.stats().pages_faulted_in - faults_before;

        // Touch the binary (code) working set + a slice of the runtime.
        let mut miss_bytes = 0u64;
        let bin_ws: Vec<Gva> = self.layout.request_binary_ws(&self.spec).collect();
        for gva in bin_ws {
            self.fault_file(0, gva, clock, &mut miss_bytes)?;
        }
        let quark_ws = ((self.quark_pages as f64) * 0.1).round() as u64;
        for i in 0..quark_ws {
            let gva = Gva(self.quark_base.0 + i * PAGE_SIZE as u64);
            self.fault_file(0, gva, clock, &mut miss_bytes)?;
        }
        // Demand-paged reload of scattered binary pages.
        clock.charge(self.svc.cost.scattered_read_ns(miss_bytes));
        outcome.file_miss_bytes = miss_bytes;

        // Scratch allocations (freed below → deflation step #2 fodder).
        for i in 0..self.layout.scratch_pages.min(self.spec.request_scratch_pages) {
            self.fault_anon(0, self.layout.scratch_page(i), true, clock)?;
        }

        // The real compute: AOT-compiled JAX/Pallas via PJRT.
        if let Some(payload) = self.spec.payload.clone() {
            self.svc.runner.run(&payload, clock)?;
        }
        clock.charge(self.spec.request_extra_ns);

        // Free scratch pages back to the allocator.
        let scratch: Vec<Gva> = (0..self.layout.scratch_pages.min(self.spec.request_scratch_pages))
            .map(|i| self.layout.scratch_page(i))
            .collect();
        for gva in scratch {
            let pte = self.procs[0].asp.pt.unmap(gva);
            if pte.present() || pte.swapped() {
                self.alloc.dec_ref(pte.gpa());
            }
        }

        self.reap.on_request_done();
        self.state = self.state.transition(Event::RequestDone)?;
        self.requests_served += 1;
        Ok(outcome)
    }

    /// SIGSTOP → deflate (§3.2's four steps). Legal from Warm and WokenUp.
    ///
    /// Composed of [`Self::hibernate_begin`] (the cheap state flip) and
    /// [`Self::hibernate_finish`] (the expensive swap/release I/O). The
    /// platform's policy loop performs the flip under its shard lock and
    /// hands the finish to a deflation worker so the I/O never stalls
    /// routing; direct callers get both in one call.
    pub fn hibernate(&mut self, clock: &Clock) -> Result<HibernateReport> {
        self.hibernate_begin()?;
        self.trace(EventKind::HibernateBegin, 0, clock);
        self.hibernate_finish(clock)
    }

    /// Deflation step #1 only: SIGSTOP semantics — pause the guest, park
    /// the runtime host threads, enter the Hibernate state. Cheap (no I/O,
    /// no page walks); after it returns the router sees `Hibernate` and
    /// stops preferring the instance, while the caller's reservation keeps
    /// requests off it until [`Self::hibernate_finish`] completes.
    pub fn hibernate_begin(&mut self) -> Result<()> {
        self.state = self.state.transition(Event::SigStop)?;
        self.paused = true;
        Ok(())
    }

    /// Deflation steps #2–#4: reclaim freed pages, swap out committed anon
    /// pages (delta), drop file-backed mappings. The expensive half — run
    /// it off the control-plane path, holding only this sandbox's mutex.
    /// Requires [`Self::hibernate_begin`] to have run.
    pub fn hibernate_finish(&mut self, clock: &Clock) -> Result<HibernateReport> {
        if self.state != ContainerState::Hibernate || !self.paused {
            bail!(
                "hibernate_finish without hibernate_begin (state {})",
                self.state
            );
        }
        let mut report = HibernateReport::default();

        // Step 2: reclaim freed application memory (scratch pages etc.).
        report.freed_pages_reclaimed = self.alloc.reclaim_free_pages()?;
        clock.charge(self.svc.cost.madvise_ns(report.freed_pages_reclaimed));

        // Step 3: swap out committed anon pages. Both paths are deltas:
        // `pages_swapped_out` counts the pages actually (re)written this
        // cycle, which for a steady-state REAP hibernate after an
        // untouched wake is zero.
        if self.reap.use_reap_swapout() {
            let Sandbox { swap, procs, svc, .. } = self;
            let mut tables: Vec<&mut PageTable> =
                procs.iter_mut().map(|p| &mut p.asp.pt).collect();
            let rpt = swap.reap_swap_out(&mut tables, &svc.host, clock)?;
            report.pages_swapped_out = rpt.unique_pages;
            report.used_reap = true;
        } else {
            let Sandbox { swap, procs, svc, reap, .. } = self;
            let mut tables: Vec<&mut PageTable> =
                procs.iter_mut().map(|p| &mut p.asp.pt).collect();
            let rpt = swap.swap_out(&mut tables, &svc.host, clock)?;
            report.pages_swapped_out = rpt.unique_pages;
            // The §3.4.1 working-set denominator is the full deflated set
            // (live swap images), not this cycle's delta.
            reap.on_full_swapout(rpt.live_pages);
        }

        // Step 4: clean up file-backed mmap memory (runtime binary spared).
        report.file_pages_released = self.release_file_pages(true)?;
        self.svc.cache.trim_unmapped();
        // Private file copies became free pages in our allocator: reclaim.
        let extra = self.alloc.reclaim_free_pages()?;
        clock.charge(self.svc.cost.madvise_ns(extra + report.file_pages_released));

        let flag = if report.used_reap { ARG_FLAG } else { 0 };
        self.trace(
            EventKind::HibernateFinish,
            (report.pages_swapped_out * PAGE_SIZE as u64) | flag,
            clock,
        );

        // Persist the image manifest (crash safety): best-effort — the
        // in-memory hibernate is complete either way, a failed manifest
        // write only costs the image its restart survival.
        match self.write_manifest() {
            Ok(generation) => {
                self.svc
                    .durability_stats
                    .manifests_written
                    .fetch_add(1, Ordering::Relaxed);
                self.trace(EventKind::ManifestWrite, generation, clock);
                self.swap.files_mut().set_persist(true);
            }
            Err(e) => eprintln!(
                "sandbox {}: image manifest write failed ({e:#}); \
                 the hibernated image will not survive a host restart",
                self.id
            ),
        }
        Ok(report)
    }

    /// Write the sidecar manifest describing this hibernated image:
    /// slot tables with per-page checksums, high-water file lengths, the
    /// recorded REAP working set and recorder counters, and a bumped
    /// generation — everything [`Self::adopt_hibernated`] needs to rebuild
    /// the sandbox in a fresh process. Requires a completed
    /// [`Self::hibernate_finish`] (every anon page has a verified swap
    /// image; REAP pages additionally have REAP slots).
    fn write_manifest(&mut self) -> Result<u64> {
        // One flat gva → gpa map over every process's anon pages. The
        // manifest can only describe a layout every process agrees on: a
        // broken-COW divergence (same gva, different frames) has no flat
        // representation, so it disables persistence rather than storing
        // a wrong image.
        let mut gva_to_gpa: BTreeMap<u64, u64> = BTreeMap::new();
        for p in &self.procs {
            let mut diverged = None;
            p.asp.pt.for_each(|gva, pte| {
                if (pte.present() || pte.swapped()) && !pte.is_file() {
                    let prev = gva_to_gpa.insert(gva.0, pte.gpa().0);
                    if let Some(old) = prev {
                        if old != pte.gpa().0 {
                            diverged = Some(gva.0);
                        }
                    }
                }
            });
            if let Some(gva) = diverged {
                bail!("COW-diverged gva {gva:#x} has no flat manifest representation");
            }
        }
        let files = self.swap.files();
        let mut swap_pages = Vec::with_capacity(gva_to_gpa.len());
        let mut gpa_to_gva: BTreeMap<u64, u64> = BTreeMap::new();
        for (&gva, &gpa) in &gva_to_gpa {
            if let Some(old) = gpa_to_gva.insert(gpa, gva) {
                // Same frame under two gvas (COW is same-gva-only): the
                // flat tables would alias one slot to two pages.
                bail!("frame {gpa:#x} aliased by gvas {old:#x} and {gva:#x}");
            }
            let slot = self
                .swap
                .swap_slot_of(Gpa(gpa))
                .with_context(|| format!("anon gva {gva:#x} has no swap image"))?;
            let sum = files
                .swap_sum(slot)
                .with_context(|| format!("swap slot {} has no checksum", slot.0))?;
            swap_pages.push(ManifestPage { gva, offset: slot.0, sum });
        }
        // REAP rows come from the recorded set — not the slot table, which
        // legitimately carries stale entries after a full swap-out cleared
        // the set.
        let mut reap_pages = Vec::with_capacity(self.swap.reap_set().len());
        let mut reap_set = Vec::with_capacity(self.swap.reap_set().len());
        for &gpa in self.swap.reap_set() {
            let gva = *gpa_to_gva
                .get(&gpa.0)
                .with_context(|| format!("reap-set frame {:#x} not mapped", gpa.0))?;
            let slot = self
                .swap
                .reap_slot_of(gpa)
                .with_context(|| format!("reap-set gva {gva:#x} has no REAP slot"))?;
            let sum = files
                .reap_sum(slot)
                .with_context(|| format!("REAP slot {} has no checksum", slot.0))?;
            reap_pages.push(ManifestPage { gva, offset: slot.0, sum });
            reap_set.push(gva);
        }
        let generation = self.manifest_generation + 1;
        let manifest = ImageManifest {
            generation,
            file_id: files.file_id(),
            workload: self.spec.name.clone(),
            swap_len: files.swap_len(),
            reap_len: files.reap_len(),
            reap_recorded_pages: self.reap.recorded_pages,
            reap_swapped_out_pages: self.reap.swapped_out_pages,
            swap_pages,
            reap_pages,
            reap_set,
        };
        manifest.save(&files.manifest_path())?;
        self.manifest_generation = generation;
        Ok(generation)
    }

    /// Drop every file-backed PTE of every process, releasing cache
    /// mappings (shared) or private copies. Returns pages released.
    ///
    /// The **Quark runtime binary** is spared when `keep_runtime` — the
    /// runtime process is still alive in the Hibernate state (its parked
    /// threads are what make the demand wake fast), so its text pages stay
    /// mapped; only application file mappings (language runtime, data) are
    /// dropped per deflation step #4.
    fn release_file_pages(&mut self, keep_runtime: bool) -> Result<u64> {
        let mut released = 0u64;
        for p in 0..self.procs.len() {
            let vmas: Vec<(u64, u64, bool, Option<(crate::mem::mmap_file::FileId, u64)>)> = self
                .procs[p]
                .asp
                .iter_vmas()
                .filter_map(|v| match v.kind {
                    VmaKind::File { file, offset, shared } => {
                        Some((v.start, v.pages(), shared, Some((file, offset / PAGE_SIZE as u64))))
                    }
                    VmaKind::Anon => None,
                })
                .collect();
            for (start, pages, shared, file_info) in vmas {
                let (file_id, first_page) = file_info.unwrap();
                if keep_runtime
                    && self.svc.registry.get(file_id).class == FileClass::QuarkRuntime
                {
                    continue;
                }
                for i in 0..pages {
                    let gva = Gva(start + i * PAGE_SIZE as u64);
                    let pte = self.procs[p].asp.pt.get(gva);
                    if !pte.present() {
                        continue;
                    }
                    self.procs[p].asp.pt.unmap(gva);
                    if shared {
                        self.svc.cache.unmap_shared(file_id, first_page + i);
                    } else {
                        self.alloc.dec_ref(pte.gpa());
                    }
                    released += 1;
                }
            }
        }
        Ok(released)
    }

    /// SIGCONT → anticipatory wake (Fig. 3 ⑤): inflate ahead of the
    /// predicted request so it sees WokenUp (Warm-like) latency.
    ///
    /// Composed of [`Self::wake_begin`] (the cheap state flip) and
    /// [`Self::wake_finish`] (the REAP batch prefetch) — the mirror of the
    /// hibernate split. The platform's policy tick performs the flip under
    /// its shard lock and hands the prefetch to a pipeline worker so the
    /// I/O never stalls the control loop; direct callers get both in one
    /// call.
    pub fn wake(&mut self, clock: &Clock) -> Result<u64> {
        self.wake_begin(clock)?;
        self.wake_finish(clock)
    }

    /// Inflation step #1 only: SIGCONT semantics — unpark the runtime host
    /// threads and enter WokenUp. Cheap (no I/O); after it returns the
    /// router ranks the instance Warm-like, while the caller's reservation
    /// keeps requests off it until [`Self::wake_finish`] completes.
    pub fn wake_begin(&mut self, clock: &Clock) -> Result<()> {
        self.state = self.state.transition(Event::SigCont)?;
        clock.charge(self.svc.cost.thread_wake_ns);
        self.paused = false;
        // Waking mutates the image; the persisted manifest is stale now.
        self.swap.files_mut().discard_manifest();
        self.trace(EventKind::WakeBegin, 0, clock);
        Ok(())
    }

    /// Inflation step #2: the REAP batch `preadv` (§3.4.2). The expensive
    /// half — run it off the control-plane path, holding only this
    /// sandbox's mutex. Requires [`Self::wake_begin`] to have run. Returns
    /// pages prefetched (0 when no REAP image exists).
    pub fn wake_finish(&mut self, clock: &Clock) -> Result<u64> {
        if self.state != ContainerState::WokenUp || self.paused {
            bail!("wake_finish without wake_begin (state {})", self.state);
        }
        let (pages, used_reap) = if self.swap.has_reap_image() {
            match self.swap.reap_swap_in(&self.svc.host, clock) {
                Ok(n) => (n, true),
                Err(e) => {
                    // Degrade rung 1 (anticipatory path): drop the REAP
                    // image; the predicted request demand-faults its
                    // working set from swap slots and mirrors instead.
                    eprintln!(
                        "sandbox {}: anticipatory REAP prefetch failed ({e:#}); \
                         degrading to per-page swap-in",
                        self.id
                    );
                    self.swap.invalidate_reap_image(clock);
                    (0, false)
                }
            }
        } else {
            (0, false)
        };
        let flag = if used_reap { ARG_FLAG } else { 0 };
        self.trace(EventKind::WakeFinish, (pages * PAGE_SIZE as u64) | flag, clock);
        Ok(pages)
    }

    /// Evict: tear down guest memory, return every page, delete swap files
    /// (via SwapFileSet::drop when the sandbox is dropped).
    pub fn terminate(&mut self) -> Result<()> {
        self.state = self.state.transition(Event::Evict)?;
        self.release_everything()
    }

    /// Force-retire an instance whose image failed integrity beyond
    /// per-page rescue (degrade rung 3): unconditionally enter `Dead` —
    /// the Fig. 3 machine has no arc out of a failed request, and a
    /// corrupted instance is beyond protocol — and release every resource
    /// so the platform can cold-start a replacement.
    pub fn retire(&mut self) -> Result<()> {
        if self.state == ContainerState::Dead {
            return Ok(());
        }
        self.state = ContainerState::Dead;
        self.release_everything()
    }

    /// Simulate the sandbox process dying out from under the platform
    /// (chaos `Crash` fault). Releases every in-memory resource like
    /// [`Self::retire`], but with one difference that recovery hinges on:
    /// if the instance was hibernated its on-disk image is still exactly
    /// what the persisted manifest describes, so the manifest is salvaged
    /// *before* teardown and the swap/REAP files are left on disk with
    /// persist still set. The platform can then re-adopt the image into a
    /// fresh instance (the same [`Self::adopt_hibernated`] path a host
    /// restart uses) instead of paying a full cold start. Returns the
    /// salvaged manifest, or `None` when the image was already stale
    /// (running/woken instances mutate memory past the manifest) and only
    /// a cold start can replace the instance.
    pub fn crash(&mut self) -> Result<Option<ImageManifest>> {
        if self.state == ContainerState::Dead {
            return Ok(None);
        }
        let salvaged = if self.state == ContainerState::Hibernate {
            ImageManifest::load(&self.swap.files().manifest_path()).ok()
        } else {
            None
        };
        self.state = ContainerState::Dead;
        self.release_everything_inner(salvaged.is_some())?;
        Ok(salvaged)
    }

    fn release_everything(&mut self) -> Result<()> {
        self.release_everything_inner(false)
    }

    fn release_everything_inner(&mut self, preserve_image: bool) -> Result<()> {
        // A dead image must never be adopted: drop the manifest and
        // revert the files to delete-on-drop. The one exception is a
        // crash whose manifest was salvaged for re-adoption — there the
        // files must outlive this sandbox (persist stays set).
        if !preserve_image {
            self.swap.files_mut().discard_manifest();
        }
        self.release_file_pages(false)?;
        self.svc.cache.trim_unmapped();
        // Release the QKernel heap.
        let kernel: Vec<Gpa> = (0..self.kernel_pages)
            .map(|i| Gpa(self.kernel_chunk.0 + i * PAGE_SIZE as u64))
            .collect();
        self.svc.host.discard_pages(&kernel)?;
        self.svc
            .heap
            .free(self.kernel_chunk)
            .map_err(|e| anyhow::anyhow!("freeing kernel heap: {e}"))?;
        for p in &mut self.procs {
            let mut anon: Vec<Gpa> = Vec::new();
            p.asp.pt.for_each(|_gva, pte| {
                if (pte.present() || pte.swapped()) && !pte.is_file() {
                    anon.push(pte.gpa());
                }
            });
            p.asp.pt.for_each_mut(|_gva, _pte| Pte::EMPTY);
            for gpa in anon {
                self.alloc.dec_ref(gpa);
            }
        }
        self.alloc.reclaim_free_pages()?;
        if let Some(env) = self.env.take() {
            env.release()?;
        }
        Ok(())
    }

    /// Rebuild a hibernated sandbox from a persisted image manifest after
    /// a host restart. `files` is the swap/REAP pair the caller re-opened
    /// via [`SwapFileSet::adopt_with_backend`] against the same manifest.
    ///
    /// The reconstruction runs a throwaway-clock cold start to rebuild the
    /// guest skeleton (address spaces, host objects, kernel heap — none of
    /// which the manifest stores, all of which are deterministic functions
    /// of the spec), then deflates it into the manifest's shape: app file
    /// mappings dropped, recorded REAP pages left present-but-uncommitted,
    /// every other imaged page marked bit-#9 swapped, frames discarded,
    /// slot tables and the REAP protocol state restored. On return the
    /// sandbox is `Hibernate` and wakes exactly like one this process
    /// deflated itself. Any mismatch between manifest and skeleton is a
    /// hard error — the caller discards the image and cold-starts.
    pub fn adopt_hibernated(
        id: u64,
        spec: WorkloadSpec,
        svc: Arc<SandboxServices>,
        manifest: &ImageManifest,
        files: SwapFileSet,
    ) -> Result<Sandbox> {
        if spec.name != manifest.workload {
            bail!(
                "manifest for workload {} adopted under deploy {}",
                manifest.workload,
                spec.name
            );
        }
        let skeleton_clock = Clock::new();
        let mut sb = Self::cold_start_inner(id, spec, svc, &skeleton_clock, Some(files))?;
        sb.hibernate_begin()?;
        // Deflation steps the skeleton owes (#2/#4): drop app file
        // mappings; freed pages reclaim below, after the anon re-mark.
        sb.release_file_pages(true)?;
        sb.svc.cache.trim_unmapped();

        // Re-mark every process's anon PTEs into the manifest's shape:
        // recorded REAP pages stay present (frames discarded below — the
        // post-REAP-swap-out uncommitted state), other imaged pages flip
        // to bit-#9 swapped, and pages the image does not contain unmap.
        let reap_set_gvas: HashSet<u64> = manifest.reap_set.iter().copied().collect();
        let swap_rows: HashMap<u64, u64> =
            manifest.swap_pages.iter().map(|p| (p.gva, p.offset)).collect();
        for p in 0..sb.procs.len() {
            let mut dropped: Vec<Gpa> = Vec::new();
            sb.procs[p].asp.pt.for_each_mut(|gva, pte| {
                if !(pte.present() || pte.swapped()) || pte.is_file() {
                    return pte;
                }
                if reap_set_gvas.contains(&gva.0) {
                    return pte;
                }
                if swap_rows.contains_key(&gva.0) {
                    return pte.to_swapped();
                }
                dropped.push(pte.gpa());
                Pte::EMPTY
            });
            for gpa in dropped {
                sb.alloc.dec_ref(gpa);
            }
        }
        sb.alloc.reclaim_free_pages()?;

        // Rebuild the swap manager's slot tables, resolving each manifest
        // row's gva through the skeleton's page table. A row the skeleton
        // cannot place means spec and image disagree: reject the image.
        let resolve = |sb: &Sandbox, gva: u64, what: &str| -> Result<Gpa> {
            let pte = sb.procs[0].asp.pt.get(Gva(gva));
            if !(pte.present() || pte.swapped()) {
                bail!("manifest {what} gva {gva:#x} absent from the skeleton layout");
            }
            Ok(pte.gpa())
        };
        let mut swap_slots = Vec::with_capacity(manifest.swap_pages.len());
        let mut imaged: Vec<Gpa> = Vec::with_capacity(manifest.swap_pages.len());
        for row in &manifest.swap_pages {
            let gpa = resolve(&sb, row.gva, "swap page")?;
            swap_slots.push((gpa, SwapSlot(row.offset)));
            imaged.push(gpa);
        }
        let mut reap_slots = Vec::with_capacity(manifest.reap_pages.len());
        for row in &manifest.reap_pages {
            let gpa = resolve(&sb, row.gva, "REAP page")?;
            reap_slots.push((gpa, SwapSlot(row.offset)));
            imaged.push(gpa);
        }
        let mut reap_set = Vec::with_capacity(manifest.reap_set.len());
        for &gva in &manifest.reap_set {
            reap_set.push(resolve(&sb, gva, "reap-set")?);
        }
        // The imaged pages' data lives on disk; the skeleton's frames are
        // placeholders. Discard them like the original deflation did.
        imaged.sort_unstable_by_key(|g| g.0);
        imaged.dedup();
        sb.svc.host.discard_pages(&imaged)?;
        sb.swap.adopt_image(swap_slots, reap_slots, reap_set);

        // Restore the REAP protocol state: an image with a recorded set
        // wakes by prefetch; one without (full page-fault deflation) needs
        // its sample request, exactly as if this process had deflated it.
        if manifest.reap_set.is_empty() {
            sb.reap.on_full_swapout(manifest.swap_pages.len() as u64);
        } else {
            sb.reap.restore_recorded(
                manifest.reap_swapped_out_pages,
                manifest.reap_recorded_pages,
            );
        }
        sb.manifest_generation = manifest.generation;
        // The manifest on disk still describes this image: keep both it
        // and the files until a wake mutates them.
        sb.swap.files_mut().set_persist(true);
        Ok(sb)
    }

    /// Drain pending control signals at a safe point (the container is
    /// idle): SIGSTOP deflates, SIGCONT anticipatorily inflates. Illegal
    /// edges (e.g. Cont while Warm) are dropped, like real signals whose
    /// handler finds nothing to do. Returns signals acted upon.
    pub fn drain_signals(&mut self, clock: &Clock) -> Result<u32> {
        let mut acted = 0;
        while let Some(sig) = self.signals.take() {
            match (sig, self.state) {
                (ControlSignal::Stop, ContainerState::Warm | ContainerState::WokenUp) => {
                    self.hibernate(clock)?;
                    acted += 1;
                }
                (ControlSignal::Cont, ContainerState::Hibernate) => {
                    self.wake(clock)?;
                    acted += 1;
                }
                _ => {}
            }
        }
        Ok(acted)
    }

    /// Like [`Self::drain_signals`], but both directions perform only the
    /// cheap state flip ([`Self::hibernate_begin`] / [`Self::wake_begin`]);
    /// the expensive I/O is left for the caller to run — or hand to a
    /// pipeline worker — via [`Self::hibernate_finish`] /
    /// [`Self::wake_finish`]. Returns which finish (if any) is now owed.
    /// This is the platform's off-tick path: the flips happen inside the
    /// policy tick, the I/O does not.
    ///
    /// Opposite signals in one drain cancel each other's pending I/O: a
    /// Cont landing on a Stop whose deflation never ran needs no inflation
    /// (the memory never left), and a Stop landing on a Cont whose
    /// prefetch never ran needs no deflation (the memory never came back).
    pub fn drain_signals_deferred(&mut self, clock: &Clock) -> Result<Option<PendingIo>> {
        let mut pending = None;
        while let Some(sig) = self.signals.take() {
            match (sig, self.state) {
                (ControlSignal::Stop, ContainerState::Warm | ContainerState::WokenUp) => {
                    self.hibernate_begin()?;
                    self.trace(EventKind::HibernateBegin, 0, clock);
                    pending = match pending {
                        Some(PendingIo::Inflate) => None,
                        _ => Some(PendingIo::Deflate),
                    };
                }
                (ControlSignal::Cont, ContainerState::Hibernate) => {
                    self.wake_begin(clock)?;
                    pending = match pending {
                        Some(PendingIo::Deflate) => None,
                        _ => Some(PendingIo::Inflate),
                    };
                }
                _ => {}
            }
        }
        Ok(pending)
    }

    /// Host-object view (None after termination).
    pub fn host_env(&self) -> Option<&HostEnv> {
        self.env.as_ref()
    }

    /// PSS of this sandbox (the Fig. 7 metric): guest mappings plus the
    /// QKernel resident heap and allocator metadata (control pages) — the
    /// runtime-process memory pmap would attribute to the sandbox.
    pub fn footprint(&self) -> PssBreakdown {
        let tables: Vec<&PageTable> = self.procs.iter().map(|p| &p.asp.pt).collect();
        let mut b = pss(&tables, &self.svc.host, &self.alloc, &self.svc.cache);
        b.anon_bytes += self.kernel_pages * PAGE_SIZE as u64 + self.alloc.metadata_bytes();
        b
    }

    /// The live-byte charge budget accounting uses for this sandbox: the
    /// resident footprint while runnable, the live swapped-slot image
    /// bytes while hibernated (the §3.1 point — a deflated container
    /// costs its swap image, not memory), nothing once dead. The swap and
    /// REAP files both hold a live image after a REAP-path hibernate; the
    /// larger one is the deflated set.
    pub fn live_bytes(&self) -> u64 {
        match self.state {
            ContainerState::Hibernate => self
                .swap
                .swapped_bytes()
                .max(self.swap.reap_live_pages() * PAGE_SIZE as u64),
            ContainerState::Dead => 0,
            _ => self.footprint().total_bytes(),
        }
    }

    /// O(1) estimate of the live-byte charge this sandbox will hold once
    /// a just-begun wake's REAP prefetch lands: the deflated image plus
    /// the recorded working set the prefetch will commit. Budget
    /// accounting charges an inflating instance at this estimate until
    /// the finish stores the real footprint — deliberately a slight
    /// over-count (image pages in the working set appear twice) so
    /// in-flight inflations can never read as budget headroom.
    pub fn wake_estimate_bytes(&self) -> u64 {
        self.swap.swapped_bytes() + self.swap.reap_live_pages() * PAGE_SIZE as u64
    }

    /// Allocator occupancy (debug/metrics).
    pub fn alloc_stats(&self) -> crate::mem::bitmap_alloc::AllocStats {
        self.alloc.stats()
    }

    pub fn is_paused(&self) -> bool {
        self.paused
    }
}

impl std::fmt::Debug for Sandbox {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Sandbox")
            .field("id", &self.id)
            .field("workload", &self.spec.name)
            .field("state", &self.state)
            .field("requests", &self.requests_served)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::NoopRunner;
    use crate::mem::mmap_file::FileClass;
    use crate::workloads::functionbench::{nodejs_hello, scaled_for_test};

    fn rig(tag: &str) -> Arc<SandboxServices> {
        SandboxServices::new_local(
            512 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            tag,
        )
        .unwrap()
    }

    /// Present PTEs of process `p` in `[start, start + pages)`.
    fn present_in(sb: &Sandbox, p: usize, start: Gva, pages: u64) -> u64 {
        (0..pages)
            .filter(|i| {
                sb.procs[p]
                    .asp
                    .pt
                    .get(Gva(start.0 + i * PAGE_SIZE as u64))
                    .present()
            })
            .count() as u64
    }

    #[test]
    fn deflation_spares_runtime_pages_and_releases_app_files() {
        // Deflation step #4 through the full hibernate path: the Quark
        // runtime binary's pages must survive (its parked threads make the
        // demand wake fast), every app file mapping must go.
        let svc = rig("sb-keep-runtime");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(1, scaled_for_test(nodejs_hello(), 8), svc.clone(), &clock)
                .unwrap();
        sb.handle_request(&clock).unwrap();
        let quark_before = present_in(&sb, 0, sb.quark_base, sb.quark_pages);
        let bin_before =
            present_in(&sb, 0, sb.layout.binary_base, sb.layout.binary_pages);
        assert!(quark_before > 0 && bin_before > 0, "init must touch both");
        let rpt = sb.hibernate(&clock).unwrap();
        assert!(rpt.file_pages_released >= bin_before);
        assert_eq!(
            present_in(&sb, 0, sb.quark_base, sb.quark_pages),
            quark_before,
            "QuarkRuntime-class pages must survive deflation"
        );
        assert_eq!(
            present_in(&sb, 0, sb.layout.binary_base, sb.layout.binary_pages),
            0,
            "language-runtime pages must be dropped"
        );
        // Terminate drops the runtime mapping too (keep_runtime = false).
        sb.terminate().unwrap();
        assert_eq!(present_in(&sb, 0, sb.quark_base, sb.quark_pages), 0);
    }

    #[test]
    fn release_drops_shared_cache_mappings_and_private_copies() {
        // Both flavors of file memory in one sandbox: a *shared* mmap'd
        // data file mapped by TWO guest processes (one cache page, two
        // mappers) and a *private* per-sandbox copy. release_file_pages
        // must unmap both processes' PTEs, drop the cache mapcounts to 0,
        // and return the private copy to the sandbox allocator — while
        // keep_runtime spares the Quark binary.
        let svc = rig("sb-shared-file");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(2, scaled_for_test(nodejs_hello(), 16), svc.clone(), &clock)
                .unwrap();
        let pages = 4u64;
        let len = pages * PAGE_SIZE as u64;
        let shared_id = svc.registry.get_or_register(
            "shared-data.bin",
            len,
            FileClass::AppData,
        );
        let private_id = svc.registry.get_or_register(
            "private-data.bin",
            len,
            FileClass::AppData,
        );
        // Second guest process sharing the same mmap'd file.
        sb.procs.push(GuestProcess::new());
        let g0 = sb.procs[0]
            .asp
            .mmap_file(shared_id, 0, len, true, "shared-data.bin")
            .unwrap();
        let g1 = sb.procs[1]
            .asp
            .mmap_file(shared_id, 0, len, true, "shared-data.bin")
            .unwrap();
        let gp = sb.procs[0]
            .asp
            .mmap_file(private_id, 0, len, false, "private-data.bin")
            .unwrap();
        let mut miss = 0u64;
        for i in 0..pages {
            let off = i * PAGE_SIZE as u64;
            sb.fault_file(0, Gva(g0.0 + off), &clock, &mut miss).unwrap();
            sb.fault_file(1, Gva(g1.0 + off), &clock, &mut miss).unwrap();
            sb.fault_file(0, Gva(gp.0 + off), &clock, &mut miss).unwrap();
        }
        assert_eq!(
            svc.cache.mapcount(shared_id, 0),
            2,
            "one cache page, two guest processes mapping it"
        );
        let private_gpa = sb.procs[0].asp.pt.get(gp).gpa();
        assert!(sb.alloc.refcount(private_gpa) > 0);
        let quark_before = present_in(&sb, 0, sb.quark_base, sb.quark_pages);

        let released = sb.release_file_pages(true).unwrap();
        // 2 procs × shared + 1 private, plus the language binary's pages.
        assert!(released >= 3 * pages, "released only {released}");
        for i in 0..pages {
            assert_eq!(svc.cache.mapcount(shared_id, i), 0, "page {i} still mapped");
            let off = i * PAGE_SIZE as u64;
            assert!(sb.procs[0].asp.pt.get(Gva(g0.0 + off)).is_empty());
            assert!(sb.procs[1].asp.pt.get(Gva(g1.0 + off)).is_empty());
            assert!(sb.procs[0].asp.pt.get(Gva(gp.0 + off)).is_empty());
        }
        assert_eq!(
            sb.alloc.refcount(private_gpa),
            0,
            "private copy must be returned to the sandbox allocator"
        );
        assert_eq!(
            present_in(&sb, 0, sb.quark_base, sb.quark_pages),
            quark_before,
            "keep_runtime must spare the Quark binary mapping"
        );
        // The unmapped cache pages are reclaimable now.
        assert!(svc.cache.trim_unmapped() >= pages);
        sb.terminate().unwrap();
    }

    #[test]
    fn hibernate_finish_requires_begin() {
        let svc = rig("sb-split");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(3, scaled_for_test(nodejs_hello(), 16), svc, &clock).unwrap();
        assert!(
            sb.hibernate_finish(&clock).is_err(),
            "finish without begin must be rejected"
        );
        sb.hibernate_begin().unwrap();
        assert_eq!(sb.state(), ContainerState::Hibernate);
        assert!(sb.is_paused());
        let rpt = sb.hibernate_finish(&clock).unwrap();
        assert!(rpt.pages_swapped_out > 0);
        // Begin+finish ≡ the one-shot path: a demand wake still works.
        let out = sb.handle_request(&clock).unwrap();
        assert_eq!(out.from, ContainerState::Hibernate);
        assert!(out.anon_faults > 0);
    }

    #[test]
    fn wake_finish_requires_begin_and_split_equals_one_shot() {
        let svc = rig("sb-wake-split");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(4, scaled_for_test(nodejs_hello(), 16), svc, &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        assert!(
            sb.wake_finish(&clock).is_err(),
            "finish without begin must be rejected"
        );
        // Build a REAP image: full hibernate → sample request → REAP
        // hibernate.
        sb.hibernate(&clock).unwrap();
        sb.handle_request(&clock).unwrap();
        let rpt = sb.hibernate(&clock).unwrap();
        assert!(rpt.used_reap);
        // Split wake: begin flips to WokenUp with nothing inflated yet;
        // finish prefetches the recorded working set.
        sb.wake_begin(&clock).unwrap();
        assert_eq!(sb.state(), ContainerState::WokenUp);
        assert!(!sb.is_paused());
        let prefetched = sb.wake_finish(&clock).unwrap();
        assert!(prefetched > 0, "REAP prefetch must run in the finish");
        // Begin+finish ≡ the one-shot path: the request is Warm-like.
        let out = sb.handle_request(&clock).unwrap();
        assert_eq!(out.from, ContainerState::WokenUp);
        assert_eq!(out.anon_faults, 0, "working set fully prefetched");
        assert_eq!(out.reap_prefetched, 0, "prefetch already done");
    }

    #[test]
    fn steady_state_reap_hibernate_writes_zero_pages() {
        // The sandbox-level view of the delta-REAP contract: hibernate →
        // anticipatory wake (no request) → hibernate writes 0 page images.
        let svc = rig("sb-reap-steady");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(5, scaled_for_test(nodejs_hello(), 16), svc, &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        sb.hibernate(&clock).unwrap();
        sb.handle_request(&clock).unwrap(); // sample request records the WS
        let first = sb.hibernate(&clock).unwrap();
        assert!(first.used_reap);
        assert!(first.pages_swapped_out > 0, "first REAP cycle writes the WS");
        sb.wake(&clock).unwrap();
        let second = sb.hibernate(&clock).unwrap();
        assert!(second.used_reap);
        assert_eq!(
            second.pages_swapped_out, 0,
            "untouched wake → REAP hibernate must write nothing"
        );
        // The image is still complete: a demand wake serves correctly.
        let out = sb.handle_request(&clock).unwrap();
        assert!(out.reap_prefetched > 0);
        assert_eq!(out.anon_faults, 0);
    }

    #[test]
    fn hibernated_image_survives_restart_and_wakes_by_prefetch() {
        let svc = rig("sb-adopt");
        let clock = Clock::new();
        let spec = scaled_for_test(nodejs_hello(), 16);
        let mut sb = Sandbox::cold_start(7, spec.clone(), svc.clone(), &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        sb.hibernate(&clock).unwrap();
        sb.handle_request(&clock).unwrap(); // sample request records the WS
        let rpt = sb.hibernate(&clock).unwrap();
        assert!(rpt.used_reap);
        let mpath = sb.swap.files().manifest_path();
        let dir = sb.swap.files().dir().to_path_buf();
        assert!(mpath.exists(), "hibernate_finish must persist a manifest");
        // "Host crash": drop the sandbox without terminating. The
        // persisted image — files and manifest — must survive the drop.
        drop(sb);
        assert!(mpath.exists(), "a persisted image must survive the drop");

        let manifest = ImageManifest::load(&mpath).unwrap();
        assert_eq!(manifest.workload, spec.name);
        assert_eq!(manifest.generation, 2, "one manifest per hibernate cycle");
        assert!(!manifest.reap_set.is_empty(), "REAP cycle must record the WS");
        let swap_sums: Vec<(u64, u64)> =
            manifest.swap_pages.iter().map(|p| (p.offset, p.sum)).collect();
        let reap_sums: Vec<(u64, u64)> =
            manifest.reap_pages.iter().map(|p| (p.offset, p.sum)).collect();
        let files = SwapFileSet::adopt_with_backend(
            &dir,
            manifest.file_id,
            svc.io.clone(),
            manifest.swap_len,
            &swap_sums,
            manifest.reap_len,
            &reap_sums,
        )
        .unwrap();
        let mut sb2 =
            Sandbox::adopt_hibernated(99, spec, svc.clone(), &manifest, files).unwrap();
        assert_eq!(sb2.state(), ContainerState::Hibernate);

        // The adopted instance serves a demand wake from the on-disk
        // image — a wake, not a cold start: the recorded working set
        // arrives by REAP prefetch, nothing faults per page.
        let out = sb2.handle_request(&clock).unwrap();
        assert_eq!(out.from, ContainerState::Hibernate);
        assert!(out.reap_prefetched > 0, "adopted image must wake by prefetch");
        assert_eq!(out.anon_faults, 0, "recorded working set fully prefetched");
        assert!(
            !mpath.exists(),
            "waking mutates the image: the stale manifest must be discarded"
        );
        // Full lifecycle continues: re-hibernating writes the next
        // generation, terminating discards it.
        sb2.hibernate(&clock).unwrap();
        let m2 = ImageManifest::load(&mpath).unwrap();
        assert_eq!(m2.generation, 3, "generation must rise monotonically");
        sb2.terminate().unwrap();
        assert!(!mpath.exists(), "terminate must discard the manifest");
    }

    #[test]
    fn deferred_drain_reports_pending_io_and_cancels_pairs() {
        use crate::container::signal::ControlSignal;
        let svc = rig("sb-deferred");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(6, scaled_for_test(nodejs_hello(), 16), svc, &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        // Stop → a deflation is owed.
        sb.signals.send(ControlSignal::Stop);
        assert_eq!(
            sb.drain_signals_deferred(&clock).unwrap(),
            Some(PendingIo::Deflate)
        );
        sb.hibernate_finish(&clock).unwrap();
        // Cont → an inflation is owed.
        sb.signals.send(ControlSignal::Cont);
        assert_eq!(
            sb.drain_signals_deferred(&clock).unwrap(),
            Some(PendingIo::Inflate)
        );
        sb.wake_finish(&clock).unwrap();
        // Stop immediately followed by Cont: the deflation never ran, so
        // nothing is owed — the memory never left.
        sb.signals.send(ControlSignal::Stop);
        sb.signals.send(ControlSignal::Cont);
        assert_eq!(sb.drain_signals_deferred(&clock).unwrap(), None);
        assert_eq!(sb.state(), ContainerState::WokenUp);
        let out = sb.handle_request(&clock).unwrap();
        assert_eq!(out.from, ContainerState::WokenUp);
    }
}
