//! A Quark sandbox: the unit the platform schedules and the paper
//! hibernates.
//!
//! Owns a per-sandbox Bitmap Page Allocator (each sandbox is its own
//! QKernel instance drawing 4 MiB blocks from the global heap), its guest
//! processes' address spaces, the Swapping Manager with its two files, and
//! the REAP recorder. Implements:
//!
//! * **cold start**: sandbox startup + runtime/app init (Fig. 3 ①);
//! * **request handling** from Warm *and* from Hibernate/WokenUp (②⑥⑦);
//! * **the 4-step deflation** of §3.2 (pause → reclaim freed pages →
//!   swap out committed anon pages → drop file-backed mmap pages);
//! * **the 2 wake triggers**: demand (a request lands on a Hibernate
//!   container and the parked runtime thread unblocks) and anticipatory
//!   (platform SIGCONT, Fig. 3 ⑤).

use super::app::{anon_content_seed, AppLayout, GuestProcess};
use super::hostenv::{HostEnv, HostEnvCost, HostEnvRegistry};
use super::signal::{ControlSignal, SignalQueue};
use super::state::{ContainerState, Event};
use super::PayloadRunner;
use crate::config::SharingConfig;
use crate::mem::bitmap_alloc::BitmapPageAllocator;
use crate::mem::buddy::BuddyAllocator;
use crate::mem::host::HostMemory;
use crate::mem::mmap_file::{FileClass, FilePageCache, FileRegistry};
use crate::mem::page_table::{PageTable, Pte};
use crate::mem::pss::{pss, PssBreakdown};
use crate::mem::vma::VmaKind;
use crate::mem::{Gpa, Gva};
use crate::obs::{ARG_FLAG, EventKind, Recorder};
use crate::platform::io_backend::{IoBackend, SyncBackend};
use crate::simtime::{Clock, CostModel};
use crate::swap::file::SwapFileSet;
use crate::swap::{ReapRecorder, SwapMgr};
use crate::workloads::WorkloadSpec;
use crate::PAGE_SIZE;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// The Quark runtime binary every sandbox maps (qkernel + qvisor image).
pub const QUARK_BINARY_NAME: &str = "quark-qkernel.bin";
/// Size of the runtime binary mapping.
pub const QUARK_BINARY_BYTES: u64 = 12 << 20;
/// Fraction of the runtime binary touched by a running sandbox.
pub const QUARK_BINARY_TOUCH_FRAC: f64 = 0.4;
/// QKernel's own resident heap (kernel stacks, task structs, page-metadata
/// arrays): a base plus a per-guest-page component. These pages are what
/// Hibernate *keeps* — "Host OS objects … consume little system memory but
/// keeping them alive saves much reinitialization cost" (§1) — and are the
/// floor under the paper's 7–25 %-of-Warm hibernate footprint.
pub const KERNEL_BASE_PAGES: u64 = 512; // 2 MiB
pub const KERNEL_PER_ANON_FRAC: f64 = 0.05;

/// Host-side services shared by all sandboxes on a node.
pub struct SandboxServices {
    pub host: Arc<HostMemory>,
    pub heap: Arc<BuddyAllocator>,
    pub cache: Arc<FilePageCache>,
    pub registry: Arc<FileRegistry>,
    pub cost: CostModel,
    pub sharing: SharingConfig,
    pub swap_dir: PathBuf,
    pub runner: Arc<dyn PayloadRunner>,
    /// Policy: may sandboxes use REAP batch swap-in?
    pub reap_enabled: bool,
    /// Host-object registry (cgroups, netns, rootfs mounts).
    pub hostenv: Arc<HostEnvRegistry>,
    /// Node-wide I/O backend every sandbox's swap files submit their batch
    /// slot runs through (`[io]` config: sync or batched).
    pub io: Arc<dyn IoBackend>,
    /// Flight recorder lifecycle seams emit into ([`crate::obs`]). Local
    /// rigs get a disabled recorder (emission is a no-op); the platform
    /// injects its own per-shard-ring recorder.
    pub recorder: Arc<Recorder>,
}

impl SandboxServices {
    /// Build a full service rig over a fresh host region (tests, examples),
    /// with the default synchronous I/O backend.
    pub fn new_local(
        host_bytes: usize,
        cost: CostModel,
        sharing: SharingConfig,
        runner: Arc<dyn PayloadRunner>,
        swap_tag: &str,
    ) -> Result<Arc<Self>> {
        Self::new_local_with_io(
            host_bytes,
            cost,
            sharing,
            runner,
            swap_tag,
            Arc::new(SyncBackend::new()),
        )
    }

    /// [`Self::new_local`] with an explicit I/O backend (fault-injection
    /// rigs wrap one; batched-backend tests pass a
    /// [`crate::platform::io_backend::BatchedBackend`]).
    pub fn new_local_with_io(
        host_bytes: usize,
        cost: CostModel,
        sharing: SharingConfig,
        runner: Arc<dyn PayloadRunner>,
        swap_tag: &str,
        io: Arc<dyn IoBackend>,
    ) -> Result<Arc<Self>> {
        let host = Arc::new(HostMemory::new(host_bytes)?);
        let len = host.size() as u64;
        let heap = Arc::new(BuddyAllocator::new(host.clone(), 0, len)?);
        // The file page cache draws from its own allocator (platform-level
        // page cache, not owned by any sandbox).
        let cache_alloc = Arc::new(BitmapPageAllocator::new(host.clone(), heap.clone()));
        let cache = Arc::new(FilePageCache::new(cache_alloc));
        let swap_dir = std::env::temp_dir().join(format!(
            "quark-hibernate-{}-{}",
            swap_tag,
            std::process::id()
        ));
        Ok(Arc::new(Self {
            host,
            heap,
            cache,
            registry: Arc::new(FileRegistry::new()),
            cost,
            sharing,
            swap_dir,
            runner,
            reap_enabled: true,
            hostenv: HostEnvRegistry::new(),
            io,
            recorder: Recorder::disabled(),
        }))
    }

    fn share_file(&self, class: FileClass) -> bool {
        match class {
            FileClass::QuarkRuntime => self.sharing.share_runtime_binary,
            FileClass::LanguageRuntime => self.sharing.share_language_runtime,
            FileClass::AppData => false,
        }
    }
}

/// Report of one deflation (§3.2's four steps).
#[derive(Debug, Clone, Copy, Default)]
pub struct HibernateReport {
    /// Step 2: freed pages returned to the host.
    pub freed_pages_reclaimed: u64,
    /// Step 3: unique anon pages written (swap or REAP file).
    pub pages_swapped_out: u64,
    /// Step 3: used the REAP batch path?
    pub used_reap: bool,
    /// Step 4: file-backed pages dropped from this sandbox's tables.
    pub file_pages_released: u64,
}

/// Per-request outcome (latency lives on the caller's clock).
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub from: ContainerState,
    /// Was this the REAP sample request?
    pub sample_request: bool,
    /// Anon pages faulted in from the swap file.
    pub anon_faults: u64,
    /// File-backed bytes re-read from the image (cache misses).
    pub file_miss_bytes: u64,
    /// Working-set pages prefetched by REAP before processing.
    pub reap_prefetched: u64,
    /// Demand-wake admission overhead (dispatch + thread unpark) charged
    /// on this request's clock; 0 unless served from Hibernate. Feeds the
    /// wake-phase admission histogram.
    pub admission_ns: u64,
}

/// What expensive I/O a deferred signal drain left owed
/// ([`Sandbox::drain_signals_deferred`]): the cheap state flip already
/// happened inside the policy tick; the finish belongs on the platform's
/// instance-I/O pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PendingIo {
    /// [`Sandbox::hibernate_begin`] ran; [`Sandbox::hibernate_finish`]
    /// (the deflation swap/release I/O) is owed.
    Deflate,
    /// [`Sandbox::wake_begin`] ran; [`Sandbox::wake_finish`] (the REAP
    /// batch prefetch) is owed.
    Inflate,
}

/// A sandboxed container instance.
pub struct Sandbox {
    pub id: u64,
    spec: WorkloadSpec,
    /// `fnv1a(spec.name)` — the flight-recorder ring key, cached so every
    /// emission avoids rehashing the workload name.
    workload_hash: u64,
    svc: Arc<SandboxServices>,
    state: ContainerState,
    alloc: Arc<BitmapPageAllocator>,
    procs: Vec<GuestProcess>,
    layout: AppLayout,
    /// Quark runtime binary mapping (own VMA in process 0).
    quark_base: Gva,
    quark_pages: u64,
    swap: SwapMgr,
    reap: ReapRecorder,
    /// QKernel resident heap: buddy chunk start + page count. Committed at
    /// cold start, survives hibernation, released at termination.
    kernel_chunk: Gpa,
    kernel_pages: u64,
    /// Host OS objects (cgroup/netns/rootfs) — created at cold start,
    /// *kept alive* across hibernation (§1), released at termination.
    env: Option<HostEnv>,
    /// Pending control signals from the platform (SIGSTOP/SIGCONT).
    pub signals: SignalQueue,
    requests_served: u64,
    paused: bool,
}

impl Sandbox {
    /// Cold start (Fig. 3 ①): sandbox startup + runtime & app init. On
    /// return the container is Warm and fully initialized.
    pub fn cold_start(
        id: u64,
        spec: WorkloadSpec,
        svc: Arc<SandboxServices>,
        clock: &Clock,
    ) -> Result<Sandbox> {
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        let workload_hash = crate::util::fnv1a(&spec.name);
        let rec = svc.recorder.clone();
        let t_begin = clock.charged_ns();
        rec.emit_workload(EventKind::ColdStartBegin, id, workload_hash, 0, clock.stamp_ns());
        // Container runtime startup. The host-object components (cgroup,
        // netns, rootfs, threads) are charged itemized by the registry; the
        // remainder is VM creation (KVM fd, memory region, vCPU setup).
        let env_cost = HostEnvCost::default_split();
        clock.charge(
            svc.cost
                .sandbox_startup_ns
                .saturating_sub(env_cost.total_ns()),
        );
        let env = svc.hostenv.create(
            id,
            &["quark-base.img", spec.lang.binary_name()],
            (spec.init_anon_pages + spec.request_scratch_pages) * PAGE_SIZE as u64 * 2,
            env_cost,
            clock,
        )?;
        rec.emit_workload(
            EventKind::ColdPhaseEnv,
            id,
            workload_hash,
            clock.charged_ns() - t_begin,
            clock.stamp_ns(),
        );
        let t_env = clock.charged_ns();

        let alloc = Arc::new(BitmapPageAllocator::new(svc.host.clone(), svc.heap.clone()));
        let binary_file = svc.registry.get_or_register(
            spec.lang.binary_name(),
            spec.binary_bytes,
            FileClass::LanguageRuntime,
        );
        let quark_file = svc.registry.get_or_register(
            QUARK_BINARY_NAME,
            QUARK_BINARY_BYTES,
            FileClass::QuarkRuntime,
        );

        let mut proc0 = GuestProcess::new();
        let share_lang = svc.share_file(FileClass::LanguageRuntime);
        let layout = AppLayout::install(&spec, &mut proc0.asp, binary_file, share_lang)?;
        let quark_pages = QUARK_BINARY_BYTES / PAGE_SIZE as u64;
        let share_quark = svc.share_file(FileClass::QuarkRuntime);
        let quark_base = proc0.asp.mmap_file(
            quark_file,
            0,
            quark_pages * PAGE_SIZE as u64,
            share_quark,
            QUARK_BINARY_NAME,
        )?;

        let files = SwapFileSet::create_with_backend(&svc.swap_dir, id, svc.io.clone())
            .context("creating sandbox swap files")?;
        let swap = SwapMgr::new(files, svc.cost.clone());
        let reap = ReapRecorder::new(svc.reap_enabled);

        // QKernel's resident heap: committed now, never deflated.
        let kernel_pages =
            KERNEL_BASE_PAGES + (spec.init_anon_pages as f64 * KERNEL_PER_ANON_FRAC) as u64;
        let kernel_chunk = svc
            .heap
            .alloc_bytes(kernel_pages * PAGE_SIZE as u64)
            .map_err(|e| anyhow::anyhow!("kernel heap: {e}"))?;
        for i in 0..kernel_pages {
            svc.host
                .fill_page(Gpa(kernel_chunk.0 + i * PAGE_SIZE as u64), id ^ i)?;
        }

        let mut sb = Sandbox {
            id,
            spec,
            workload_hash,
            svc,
            state: ContainerState::ColdStarting,
            alloc,
            procs: vec![proc0],
            layout,
            quark_base,
            quark_pages,
            swap,
            reap,
            kernel_chunk,
            kernel_pages,
            env: Some(env),
            signals: SignalQueue::new(),
            requests_served: 0,
            paused: false,
        };

        // --- Init phase: touch runtime + binary + heap. ---
        let mut miss_bytes = 0u64;
        let quark_touch = ((quark_pages as f64) * QUARK_BINARY_TOUCH_FRAC).round() as u64;
        for i in 0..quark_touch {
            let gva = Gva(sb.quark_base.0 + i * PAGE_SIZE as u64);
            sb.fault_file(0, gva, clock, &mut miss_bytes)?;
        }
        for i in 0..sb.spec.binary_init_pages() {
            let gva = sb.layout.binary_page(i);
            sb.fault_file(0, gva, clock, &mut miss_bytes)?;
        }
        // Cold image loads stream from the registry (container image on
        // local disk): sequential, not scattered.
        clock.charge(sb.svc.cost.seq_read_ns(miss_bytes));
        rec.emit_workload(
            EventKind::ColdPhaseLayout,
            id,
            workload_hash,
            clock.charged_ns() - t_env,
            clock.stamp_ns(),
        );
        let t_layout = clock.charged_ns();
        for i in 0..sb.layout.heap_pages {
            sb.fault_anon(0, sb.layout.heap_page(i), true, clock)?;
        }
        clock.charge(sb.spec.init_ns);

        // --- Clones: fork children COW-sharing the init heap. ---
        for _ in 1..sb.spec.processes {
            sb.clone_process()?;
        }
        rec.emit_workload(
            EventKind::ColdPhaseInit,
            id,
            workload_hash,
            clock.charged_ns() - t_layout,
            clock.stamp_ns(),
        );

        sb.state = sb.state.transition(Event::ColdStartDone)?;
        rec.emit_workload(
            EventKind::ColdStartEnd,
            id,
            workload_hash,
            clock.charged_ns() - t_begin,
            clock.stamp_ns(),
        );
        Ok(sb)
    }

    /// Emit a flight-recorder event on this sandbox's workload ring,
    /// stamped at the clock's current virtual position.
    fn trace(&self, kind: EventKind, arg: u64, clock: &Clock) {
        if self.svc.recorder.is_enabled() {
            self.svc
                .recorder
                .emit_workload(kind, self.id, self.workload_hash, arg, clock.stamp_ns());
        }
    }

    pub fn state(&self) -> ContainerState {
        self.state
    }

    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    pub fn requests_served(&self) -> u64 {
        self.requests_served
    }

    pub fn swap_stats(&self) -> crate::swap::SwapStats {
        self.swap.stats()
    }

    pub fn reap_recorder(&self) -> &ReapRecorder {
        &self.reap
    }

    /// Fork a guest process: map every *present anon* heap page COW into the
    /// child (refcount++), downgrading the parent's PTE to read-only COW.
    fn clone_process(&mut self) -> Result<()> {
        let mut child = GuestProcess::new();
        let mut shares: Vec<(Gva, Pte)> = Vec::new();
        self.procs[0].asp.pt.for_each(|gva, pte| {
            if pte.present() && !pte.is_file() {
                shares.push((gva, pte));
            }
        });
        for (gva, pte) in shares {
            let gpa = pte.gpa();
            self.alloc.inc_ref(gpa);
            let cow = Pte::new_present(gpa, Pte::COW);
            self.procs[0].asp.pt.map(gva, cow);
            child.asp.pt.map(gva, cow);
        }
        self.procs.push(child);
        Ok(())
    }

    /// Anonymous page fault (or plain access) at `gva` of process `p`.
    fn fault_anon(&mut self, p: usize, gva: Gva, write: bool, clock: &Clock) -> Result<()> {
        let pte = self.procs[p].asp.pt.get(gva);
        if pte.is_empty() {
            // First touch: allocate from the Bitmap Page Allocator in the
            // page-fault handler (§3.3) and fill deterministic content.
            // The fill is a write, so the entry starts DIRTY (the delta
            // swap-out keys off the bit).
            let gpa = self.alloc.alloc_page()?;
            self.svc
                .host
                .fill_page(gpa, anon_content_seed(self.id, gva))?;
            self.procs[p]
                .asp
                .pt
                .map(gva, Pte::new_present(gpa, Pte::WRITABLE | Pte::DIRTY));
            clock.charge(
                self.svc.cost.page_fault_handling_ns + self.svc.cost.host_commit_per_page_ns,
            );
            return Ok(());
        }
        if pte.swapped() {
            let Sandbox { swap, procs, svc, reap, .. } = self;
            swap.fault_swap_in(&mut procs[p].asp.pt, gva, &svc.host, clock)?;
            reap.on_fault_in();
            // fall through for the COW/write handling on the restored pte
        }
        let pte = self.procs[p].asp.pt.get(gva);
        debug_assert!(pte.present());
        if write && pte.is_cow() {
            let gpa = pte.gpa();
            if self.alloc.refcount(gpa) > 1 {
                // COW break: copy to a private page.
                let new_gpa = self.alloc.alloc_page()?;
                let mut buf = vec![0u8; PAGE_SIZE];
                self.svc.host.read_page(gpa, &mut buf)?;
                self.svc.host.write_page(new_gpa, &buf)?;
                self.alloc.dec_ref(gpa);
                self.procs[p]
                    .asp
                    .pt
                    .map(gva, Pte::new_present(new_gpa, Pte::WRITABLE | Pte::DIRTY));
                clock.charge(
                    self.svc.cost.page_fault_handling_ns
                        + self.svc.cost.host_commit_per_page_ns,
                );
                return Ok(());
            }
            // Last owner: take the page back exclusively.
            self.procs[p]
                .asp
                .pt
                .update(gva, |q| q.without(Pte::COW).with(Pte::WRITABLE));
        }
        // touch_page modifies the frame (it is a write access), so mark the
        // entry DIRTY like the MMU would — the delta swap-out must rewrite
        // this page's slot image.
        self.procs[p].asp.pt.update(gva, |q| q.with(Pte::DIRTY));
        self.svc.host.touch_page(pte.gpa())?;
        Ok(())
    }

    /// File-backed page fault at `gva` of process `p`. Accumulates cache
    /// misses in `miss_bytes` (charged by the caller as one scattered or
    /// sequential read, modelling readahead batching).
    fn fault_file(
        &mut self,
        p: usize,
        gva: Gva,
        clock: &Clock,
        miss_bytes: &mut u64,
    ) -> Result<()> {
        let pte = self.procs[p].asp.pt.get(gva);
        if pte.present() {
            self.svc.host.touch_page(pte.gpa())?;
            return Ok(());
        }
        let (shared, file_id, page_no) = {
            let vma = self.procs[p]
                .asp
                .find_vma(gva)
                .with_context(|| format!("file fault outside any vma at {gva:?}"))?;
            let VmaKind::File { shared, .. } = &vma.kind else {
                bail!("fault_file on anon vma at {gva:?}");
            };
            let (file_id, page_no) = vma.file_page(gva).unwrap();
            (*shared, file_id, page_no)
        };
        let file = self.svc.registry.get(file_id);
        let gpa = if shared {
            let (gpa, hit) = self.svc.cache.map_shared(&file, page_no)?;
            if !hit {
                *miss_bytes += PAGE_SIZE as u64;
            }
            gpa
        } else {
            *miss_bytes += PAGE_SIZE as u64;
            self.svc.cache.map_private_for(&file, page_no, &self.alloc)?
        };
        self.procs[p].asp.pt.map(gva, Pte::new_present(gpa, Pte::FILE));
        // Minor fault: guest fault handling + one guest/host switch.
        clock.charge(self.svc.cost.page_fault_handling_ns + self.svc.cost.guest_host_switch_ns);
        Ok(())
    }

    /// Handle one request (Fig. 3 ②⑥⑦): touch the stable working set, run
    /// the real payload, release scratch memory, transition back (③⑧).
    pub fn handle_request(&mut self, clock: &Clock) -> Result<RequestOutcome> {
        let from = self.state;
        self.state = self.state.transition(Event::Request)?;
        let mut outcome = RequestOutcome {
            from,
            sample_request: false,
            anon_faults: 0,
            file_miss_bytes: 0,
            reap_prefetched: 0,
            admission_ns: 0,
        };
        if from == ContainerState::Hibernate {
            // Demand wake. The REAP batch read is issued the moment the
            // request is admitted, and the admission work — dispatch plus
            // unparking the runtime host thread (sys_accept returning) —
            // proceeds concurrently with it, so the serve path pays
            // max(admission, prefetch) instead of their sum: the request
            // no longer waits out the whole batch read up front.
            self.paused = false;
            self.trace(EventKind::WakeBegin, 0, clock);
            let admission_ns =
                self.svc.cost.request_dispatch_ns + self.svc.cost.thread_wake_ns;
            if self.swap.has_reap_image() {
                let prefetch = Clock::new();
                outcome.reap_prefetched =
                    self.swap.reap_swap_in(&self.svc.host, &prefetch)?;
                clock.charge(admission_ns.max(prefetch.charged_ns()));
                self.trace(
                    EventKind::WakeFinish,
                    (outcome.reap_prefetched * PAGE_SIZE as u64) | ARG_FLAG,
                    clock,
                );
            } else {
                clock.charge(admission_ns);
                self.trace(EventKind::WakeFinish, 0, clock);
            }
            outcome.admission_ns = admission_ns;
            outcome.sample_request = self.reap.on_wake_request();
        } else {
            clock.charge(self.svc.cost.request_dispatch_ns);
            if from == ContainerState::WokenUp {
                outcome.sample_request = self.reap.on_wake_request();
            }
        }

        // Touch the stable anon working set.
        let faults_before = self.swap.stats().pages_faulted_in;
        let anon_ws: Vec<Gva> = self.layout.request_anon_ws(&self.spec).collect();
        for gva in anon_ws {
            self.fault_anon(0, gva, false, clock)?;
        }
        outcome.anon_faults = self.swap.stats().pages_faulted_in - faults_before;

        // Touch the binary (code) working set + a slice of the runtime.
        let mut miss_bytes = 0u64;
        let bin_ws: Vec<Gva> = self.layout.request_binary_ws(&self.spec).collect();
        for gva in bin_ws {
            self.fault_file(0, gva, clock, &mut miss_bytes)?;
        }
        let quark_ws = ((self.quark_pages as f64) * 0.1).round() as u64;
        for i in 0..quark_ws {
            let gva = Gva(self.quark_base.0 + i * PAGE_SIZE as u64);
            self.fault_file(0, gva, clock, &mut miss_bytes)?;
        }
        // Demand-paged reload of scattered binary pages.
        clock.charge(self.svc.cost.scattered_read_ns(miss_bytes));
        outcome.file_miss_bytes = miss_bytes;

        // Scratch allocations (freed below → deflation step #2 fodder).
        for i in 0..self.layout.scratch_pages.min(self.spec.request_scratch_pages) {
            self.fault_anon(0, self.layout.scratch_page(i), true, clock)?;
        }

        // The real compute: AOT-compiled JAX/Pallas via PJRT.
        if let Some(payload) = self.spec.payload.clone() {
            self.svc.runner.run(&payload, clock)?;
        }
        clock.charge(self.spec.request_extra_ns);

        // Free scratch pages back to the allocator.
        let scratch: Vec<Gva> = (0..self.layout.scratch_pages.min(self.spec.request_scratch_pages))
            .map(|i| self.layout.scratch_page(i))
            .collect();
        for gva in scratch {
            let pte = self.procs[0].asp.pt.unmap(gva);
            if pte.present() || pte.swapped() {
                self.alloc.dec_ref(pte.gpa());
            }
        }

        self.reap.on_request_done();
        self.state = self.state.transition(Event::RequestDone)?;
        self.requests_served += 1;
        Ok(outcome)
    }

    /// SIGSTOP → deflate (§3.2's four steps). Legal from Warm and WokenUp.
    ///
    /// Composed of [`Self::hibernate_begin`] (the cheap state flip) and
    /// [`Self::hibernate_finish`] (the expensive swap/release I/O). The
    /// platform's policy loop performs the flip under its shard lock and
    /// hands the finish to a deflation worker so the I/O never stalls
    /// routing; direct callers get both in one call.
    pub fn hibernate(&mut self, clock: &Clock) -> Result<HibernateReport> {
        self.hibernate_begin()?;
        self.trace(EventKind::HibernateBegin, 0, clock);
        self.hibernate_finish(clock)
    }

    /// Deflation step #1 only: SIGSTOP semantics — pause the guest, park
    /// the runtime host threads, enter the Hibernate state. Cheap (no I/O,
    /// no page walks); after it returns the router sees `Hibernate` and
    /// stops preferring the instance, while the caller's reservation keeps
    /// requests off it until [`Self::hibernate_finish`] completes.
    pub fn hibernate_begin(&mut self) -> Result<()> {
        self.state = self.state.transition(Event::SigStop)?;
        self.paused = true;
        Ok(())
    }

    /// Deflation steps #2–#4: reclaim freed pages, swap out committed anon
    /// pages (delta), drop file-backed mappings. The expensive half — run
    /// it off the control-plane path, holding only this sandbox's mutex.
    /// Requires [`Self::hibernate_begin`] to have run.
    pub fn hibernate_finish(&mut self, clock: &Clock) -> Result<HibernateReport> {
        if self.state != ContainerState::Hibernate || !self.paused {
            bail!(
                "hibernate_finish without hibernate_begin (state {})",
                self.state
            );
        }
        let mut report = HibernateReport::default();

        // Step 2: reclaim freed application memory (scratch pages etc.).
        report.freed_pages_reclaimed = self.alloc.reclaim_free_pages()?;
        clock.charge(self.svc.cost.madvise_ns(report.freed_pages_reclaimed));

        // Step 3: swap out committed anon pages. Both paths are deltas:
        // `pages_swapped_out` counts the pages actually (re)written this
        // cycle, which for a steady-state REAP hibernate after an
        // untouched wake is zero.
        if self.reap.use_reap_swapout() {
            let Sandbox { swap, procs, svc, .. } = self;
            let mut tables: Vec<&mut PageTable> =
                procs.iter_mut().map(|p| &mut p.asp.pt).collect();
            let rpt = swap.reap_swap_out(&mut tables, &svc.host, clock)?;
            report.pages_swapped_out = rpt.unique_pages;
            report.used_reap = true;
        } else {
            let Sandbox { swap, procs, svc, reap, .. } = self;
            let mut tables: Vec<&mut PageTable> =
                procs.iter_mut().map(|p| &mut p.asp.pt).collect();
            let rpt = swap.swap_out(&mut tables, &svc.host, clock)?;
            report.pages_swapped_out = rpt.unique_pages;
            // The §3.4.1 working-set denominator is the full deflated set
            // (live swap images), not this cycle's delta.
            reap.on_full_swapout(rpt.live_pages);
        }

        // Step 4: clean up file-backed mmap memory (runtime binary spared).
        report.file_pages_released = self.release_file_pages(true)?;
        self.svc.cache.trim_unmapped();
        // Private file copies became free pages in our allocator: reclaim.
        let extra = self.alloc.reclaim_free_pages()?;
        clock.charge(self.svc.cost.madvise_ns(extra + report.file_pages_released));

        let flag = if report.used_reap { ARG_FLAG } else { 0 };
        self.trace(
            EventKind::HibernateFinish,
            (report.pages_swapped_out * PAGE_SIZE as u64) | flag,
            clock,
        );
        Ok(report)
    }

    /// Drop every file-backed PTE of every process, releasing cache
    /// mappings (shared) or private copies. Returns pages released.
    ///
    /// The **Quark runtime binary** is spared when `keep_runtime` — the
    /// runtime process is still alive in the Hibernate state (its parked
    /// threads are what make the demand wake fast), so its text pages stay
    /// mapped; only application file mappings (language runtime, data) are
    /// dropped per deflation step #4.
    fn release_file_pages(&mut self, keep_runtime: bool) -> Result<u64> {
        let mut released = 0u64;
        for p in 0..self.procs.len() {
            let vmas: Vec<(u64, u64, bool, Option<(crate::mem::mmap_file::FileId, u64)>)> = self
                .procs[p]
                .asp
                .iter_vmas()
                .filter_map(|v| match v.kind {
                    VmaKind::File { file, offset, shared } => {
                        Some((v.start, v.pages(), shared, Some((file, offset / PAGE_SIZE as u64))))
                    }
                    VmaKind::Anon => None,
                })
                .collect();
            for (start, pages, shared, file_info) in vmas {
                let (file_id, first_page) = file_info.unwrap();
                if keep_runtime
                    && self.svc.registry.get(file_id).class == FileClass::QuarkRuntime
                {
                    continue;
                }
                for i in 0..pages {
                    let gva = Gva(start + i * PAGE_SIZE as u64);
                    let pte = self.procs[p].asp.pt.get(gva);
                    if !pte.present() {
                        continue;
                    }
                    self.procs[p].asp.pt.unmap(gva);
                    if shared {
                        self.svc.cache.unmap_shared(file_id, first_page + i);
                    } else {
                        self.alloc.dec_ref(pte.gpa());
                    }
                    released += 1;
                }
            }
        }
        Ok(released)
    }

    /// SIGCONT → anticipatory wake (Fig. 3 ⑤): inflate ahead of the
    /// predicted request so it sees WokenUp (Warm-like) latency.
    ///
    /// Composed of [`Self::wake_begin`] (the cheap state flip) and
    /// [`Self::wake_finish`] (the REAP batch prefetch) — the mirror of the
    /// hibernate split. The platform's policy tick performs the flip under
    /// its shard lock and hands the prefetch to a pipeline worker so the
    /// I/O never stalls the control loop; direct callers get both in one
    /// call.
    pub fn wake(&mut self, clock: &Clock) -> Result<u64> {
        self.wake_begin(clock)?;
        self.wake_finish(clock)
    }

    /// Inflation step #1 only: SIGCONT semantics — unpark the runtime host
    /// threads and enter WokenUp. Cheap (no I/O); after it returns the
    /// router ranks the instance Warm-like, while the caller's reservation
    /// keeps requests off it until [`Self::wake_finish`] completes.
    pub fn wake_begin(&mut self, clock: &Clock) -> Result<()> {
        self.state = self.state.transition(Event::SigCont)?;
        clock.charge(self.svc.cost.thread_wake_ns);
        self.paused = false;
        self.trace(EventKind::WakeBegin, 0, clock);
        Ok(())
    }

    /// Inflation step #2: the REAP batch `preadv` (§3.4.2). The expensive
    /// half — run it off the control-plane path, holding only this
    /// sandbox's mutex. Requires [`Self::wake_begin`] to have run. Returns
    /// pages prefetched (0 when no REAP image exists).
    pub fn wake_finish(&mut self, clock: &Clock) -> Result<u64> {
        if self.state != ContainerState::WokenUp || self.paused {
            bail!("wake_finish without wake_begin (state {})", self.state);
        }
        let (pages, used_reap) = if self.swap.has_reap_image() {
            (self.swap.reap_swap_in(&self.svc.host, clock)?, true)
        } else {
            (0, false)
        };
        let flag = if used_reap { ARG_FLAG } else { 0 };
        self.trace(EventKind::WakeFinish, (pages * PAGE_SIZE as u64) | flag, clock);
        Ok(pages)
    }

    /// Evict: tear down guest memory, return every page, delete swap files
    /// (via SwapFileSet::drop when the sandbox is dropped).
    pub fn terminate(&mut self) -> Result<()> {
        self.state = self.state.transition(Event::Evict)?;
        self.release_file_pages(false)?;
        self.svc.cache.trim_unmapped();
        // Release the QKernel heap.
        let kernel: Vec<Gpa> = (0..self.kernel_pages)
            .map(|i| Gpa(self.kernel_chunk.0 + i * PAGE_SIZE as u64))
            .collect();
        self.svc.host.discard_pages(&kernel)?;
        self.svc
            .heap
            .free(self.kernel_chunk)
            .map_err(|e| anyhow::anyhow!("freeing kernel heap: {e}"))?;
        for p in &mut self.procs {
            let mut anon: Vec<Gpa> = Vec::new();
            p.asp.pt.for_each(|_gva, pte| {
                if (pte.present() || pte.swapped()) && !pte.is_file() {
                    anon.push(pte.gpa());
                }
            });
            p.asp.pt.for_each_mut(|_gva, _pte| Pte::EMPTY);
            for gpa in anon {
                self.alloc.dec_ref(gpa);
            }
        }
        self.alloc.reclaim_free_pages()?;
        if let Some(env) = self.env.take() {
            env.release()?;
        }
        Ok(())
    }

    /// Drain pending control signals at a safe point (the container is
    /// idle): SIGSTOP deflates, SIGCONT anticipatorily inflates. Illegal
    /// edges (e.g. Cont while Warm) are dropped, like real signals whose
    /// handler finds nothing to do. Returns signals acted upon.
    pub fn drain_signals(&mut self, clock: &Clock) -> Result<u32> {
        let mut acted = 0;
        while let Some(sig) = self.signals.take() {
            match (sig, self.state) {
                (ControlSignal::Stop, ContainerState::Warm | ContainerState::WokenUp) => {
                    self.hibernate(clock)?;
                    acted += 1;
                }
                (ControlSignal::Cont, ContainerState::Hibernate) => {
                    self.wake(clock)?;
                    acted += 1;
                }
                _ => {}
            }
        }
        Ok(acted)
    }

    /// Like [`Self::drain_signals`], but both directions perform only the
    /// cheap state flip ([`Self::hibernate_begin`] / [`Self::wake_begin`]);
    /// the expensive I/O is left for the caller to run — or hand to a
    /// pipeline worker — via [`Self::hibernate_finish`] /
    /// [`Self::wake_finish`]. Returns which finish (if any) is now owed.
    /// This is the platform's off-tick path: the flips happen inside the
    /// policy tick, the I/O does not.
    ///
    /// Opposite signals in one drain cancel each other's pending I/O: a
    /// Cont landing on a Stop whose deflation never ran needs no inflation
    /// (the memory never left), and a Stop landing on a Cont whose
    /// prefetch never ran needs no deflation (the memory never came back).
    pub fn drain_signals_deferred(&mut self, clock: &Clock) -> Result<Option<PendingIo>> {
        let mut pending = None;
        while let Some(sig) = self.signals.take() {
            match (sig, self.state) {
                (ControlSignal::Stop, ContainerState::Warm | ContainerState::WokenUp) => {
                    self.hibernate_begin()?;
                    self.trace(EventKind::HibernateBegin, 0, clock);
                    pending = match pending {
                        Some(PendingIo::Inflate) => None,
                        _ => Some(PendingIo::Deflate),
                    };
                }
                (ControlSignal::Cont, ContainerState::Hibernate) => {
                    self.wake_begin(clock)?;
                    pending = match pending {
                        Some(PendingIo::Deflate) => None,
                        _ => Some(PendingIo::Inflate),
                    };
                }
                _ => {}
            }
        }
        Ok(pending)
    }

    /// Host-object view (None after termination).
    pub fn host_env(&self) -> Option<&HostEnv> {
        self.env.as_ref()
    }

    /// PSS of this sandbox (the Fig. 7 metric): guest mappings plus the
    /// QKernel resident heap and allocator metadata (control pages) — the
    /// runtime-process memory pmap would attribute to the sandbox.
    pub fn footprint(&self) -> PssBreakdown {
        let tables: Vec<&PageTable> = self.procs.iter().map(|p| &p.asp.pt).collect();
        let mut b = pss(&tables, &self.svc.host, &self.alloc, &self.svc.cache);
        b.anon_bytes += self.kernel_pages * PAGE_SIZE as u64 + self.alloc.metadata_bytes();
        b
    }

    /// The live-byte charge budget accounting uses for this sandbox: the
    /// resident footprint while runnable, the live swapped-slot image
    /// bytes while hibernated (the §3.1 point — a deflated container
    /// costs its swap image, not memory), nothing once dead. The swap and
    /// REAP files both hold a live image after a REAP-path hibernate; the
    /// larger one is the deflated set.
    pub fn live_bytes(&self) -> u64 {
        match self.state {
            ContainerState::Hibernate => self
                .swap
                .swapped_bytes()
                .max(self.swap.reap_live_pages() * PAGE_SIZE as u64),
            ContainerState::Dead => 0,
            _ => self.footprint().total_bytes(),
        }
    }

    /// O(1) estimate of the live-byte charge this sandbox will hold once
    /// a just-begun wake's REAP prefetch lands: the deflated image plus
    /// the recorded working set the prefetch will commit. Budget
    /// accounting charges an inflating instance at this estimate until
    /// the finish stores the real footprint — deliberately a slight
    /// over-count (image pages in the working set appear twice) so
    /// in-flight inflations can never read as budget headroom.
    pub fn wake_estimate_bytes(&self) -> u64 {
        self.swap.swapped_bytes() + self.swap.reap_live_pages() * PAGE_SIZE as u64
    }

    /// Allocator occupancy (debug/metrics).
    pub fn alloc_stats(&self) -> crate::mem::bitmap_alloc::AllocStats {
        self.alloc.stats()
    }

    pub fn is_paused(&self) -> bool {
        self.paused
    }
}

impl std::fmt::Debug for Sandbox {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Sandbox")
            .field("id", &self.id)
            .field("workload", &self.spec.name)
            .field("state", &self.state)
            .field("requests", &self.requests_served)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::NoopRunner;
    use crate::mem::mmap_file::FileClass;
    use crate::workloads::functionbench::{nodejs_hello, scaled_for_test};

    fn rig(tag: &str) -> Arc<SandboxServices> {
        SandboxServices::new_local(
            512 << 20,
            CostModel::free(),
            SharingConfig::default(),
            Arc::new(NoopRunner),
            tag,
        )
        .unwrap()
    }

    /// Present PTEs of process `p` in `[start, start + pages)`.
    fn present_in(sb: &Sandbox, p: usize, start: Gva, pages: u64) -> u64 {
        (0..pages)
            .filter(|i| {
                sb.procs[p]
                    .asp
                    .pt
                    .get(Gva(start.0 + i * PAGE_SIZE as u64))
                    .present()
            })
            .count() as u64
    }

    #[test]
    fn deflation_spares_runtime_pages_and_releases_app_files() {
        // Deflation step #4 through the full hibernate path: the Quark
        // runtime binary's pages must survive (its parked threads make the
        // demand wake fast), every app file mapping must go.
        let svc = rig("sb-keep-runtime");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(1, scaled_for_test(nodejs_hello(), 8), svc.clone(), &clock)
                .unwrap();
        sb.handle_request(&clock).unwrap();
        let quark_before = present_in(&sb, 0, sb.quark_base, sb.quark_pages);
        let bin_before =
            present_in(&sb, 0, sb.layout.binary_base, sb.layout.binary_pages);
        assert!(quark_before > 0 && bin_before > 0, "init must touch both");
        let rpt = sb.hibernate(&clock).unwrap();
        assert!(rpt.file_pages_released >= bin_before);
        assert_eq!(
            present_in(&sb, 0, sb.quark_base, sb.quark_pages),
            quark_before,
            "QuarkRuntime-class pages must survive deflation"
        );
        assert_eq!(
            present_in(&sb, 0, sb.layout.binary_base, sb.layout.binary_pages),
            0,
            "language-runtime pages must be dropped"
        );
        // Terminate drops the runtime mapping too (keep_runtime = false).
        sb.terminate().unwrap();
        assert_eq!(present_in(&sb, 0, sb.quark_base, sb.quark_pages), 0);
    }

    #[test]
    fn release_drops_shared_cache_mappings_and_private_copies() {
        // Both flavors of file memory in one sandbox: a *shared* mmap'd
        // data file mapped by TWO guest processes (one cache page, two
        // mappers) and a *private* per-sandbox copy. release_file_pages
        // must unmap both processes' PTEs, drop the cache mapcounts to 0,
        // and return the private copy to the sandbox allocator — while
        // keep_runtime spares the Quark binary.
        let svc = rig("sb-shared-file");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(2, scaled_for_test(nodejs_hello(), 16), svc.clone(), &clock)
                .unwrap();
        let pages = 4u64;
        let len = pages * PAGE_SIZE as u64;
        let shared_id = svc.registry.get_or_register(
            "shared-data.bin",
            len,
            FileClass::AppData,
        );
        let private_id = svc.registry.get_or_register(
            "private-data.bin",
            len,
            FileClass::AppData,
        );
        // Second guest process sharing the same mmap'd file.
        sb.procs.push(GuestProcess::new());
        let g0 = sb.procs[0]
            .asp
            .mmap_file(shared_id, 0, len, true, "shared-data.bin")
            .unwrap();
        let g1 = sb.procs[1]
            .asp
            .mmap_file(shared_id, 0, len, true, "shared-data.bin")
            .unwrap();
        let gp = sb.procs[0]
            .asp
            .mmap_file(private_id, 0, len, false, "private-data.bin")
            .unwrap();
        let mut miss = 0u64;
        for i in 0..pages {
            let off = i * PAGE_SIZE as u64;
            sb.fault_file(0, Gva(g0.0 + off), &clock, &mut miss).unwrap();
            sb.fault_file(1, Gva(g1.0 + off), &clock, &mut miss).unwrap();
            sb.fault_file(0, Gva(gp.0 + off), &clock, &mut miss).unwrap();
        }
        assert_eq!(
            svc.cache.mapcount(shared_id, 0),
            2,
            "one cache page, two guest processes mapping it"
        );
        let private_gpa = sb.procs[0].asp.pt.get(gp).gpa();
        assert!(sb.alloc.refcount(private_gpa) > 0);
        let quark_before = present_in(&sb, 0, sb.quark_base, sb.quark_pages);

        let released = sb.release_file_pages(true).unwrap();
        // 2 procs × shared + 1 private, plus the language binary's pages.
        assert!(released >= 3 * pages, "released only {released}");
        for i in 0..pages {
            assert_eq!(svc.cache.mapcount(shared_id, i), 0, "page {i} still mapped");
            let off = i * PAGE_SIZE as u64;
            assert!(sb.procs[0].asp.pt.get(Gva(g0.0 + off)).is_empty());
            assert!(sb.procs[1].asp.pt.get(Gva(g1.0 + off)).is_empty());
            assert!(sb.procs[0].asp.pt.get(Gva(gp.0 + off)).is_empty());
        }
        assert_eq!(
            sb.alloc.refcount(private_gpa),
            0,
            "private copy must be returned to the sandbox allocator"
        );
        assert_eq!(
            present_in(&sb, 0, sb.quark_base, sb.quark_pages),
            quark_before,
            "keep_runtime must spare the Quark binary mapping"
        );
        // The unmapped cache pages are reclaimable now.
        assert!(svc.cache.trim_unmapped() >= pages);
        sb.terminate().unwrap();
    }

    #[test]
    fn hibernate_finish_requires_begin() {
        let svc = rig("sb-split");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(3, scaled_for_test(nodejs_hello(), 16), svc, &clock).unwrap();
        assert!(
            sb.hibernate_finish(&clock).is_err(),
            "finish without begin must be rejected"
        );
        sb.hibernate_begin().unwrap();
        assert_eq!(sb.state(), ContainerState::Hibernate);
        assert!(sb.is_paused());
        let rpt = sb.hibernate_finish(&clock).unwrap();
        assert!(rpt.pages_swapped_out > 0);
        // Begin+finish ≡ the one-shot path: a demand wake still works.
        let out = sb.handle_request(&clock).unwrap();
        assert_eq!(out.from, ContainerState::Hibernate);
        assert!(out.anon_faults > 0);
    }

    #[test]
    fn wake_finish_requires_begin_and_split_equals_one_shot() {
        let svc = rig("sb-wake-split");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(4, scaled_for_test(nodejs_hello(), 16), svc, &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        assert!(
            sb.wake_finish(&clock).is_err(),
            "finish without begin must be rejected"
        );
        // Build a REAP image: full hibernate → sample request → REAP
        // hibernate.
        sb.hibernate(&clock).unwrap();
        sb.handle_request(&clock).unwrap();
        let rpt = sb.hibernate(&clock).unwrap();
        assert!(rpt.used_reap);
        // Split wake: begin flips to WokenUp with nothing inflated yet;
        // finish prefetches the recorded working set.
        sb.wake_begin(&clock).unwrap();
        assert_eq!(sb.state(), ContainerState::WokenUp);
        assert!(!sb.is_paused());
        let prefetched = sb.wake_finish(&clock).unwrap();
        assert!(prefetched > 0, "REAP prefetch must run in the finish");
        // Begin+finish ≡ the one-shot path: the request is Warm-like.
        let out = sb.handle_request(&clock).unwrap();
        assert_eq!(out.from, ContainerState::WokenUp);
        assert_eq!(out.anon_faults, 0, "working set fully prefetched");
        assert_eq!(out.reap_prefetched, 0, "prefetch already done");
    }

    #[test]
    fn steady_state_reap_hibernate_writes_zero_pages() {
        // The sandbox-level view of the delta-REAP contract: hibernate →
        // anticipatory wake (no request) → hibernate writes 0 page images.
        let svc = rig("sb-reap-steady");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(5, scaled_for_test(nodejs_hello(), 16), svc, &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        sb.hibernate(&clock).unwrap();
        sb.handle_request(&clock).unwrap(); // sample request records the WS
        let first = sb.hibernate(&clock).unwrap();
        assert!(first.used_reap);
        assert!(first.pages_swapped_out > 0, "first REAP cycle writes the WS");
        sb.wake(&clock).unwrap();
        let second = sb.hibernate(&clock).unwrap();
        assert!(second.used_reap);
        assert_eq!(
            second.pages_swapped_out, 0,
            "untouched wake → REAP hibernate must write nothing"
        );
        // The image is still complete: a demand wake serves correctly.
        let out = sb.handle_request(&clock).unwrap();
        assert!(out.reap_prefetched > 0);
        assert_eq!(out.anon_faults, 0);
    }

    #[test]
    fn deferred_drain_reports_pending_io_and_cancels_pairs() {
        use crate::container::signal::ControlSignal;
        let svc = rig("sb-deferred");
        let clock = Clock::new();
        let mut sb =
            Sandbox::cold_start(6, scaled_for_test(nodejs_hello(), 16), svc, &clock).unwrap();
        sb.handle_request(&clock).unwrap();
        // Stop → a deflation is owed.
        sb.signals.send(ControlSignal::Stop);
        assert_eq!(
            sb.drain_signals_deferred(&clock).unwrap(),
            Some(PendingIo::Deflate)
        );
        sb.hibernate_finish(&clock).unwrap();
        // Cont → an inflation is owed.
        sb.signals.send(ControlSignal::Cont);
        assert_eq!(
            sb.drain_signals_deferred(&clock).unwrap(),
            Some(PendingIo::Inflate)
        );
        sb.wake_finish(&clock).unwrap();
        // Stop immediately followed by Cont: the deflation never ran, so
        // nothing is owed — the memory never left.
        sb.signals.send(ControlSignal::Stop);
        sb.signals.send(ControlSignal::Cont);
        assert_eq!(sb.drain_signals_deferred(&clock).unwrap(), None);
        assert_eq!(sb.state(), ContainerState::WokenUp);
        let out = sb.handle_request(&clock).unwrap();
        assert_eq!(out.from, ContainerState::WokenUp);
    }
}
