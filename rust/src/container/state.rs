//! The container state machine (Fig. 3).
//!
//! Conventional states: `Warm`, `Running`. The paper's three new states:
//! `Hibernate` (deflated), `HibernateRunning` (processing while inflating),
//! `WokenUp` (inflated-on-demand, cheaper than Warm). The nine numbered
//! transitions of Fig. 3 are the only legal ones; anything else is a bug
//! and [`ContainerState::transition`] rejects it.

use std::fmt;

/// Container lifecycle states. `Dead` models eviction/termination (the exit
/// arc of the figure).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ContainerState {
    /// Being cold-started (runtime + app init in progress).
    ColdStarting,
    /// Fully initialized, idle, full memory footprint.
    Warm,
    /// Processing a request from Warm.
    Running,
    /// Deflated: paused, memory swapped/reclaimed (the paper's mode).
    Hibernate,
    /// Processing a request while inflating from Hibernate/WokenUp.
    HibernateRunning,
    /// Finished a post-hibernate request (or anticipatorily woken):
    /// Warm-like latency, smaller footprint.
    WokenUp,
    /// Evicted / terminated.
    Dead,
}

impl fmt::Display for ContainerState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ContainerState::ColdStarting => "cold-starting",
            ContainerState::Warm => "warm",
            ContainerState::Running => "running",
            ContainerState::Hibernate => "hibernate",
            ContainerState::HibernateRunning => "hibernate-running",
            ContainerState::WokenUp => "woken-up",
            ContainerState::Dead => "dead",
        };
        f.write_str(s)
    }
}

/// The events that drive transitions (Fig. 3's arrows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// ① cold start completed.
    ColdStartDone,
    /// ②⑥⑦ a user request arrives.
    Request,
    /// ③⑧ request processing finished.
    RequestDone,
    /// ④⑨ SIGSTOP from the platform: deflate.
    SigStop,
    /// ⑤ SIGCONT from the platform: anticipatory wake.
    SigCont,
    /// Eviction.
    Evict,
}

/// Error for an illegal transition.
#[derive(Debug, Clone, thiserror::Error, PartialEq, Eq)]
#[error("illegal transition: {from} on {event:?}")]
pub struct IllegalTransition {
    pub from: ContainerState,
    pub event: Event,
}

impl ContainerState {
    /// Apply an event per Fig. 3. Returns the next state or an error.
    pub fn transition(self, event: Event) -> Result<ContainerState, IllegalTransition> {
        use ContainerState::*;
        use Event::*;
        let next = match (self, event) {
            // ① cold start spawns a Warm container.
            (ColdStarting, ColdStartDone) => Warm,
            // ② Warm + request → Running; ③ done → Warm.
            (Warm, Request) => Running,
            (Running, RequestDone) => Warm,
            // ④ Warm --SIGSTOP--> Hibernate.
            (Warm, SigStop) => Hibernate,
            // ⑤ Hibernate --SIGCONT--> WokenUp (anticipatory).
            (Hibernate, SigCont) => WokenUp,
            // ⑥ WokenUp + request → HibernateRunning.
            (WokenUp, Request) => HibernateRunning,
            // ⑦ Hibernate + request → HibernateRunning (demand wake).
            (Hibernate, Request) => HibernateRunning,
            // ⑧ HibernateRunning done → WokenUp.
            (HibernateRunning, RequestDone) => WokenUp,
            // ⑨ WokenUp --SIGSTOP--> Hibernate.
            (WokenUp, SigStop) => Hibernate,
            // Eviction is legal from any idle state.
            (Warm | Hibernate | WokenUp, Evict) => Dead,
            _ => return Err(IllegalTransition { from: self, event }),
        };
        Ok(next)
    }

    /// Can this container accept a request right now?
    pub fn accepts_requests(self) -> bool {
        matches!(
            self,
            ContainerState::Warm | ContainerState::Hibernate | ContainerState::WokenUp
        )
    }

    /// Is the container currently processing?
    pub fn is_busy(self) -> bool {
        matches!(
            self,
            ContainerState::Running | ContainerState::HibernateRunning | ContainerState::ColdStarting
        )
    }

    /// Is this one of the paper's deflated/derived states?
    pub fn is_hibernate_family(self) -> bool {
        matches!(
            self,
            ContainerState::Hibernate | ContainerState::HibernateRunning | ContainerState::WokenUp
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ContainerState::*;
    use Event::*;

    /// The exact legal-transition set of Fig. 3 (plus eviction arcs):
    /// anything not listed must be rejected. This test *is* Fig. 3.
    #[test]
    fn figure3_transition_table_exact() {
        let legal = [
            (ColdStarting, ColdStartDone, Warm),
            (Warm, Request, Running),            // ②
            (Running, RequestDone, Warm),        // ③
            (Warm, SigStop, Hibernate),          // ④
            (Hibernate, SigCont, WokenUp),       // ⑤
            (WokenUp, Request, HibernateRunning), // ⑥
            (Hibernate, Request, HibernateRunning), // ⑦
            (HibernateRunning, RequestDone, WokenUp), // ⑧
            (WokenUp, SigStop, Hibernate),       // ⑨
            (Warm, Evict, Dead),
            (Hibernate, Evict, Dead),
            (WokenUp, Evict, Dead),
        ];
        let states = [
            ColdStarting,
            Warm,
            Running,
            Hibernate,
            HibernateRunning,
            WokenUp,
            Dead,
        ];
        let events = [ColdStartDone, Request, RequestDone, SigStop, SigCont, Evict];
        for &s in &states {
            for &e in &events {
                let expected = legal
                    .iter()
                    .find(|&&(fs, fe, _)| fs == s && fe == e)
                    .map(|&(_, _, to)| to);
                match (s.transition(e), expected) {
                    (Ok(got), Some(want)) => assert_eq!(got, want, "{s} on {e:?}"),
                    (Err(_), None) => {}
                    (Ok(got), None) => panic!("{s} on {e:?} illegally allowed → {got}"),
                    (Err(err), Some(want)) => {
                        panic!("{s} on {e:?} should go to {want}, got {err}")
                    }
                }
            }
        }
    }

    #[test]
    fn request_cycle_through_hibernate() {
        // The canonical life of a Hibernate Container:
        // cold → warm → running → warm → hibernate → hibernate-running →
        // woken-up → hibernate-running → woken-up → hibernate.
        let mut s = ColdStarting;
        for (e, want) in [
            (ColdStartDone, Warm),
            (Request, Running),
            (RequestDone, Warm),
            (SigStop, Hibernate),
            (Request, HibernateRunning),
            (RequestDone, WokenUp),
            (Request, HibernateRunning),
            (RequestDone, WokenUp),
            (SigStop, Hibernate),
            (SigCont, WokenUp),
            (Evict, Dead),
        ] {
            s = s.transition(e).unwrap();
            assert_eq!(s, want);
        }
    }

    #[test]
    fn predicates() {
        assert!(Warm.accepts_requests());
        assert!(Hibernate.accepts_requests());
        assert!(WokenUp.accepts_requests());
        assert!(!Running.accepts_requests());
        assert!(!Dead.accepts_requests());
        assert!(Running.is_busy());
        assert!(HibernateRunning.is_busy());
        assert!(Hibernate.is_hibernate_family());
        assert!(WokenUp.is_hibernate_family());
        assert!(!Warm.is_hibernate_family());
    }

    #[test]
    fn dead_is_terminal() {
        for e in [ColdStartDone, Request, RequestDone, SigStop, SigCont, Evict] {
            assert!(Dead.transition(e).is_err());
        }
    }
}
