//! Control-signal plumbing: the platform drives deflation and anticipatory
//! wake-up with SIGSTOP/SIGCONT (§3.1 "Serverless Platform may initiate
//! deflation of a Warm Container by sending a SIGSTOP signal"; Fig. 3 ④⑤⑨).
//!
//! [`SignalQueue`] models the per-sandbox signal delivery path: signals are
//! queued by the control plane and drained by the runtime at safe points
//! (between requests — a busy container defers the stop until its request
//! finishes, exactly like a real SIGSTOP'd runtime that masks signals in
//! the request critical section).

use std::collections::VecDeque;
use std::sync::Mutex;

/// The two control edges of Fig. 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlSignal {
    /// Deflate (SIGSTOP): Warm/WokenUp → Hibernate.
    Stop,
    /// Anticipatory inflate (SIGCONT): Hibernate → WokenUp.
    Cont,
}

/// Per-sandbox pending-signal queue. Coalesces redundant edges the way the
/// kernel coalesces standard signals: consecutive identical signals merge,
/// and a Stop+Cont pair cancels out (the container would stop and
/// immediately continue — the net effect the platform wants is "stay up").
#[derive(Debug, Default)]
pub struct SignalQueue {
    pending: Mutex<VecDeque<ControlSignal>>,
}

impl SignalQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue a signal (control-plane side).
    pub fn send(&self, sig: ControlSignal) {
        let mut q = self.pending.lock().unwrap();
        match (q.back().copied(), sig) {
            // Coalesce identical consecutive signals.
            (Some(last), s) if last == s => {}
            // Stop followed by Cont cancels (and vice versa).
            (Some(ControlSignal::Stop), ControlSignal::Cont)
            | (Some(ControlSignal::Cont), ControlSignal::Stop) => {
                q.pop_back();
            }
            _ => q.push_back(sig),
        }
    }

    /// Take the next pending signal (runtime side, at a safe point).
    pub fn take(&self) -> Option<ControlSignal> {
        self.pending.lock().unwrap().pop_front()
    }

    pub fn pending(&self) -> usize {
        self.pending.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ControlSignal::*;

    #[test]
    fn fifo_delivery() {
        let q = SignalQueue::new();
        q.send(Stop);
        assert_eq!(q.take(), Some(Stop));
        assert_eq!(q.take(), None);
    }

    #[test]
    fn coalesces_duplicates() {
        let q = SignalQueue::new();
        q.send(Stop);
        q.send(Stop);
        q.send(Stop);
        assert_eq!(q.pending(), 1);
    }

    #[test]
    fn stop_cont_cancels() {
        let q = SignalQueue::new();
        q.send(Stop);
        q.send(Cont);
        assert_eq!(q.pending(), 0, "stop+cont is a no-op pair");
        q.send(Cont);
        q.send(Stop);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn non_adjacent_signals_kept() {
        let q = SignalQueue::new();
        q.send(Stop);
        assert_eq!(q.take(), Some(Stop));
        q.send(Cont);
        q.send(Stop); // cancels the Cont
        q.send(Stop);
        assert_eq!(q.take(), Some(Stop));
        assert_eq!(q.take(), None);
    }
}
