//! Host OS objects backing a sandbox: cgroup, network namespace, rootfs
//! mounts and the runtime's host threads.
//!
//! §1 of the paper: "Hibernate container keeps its host OS objects alive,
//! such as container runtime OS process, Cgroup, container network,
//! container file system, processes. The OS objects consume little system
//! memory but keeping them alive saves much reinitialization cost."
//!
//! This module is that substrate: cold start *creates* these objects
//! (charged setup time — the bulk of the paper's "container runtime
//! startup"), Hibernate *retains* them (that's precisely why a Hibernate
//! wake skips re-running this), and termination releases them. The
//! registries enforce real invariants (unique cgroup paths, IP/veth
//! allocation, mount refcounts on shared lower layers) so leaks and
//! double-frees are detectable in tests.

use crate::simtime::Clock;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

/// Setup cost model for host objects (part of `sandbox_startup_ns` in the
/// aggregate; broken out here so the components are visible in traces).
#[derive(Debug, Clone, Copy)]
pub struct HostEnvCost {
    pub cgroup_ns: u64,
    pub netns_ns: u64,
    pub rootfs_ns: u64,
    pub threads_ns: u64,
}

impl HostEnvCost {
    /// RunD-style measured component split of VM-runtime startup.
    pub fn default_split() -> Self {
        Self {
            cgroup_ns: 3_000_000,
            netns_ns: 7_000_000,
            rootfs_ns: 9_000_000,
            threads_ns: 1_000_000,
        }
    }

    pub fn total_ns(&self) -> u64 {
        self.cgroup_ns + self.netns_ns + self.rootfs_ns + self.threads_ns
    }
}

/// A cgroup: memory limit + usage accounting for one sandbox.
#[derive(Debug)]
pub struct Cgroup {
    pub path: String,
    pub memory_limit: u64,
}

/// A network namespace with a veth pair and an allocated address.
#[derive(Debug)]
pub struct NetNs {
    pub veth_host: String,
    pub veth_guest: String,
    /// 10.88.x.y/16 pod address.
    pub ip: (u8, u8),
}

/// An overlay rootfs: shared read-only lower layers + private upper dir.
#[derive(Debug)]
pub struct RootFs {
    pub lower_layers: Vec<String>,
    pub upper: String,
}

/// The set of host objects owned by one sandbox.
pub struct HostEnv {
    pub cgroup: Cgroup,
    pub netns: NetNs,
    pub rootfs: RootFs,
    /// Parked runtime host threads (blocked in sys_accept/sys_read while
    /// hibernated — they hold no CPU but wake instantly).
    pub runtime_threads: u32,
    registry: Arc<HostEnvRegistry>,
    id: u64,
}

/// Node-wide registry enforcing uniqueness/refcount invariants.
#[derive(Default)]
pub struct HostEnvRegistry {
    inner: Mutex<RegistryInner>,
    next_ip: AtomicU32,
}

#[derive(Default)]
struct RegistryInner {
    cgroup_paths: HashSet<String>,
    veths: HashSet<String>,
    /// Lower-layer image mounts are shared across sandboxes: name → users.
    layer_refs: HashMap<String, u32>,
    live_envs: HashSet<u64>,
}

impl HostEnvRegistry {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Create the full host environment for sandbox `id` (cold-start path).
    /// Charges the component setup costs to `clock`.
    pub fn create(
        self: &Arc<Self>,
        id: u64,
        image_layers: &[&str],
        memory_limit: u64,
        cost: HostEnvCost,
        clock: &Clock,
    ) -> Result<HostEnv> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.live_envs.insert(id) {
            bail!("sandbox {id} already has a host environment");
        }

        // Cgroup.
        let path = format!("/sys/fs/cgroup/quark/sandbox-{id}");
        if !inner.cgroup_paths.insert(path.clone()) {
            bail!("cgroup path collision: {path}");
        }
        clock.charge(cost.cgroup_ns);

        // Network namespace + veth pair + IP.
        let n = self.next_ip.fetch_add(1, Ordering::Relaxed);
        if n >= 0xFFFF {
            bail!("pod address space exhausted");
        }
        let veth_host = format!("veth-h{id}");
        let veth_guest = format!("veth-g{id}");
        if !inner.veths.insert(veth_host.clone()) {
            bail!("veth collision: {veth_host}");
        }
        clock.charge(cost.netns_ns);

        // Rootfs: refcount shared lower layers, private upper.
        for layer in image_layers {
            *inner.layer_refs.entry(layer.to_string()).or_insert(0) += 1;
        }
        clock.charge(cost.rootfs_ns);
        clock.charge(cost.threads_ns);

        Ok(HostEnv {
            cgroup: Cgroup {
                path,
                memory_limit,
            },
            netns: NetNs {
                veth_host,
                veth_guest,
                ip: ((n >> 8) as u8, (n & 0xFF) as u8),
            },
            rootfs: RootFs {
                lower_layers: image_layers.iter().map(|s| s.to_string()).collect(),
                upper: format!("/run/quark/sandbox-{id}/upper"),
            },
            runtime_threads: 2, // io thread + vcpu thread, parked when idle
            registry: self.clone(),
            id,
        })
    }

    /// How many sandboxes currently share an image layer.
    pub fn layer_users(&self, layer: &str) -> u32 {
        self.inner
            .lock()
            .unwrap()
            .layer_refs
            .get(layer)
            .copied()
            .unwrap_or(0)
    }

    pub fn live_count(&self) -> usize {
        self.inner.lock().unwrap().live_envs.len()
    }

    fn release(&self, env: &HostEnv) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.live_envs.remove(&env.id) {
            bail!("double release of host env {}", env.id);
        }
        inner.cgroup_paths.remove(&env.cgroup.path);
        inner.veths.remove(&env.netns.veth_host);
        for layer in &env.rootfs.lower_layers {
            let refs = inner
                .layer_refs
                .get_mut(layer)
                .with_context(|| format!("layer {layer} not mounted"))?;
            *refs -= 1;
            if *refs == 0 {
                inner.layer_refs.remove(layer);
            }
        }
        Ok(())
    }
}

impl HostEnv {
    /// Tear everything down (sandbox termination — NOT hibernation; a
    /// hibernated sandbox keeps all of this alive, which is exactly why its
    /// wake skips the `create` costs).
    pub fn release(self) -> Result<()> {
        self.registry.clone().release(&self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_charges_component_costs() {
        let reg = HostEnvRegistry::new();
        let clock = Clock::new();
        let cost = HostEnvCost::default_split();
        let env = reg
            .create(1, &["base.img", "node.img"], 128 << 20, cost, &clock)
            .unwrap();
        assert_eq!(clock.charged_ns(), cost.total_ns());
        assert_eq!(env.runtime_threads, 2);
        assert_eq!(reg.live_count(), 1);
        env.release().unwrap();
        assert_eq!(reg.live_count(), 0);
    }

    #[test]
    fn ids_must_be_unique() {
        let reg = HostEnvRegistry::new();
        let clock = Clock::new();
        let cost = HostEnvCost::default_split();
        let _a = reg.create(7, &[], 0, cost, &clock).unwrap();
        assert!(reg.create(7, &[], 0, cost, &clock).is_err());
    }

    #[test]
    fn layers_are_refcounted_across_sandboxes() {
        let reg = HostEnvRegistry::new();
        let clock = Clock::new();
        let cost = HostEnvCost::default_split();
        let a = reg.create(1, &["base.img"], 0, cost, &clock).unwrap();
        let b = reg.create(2, &["base.img"], 0, cost, &clock).unwrap();
        assert_eq!(reg.layer_users("base.img"), 2);
        a.release().unwrap();
        assert_eq!(reg.layer_users("base.img"), 1);
        b.release().unwrap();
        assert_eq!(reg.layer_users("base.img"), 0);
    }

    #[test]
    fn unique_ips_and_veths() {
        let reg = HostEnvRegistry::new();
        let clock = Clock::new();
        let cost = HostEnvCost::default_split();
        let mut seen = HashSet::new();
        for i in 0..300 {
            let env = reg.create(i, &[], 0, cost, &clock).unwrap();
            assert!(seen.insert(env.netns.ip), "duplicate IP {:?}", env.netns.ip);
            assert_ne!(env.netns.veth_host, env.netns.veth_guest);
        }
    }

    #[test]
    fn release_is_single_shot() {
        let reg = HostEnvRegistry::new();
        let clock = Clock::new();
        let env = reg
            .create(9, &["x"], 0, HostEnvCost::default_split(), &clock)
            .unwrap();
        // Simulate a double release through a second handle: release consumes
        // the env, so the only way is registry-level — check it errors.
        let fake = HostEnv {
            cgroup: Cgroup {
                path: env.cgroup.path.clone(),
                memory_limit: 0,
            },
            netns: NetNs {
                veth_host: env.netns.veth_host.clone(),
                veth_guest: env.netns.veth_guest.clone(),
                ip: env.netns.ip,
            },
            rootfs: RootFs {
                lower_layers: vec!["x".into()],
                upper: String::new(),
            },
            runtime_threads: 0,
            registry: reg.clone(),
            id: 9,
        };
        env.release().unwrap();
        assert!(fake.release().is_err(), "double release must be detected");
    }
}
