//! The guest application model: processes, address-space layout and the
//! deterministic page-touch phases the workload specs describe.
//!
//! A guest app has one primary process plus optional clones (fork children
//! sharing the init pages copy-on-write — what gives swap-out its dedup
//! work and the refcount array its traffic). Init and request phases touch
//! pages in a *stable* order, which is the empirical property REAP banks on
//! ("functions access the same stable working set of pages across different
//! invocations").

use crate::mem::vma::AddressSpace;
use crate::mem::Gva;
use crate::workloads::WorkloadSpec;
use crate::PAGE_SIZE;
use anyhow::Result;

/// One guest process: an address space (VMAs + page table).
pub struct GuestProcess {
    pub asp: AddressSpace,
}

impl GuestProcess {
    pub fn new() -> Self {
        Self {
            asp: AddressSpace::new(),
        }
    }
}

impl Default for GuestProcess {
    fn default() -> Self {
        Self::new()
    }
}

/// Fixed address-space layout for a workload instance (primary process).
#[derive(Debug, Clone)]
pub struct AppLayout {
    /// Anonymous heap (init pages live here).
    pub heap_base: Gva,
    pub heap_pages: u64,
    /// Per-request scratch arena.
    pub scratch_base: Gva,
    pub scratch_pages: u64,
    /// Language runtime binary mapping.
    pub binary_base: Gva,
    pub binary_pages: u64,
}

impl AppLayout {
    /// Reserve the three regions in a fresh address space.
    pub fn install(spec: &WorkloadSpec, asp: &mut AddressSpace, binary_file: crate::mem::mmap_file::FileId, shared: bool) -> Result<Self> {
        let heap_pages = spec.init_anon_pages;
        let scratch_pages = spec.request_scratch_pages.max(1);
        let binary_pages = spec.binary_pages();
        let heap_base = asp.mmap_anon(heap_pages * PAGE_SIZE as u64, "heap")?;
        let scratch_base = asp.mmap_anon(scratch_pages * PAGE_SIZE as u64, "scratch")?;
        let binary_base = asp.mmap_file(
            binary_file,
            0,
            binary_pages * PAGE_SIZE as u64,
            shared,
            spec.lang.binary_name(),
        )?;
        Ok(Self {
            heap_base,
            heap_pages,
            scratch_base,
            scratch_pages,
            binary_base,
            binary_pages,
        })
    }

    pub fn heap_page(&self, i: u64) -> Gva {
        debug_assert!(i < self.heap_pages);
        Gva(self.heap_base.0 + i * PAGE_SIZE as u64)
    }

    pub fn scratch_page(&self, i: u64) -> Gva {
        debug_assert!(i < self.scratch_pages);
        Gva(self.scratch_base.0 + i * PAGE_SIZE as u64)
    }

    pub fn binary_page(&self, i: u64) -> Gva {
        debug_assert!(i < self.binary_pages);
        Gva(self.binary_base.0 + i * PAGE_SIZE as u64)
    }

    /// The stable anon working set of a request: the first
    /// `spec.request_ws_pages()` heap pages. Deterministic by construction.
    pub fn request_anon_ws(&self, spec: &WorkloadSpec) -> impl Iterator<Item = Gva> + '_ {
        let n = spec.request_ws_pages().min(self.heap_pages);
        (0..n).map(move |i| self.heap_page(i))
    }

    /// The binary (code) working set of a request.
    pub fn request_binary_ws(&self, spec: &WorkloadSpec) -> impl Iterator<Item = Gva> + '_ {
        let n = spec.binary_request_pages().min(self.binary_pages);
        (0..n).map(move |i| self.binary_page(i))
    }
}

/// Deterministic content seed for an anon page of a sandbox — lets tests
/// verify that page contents survive hibernate round trips.
pub fn anon_content_seed(sandbox_id: u64, gva: Gva) -> u64 {
    sandbox_id
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(gva.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::mmap_file::FileId;
    use crate::workloads::{Lang, WorkloadSpec};

    fn spec() -> WorkloadSpec {
        WorkloadSpec {
            name: "t".into(),
            lang: Lang::NodeJs,
            binary_bytes: 20 * PAGE_SIZE as u64,
            binary_init_frac: 0.5,
            binary_request_frac: 0.25,
            init_ns: 0,
            init_anon_pages: 64,
            request_ws_frac: 0.5,
            request_scratch_pages: 8,
            request_extra_ns: 0,
            payload: None,
            processes: 1,
        }
    }

    #[test]
    fn layout_reserves_disjoint_regions() {
        let s = spec();
        let mut p = GuestProcess::new();
        let l = AppLayout::install(&s, &mut p.asp, FileId(0), true).unwrap();
        assert_eq!(l.heap_pages, 64);
        assert_eq!(l.scratch_pages, 8);
        assert_eq!(l.binary_pages, 20);
        assert_eq!(p.asp.vma_count(), 3);
        // Regions don't overlap.
        let heap_end = l.heap_base.0 + 64 * 4096;
        assert!(l.scratch_base.0 >= heap_end);
    }

    #[test]
    fn working_sets_are_stable_prefixes() {
        let s = spec();
        let mut p = GuestProcess::new();
        let l = AppLayout::install(&s, &mut p.asp, FileId(0), true).unwrap();
        let ws1: Vec<Gva> = l.request_anon_ws(&s).collect();
        let ws2: Vec<Gva> = l.request_anon_ws(&s).collect();
        assert_eq!(ws1, ws2, "REAP's stable-working-set assumption");
        assert_eq!(ws1.len(), 32);
        assert_eq!(ws1[0], l.heap_page(0));
        let bws: Vec<Gva> = l.request_binary_ws(&s).collect();
        assert_eq!(bws.len(), 5);
    }

    #[test]
    fn content_seed_distinguishes_sandboxes_and_pages() {
        let g = Gva(0x1000);
        assert_ne!(anon_content_seed(1, g), anon_content_seed(2, g));
        assert_ne!(anon_content_seed(1, g), anon_content_seed(1, Gva(0x2000)));
    }
}
