//! The container layer: Fig. 3's state machine, the guest application
//! model, and the Hibernate deflate/inflate orchestration (§3.1–§3.2).
//!
//! * [`state`] — the six-state machine (Cold → Warm → Running plus the
//!   paper's Hibernate / HibernateRunning / WokenUp) with the nine numbered
//!   transitions of Fig. 3, enforced at runtime.
//! * [`app`] — the guest application: processes, address-space layout,
//!   deterministic page contents, init/request touch phases.
//! * [`sandbox`] — a Quark sandbox binding everything together: per-sandbox
//!   Bitmap Page Allocator, page tables, swap manager, REAP recorder,
//!   file-backed mappings with the §3.5 sharing policy, and the 4-step
//!   deflation / 2-trigger inflation.

pub mod app;
pub mod hostenv;
pub mod sandbox;
pub mod signal;
pub mod state;

use crate::simtime::Clock;
use crate::workloads::PayloadSpec;

/// Executes a request's real compute. The PJRT runtime implements this for
/// AOT artifacts; tests use [`SpinRunner`] / [`NoopRunner`].
pub trait PayloadRunner: Send + Sync {
    fn run(&self, payload: &PayloadSpec, clock: &Clock) -> anyhow::Result<()>;
}

/// No compute (pure memory workloads / unit tests).
pub struct NoopRunner;

impl PayloadRunner for NoopRunner {
    fn run(&self, _payload: &PayloadSpec, _clock: &Clock) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Busy-spins for a fixed real duration per iteration — a deterministic
/// compute stand-in for tests and calibration runs without artifacts.
pub struct SpinRunner {
    pub ns_per_iteration: u64,
}

impl PayloadRunner for SpinRunner {
    fn run(&self, payload: &PayloadSpec, clock: &Clock) -> anyhow::Result<()> {
        let total = self.ns_per_iteration * payload.iterations as u64;
        clock.time(|| {
            // lint:allow(wall-clock): real busy-spin inside the measured domain
            let t0 = std::time::Instant::now();
            while (t0.elapsed().as_nanos() as u64) < total {
                std::hint::spin_loop();
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spin_runner_spins_and_records() {
        let clock = Clock::new();
        let r = SpinRunner {
            ns_per_iteration: 100_000,
        };
        r.run(
            &PayloadSpec {
                artifact: "x".into(),
                iterations: 3,
            },
            &clock,
        )
        .unwrap();
        assert!(clock.measured_ns() >= 300_000);
    }

    #[test]
    fn noop_runner_is_free() {
        let clock = Clock::new();
        NoopRunner
            .run(
                &PayloadSpec {
                    artifact: "x".into(),
                    iterations: 1,
                },
                &clock,
            )
            .unwrap();
        assert_eq!(clock.total_ns(), 0);
    }
}
