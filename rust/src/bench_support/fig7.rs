//! **Figure 7** — memory consumption (PSS) of different container states,
//! measured with 10 running instances per workload exactly as §4.2 does
//! ("we collect the PSS data with 10 running benchmark application
//! instances", sharing the Quark runtime binary).
//!
//! Paper shape to hold: `hibernate ≪ woken-up < warm`; hibernate at
//! 7–25 % of warm; woken-up at 28–90 % of warm.

use super::{best_runner, maybe_scale, mib, pct, rig, row};
use crate::config::SharingConfig;
use crate::container::sandbox::Sandbox;
use crate::simtime::Clock;
use crate::workloads::functionbench::all_workloads;
use crate::workloads::WorkloadSpec;

/// PSS readings (bytes, mean over instances).
#[derive(Debug, Clone, Copy)]
pub struct Fig7Row {
    pub warm: u64,
    pub hibernate: u64,
    pub wokenup: u64,
}

/// Measure mean PSS for `instances` sandboxes in each of the three states.
pub fn measure(spec: &WorkloadSpec, instances: usize, host_bytes: usize) -> Fig7Row {
    measure_with(spec, instances, host_bytes, best_runner())
}

/// Measure with an explicit runner (tests use NoopRunner for speed — PSS
/// does not depend on payload compute).
pub fn measure_with(
    spec: &WorkloadSpec,
    instances: usize,
    host_bytes: usize,
    runner: std::sync::Arc<dyn crate::container::PayloadRunner>,
) -> Fig7Row {
    let svc = rig(
        host_bytes,
        SharingConfig::default(),
        true,
        runner,
        &format!("fig7-{}", spec.name),
    );
    let clock = Clock::new();
    let mut sbs: Vec<Sandbox> = (0..instances)
        .map(|i| {
            let mut sb =
                Sandbox::cold_start(i as u64 + 1, spec.clone(), svc.clone(), &clock).unwrap();
            // "The container processes a few user requests."
            for _ in 0..3 {
                sb.handle_request(&clock).unwrap();
            }
            sb
        })
        .collect();

    let mean_pss = |sbs: &[Sandbox]| -> u64 {
        let total: u64 = sbs.iter().map(|s| s.footprint().total_bytes()).sum();
        total / sbs.len() as u64
    };

    let warm = mean_pss(&sbs);
    for sb in &mut sbs {
        sb.hibernate(&clock).unwrap();
    }
    let hibernate = mean_pss(&sbs);
    for sb in &mut sbs {
        sb.handle_request(&clock).unwrap(); // demand wake → WokenUp
    }
    let wokenup = mean_pss(&sbs);

    Fig7Row {
        warm,
        hibernate,
        wokenup,
    }
}

/// Print the figure; returns rows for assertions.
pub fn run(quick: bool) -> Vec<(String, Fig7Row)> {
    println!("== Figure 7: PSS by container state (10 instances) ==");
    println!(
        "{}",
        row(
            "workload",
            &[
                "warm".into(),
                "hibernate".into(),
                "woken-up".into(),
                "hib/warm".into(),
                "wok/warm".into(),
            ],
        )
    );
    let instances = if quick { 4 } else { 10 };
    let host_bytes = if quick { 1 << 30 } else { 6 << 30 };
    let mut out = Vec::new();
    for spec in all_workloads() {
        let spec = maybe_scale(spec, quick);
        let r = measure(&spec, instances, host_bytes);
        println!(
            "{}",
            row(
                &spec.name,
                &[
                    mib(r.warm),
                    mib(r.hibernate),
                    mib(r.wokenup),
                    pct(r.hibernate, r.warm),
                    pct(r.wokenup, r.warm),
                ],
            )
        );
        out.push((spec.name.clone(), r));
    }
    println!();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::functionbench::{scaled_for_test, video_processing};

    #[test]
    fn memory_ordering_holds() {
        let spec = scaled_for_test(video_processing(), 16);
        let r = measure_with(
            &spec,
            3,
            512 << 20,
            std::sync::Arc::new(crate::container::NoopRunner),
        );
        assert!(
            r.hibernate < r.warm / 3,
            "hibernate {} must be ≪ warm {}",
            r.hibernate,
            r.warm
        );
        assert!(
            r.wokenup < r.warm,
            "wokenup {} < warm {}",
            r.wokenup,
            r.warm
        );
        assert!(
            r.hibernate < r.wokenup,
            "hibernate {} < wokenup {}",
            r.hibernate,
            r.wokenup
        );
    }
}
