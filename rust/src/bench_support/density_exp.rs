//! Deployment-density experiment driver (§1/§4.2's "higher deployment
//! density" claim): pack real sandboxes into a committed-memory budget in
//! each park mode and report instances-per-budget.

use super::{maybe_scale, mib, row};
use crate::config::SharingConfig;
use crate::platform::density::{pack, DensityResult, ParkMode};
use crate::workloads::functionbench::nodejs_hello;

/// Run the packing comparison for the node.js workload (the paper's
/// sharing-ablation subject — density benefits combine deflation and
/// runtime-binary sharing).
pub fn run(budget: u64, quick: bool) -> Vec<DensityResult> {
    println!("== Deployment density: instances within {} ==", mib(budget));
    println!(
        "{}",
        row(
            "park mode",
            &["instances".into(), "committed".into(), "mean PSS".into()],
        )
    );
    let spec = maybe_scale(nodejs_hello(), quick);
    let host_bytes = (budget as usize) * 16;
    let max = if quick { 64 } else { 512 };
    let mut out = Vec::new();
    for mode in [ParkMode::Warm, ParkMode::WokenUp, ParkMode::Hibernate] {
        let r = pack(
            &spec,
            mode,
            budget,
            host_bytes,
            max,
            SharingConfig::default(),
        )
        .unwrap();
        println!(
            "{}",
            row(
                mode.label(),
                &[
                    r.instances.to_string(),
                    mib(r.committed_bytes),
                    mib(r.mean_pss),
                ],
            )
        );
        out.push(r);
    }
    println!();
    out
}
