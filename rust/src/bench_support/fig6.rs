//! **Figure 6** — request-response latency of different container states.
//!
//! For every workload of §4 this measures the end-to-end request latency
//! (virtual clock: charged OS/device model + real PJRT compute) along the
//! five paths of the figure:
//!
//! * `cold`      — container startup + runtime/app init + first request;
//! * `warm`      — request on a fully initialized container;
//! * `hib-fault` — first request on a Hibernate container, page-fault
//!   swap-in (REAP disabled);
//! * `hib-reap`  — first request on a Hibernate container with a REAP
//!   image (record pass done, batch prefetch on wake);
//! * `woken-up`  — request on a WokenUp container.
//!
//! Paper shape to hold: `warm ≈ woken-up < hib-reap ≤ hib-fault ≪ cold`;
//! `hib-reap` at 3–67 % of cold.

use super::{best_runner, maybe_scale, ms, pct, rig, row};
use crate::config::SharingConfig;
use crate::container::sandbox::Sandbox;
use crate::simtime::Clock;
use crate::workloads::functionbench::all_workloads;
use crate::workloads::WorkloadSpec;

/// Latency readings for one workload (ns, virtual).
#[derive(Debug, Clone, Copy)]
pub struct Fig6Row {
    pub cold_ns: u64,
    pub warm_ns: u64,
    pub hib_fault_ns: u64,
    pub hib_reap_ns: u64,
    pub wokenup_ns: u64,
}

fn span(clock: &Clock, f: impl FnOnce()) -> u64 {
    let before = clock.total_ns();
    f();
    clock.total_ns() - before
}

/// Measure all five paths for one workload (PJRT payloads when available).
pub fn measure(spec: &WorkloadSpec, host_bytes: usize) -> Fig6Row {
    measure_with(spec, host_bytes, best_runner())
}

/// Measure with an explicit payload runner (tests pass NoopRunner so the
/// latency ordering is driven by the memory mechanism, not CPU contention).
pub fn measure_with(
    spec: &WorkloadSpec,
    host_bytes: usize,
    runner: std::sync::Arc<dyn crate::container::PayloadRunner>,
) -> Fig6Row {

    // --- Rig A: REAP disabled → cold, warm, hib-fault. ---
    let svc = rig(
        host_bytes,
        SharingConfig::default(),
        false,
        runner.clone(),
        &format!("fig6a-{}", spec.name),
    );
    let clock = Clock::new();
    let mut sb = None;
    let cold_ns = span(&clock, || {
        let mut s = Sandbox::cold_start(1, spec.clone(), svc.clone(), &clock).unwrap();
        s.handle_request(&clock).unwrap();
        sb = Some(s);
    });
    let mut sb = sb.unwrap();
    let warm_ns = span(&clock, || {
        sb.handle_request(&clock).unwrap();
    });
    sb.hibernate(&clock).unwrap();
    let hib_fault_ns = span(&clock, || {
        sb.handle_request(&clock).unwrap();
    });

    // --- Rig B: REAP enabled → hib-reap, woken-up. ---
    let svc = rig(
        host_bytes,
        SharingConfig::default(),
        true,
        runner,
        &format!("fig6b-{}", spec.name),
    );
    let clock = Clock::new();
    let mut sb = Sandbox::cold_start(2, spec.clone(), svc, &clock).unwrap();
    sb.handle_request(&clock).unwrap();
    // First hibernate is the full swap-out; the next request records.
    sb.hibernate(&clock).unwrap();
    sb.handle_request(&clock).unwrap(); // sample request (fault-based)
    // Second hibernate takes the REAP path; its wake is the measurement.
    sb.hibernate(&clock).unwrap();
    let hib_reap_ns = span(&clock, || {
        sb.handle_request(&clock).unwrap();
    });
    // Container is WokenUp now.
    let wokenup_ns = span(&clock, || {
        sb.handle_request(&clock).unwrap();
    });

    Fig6Row {
        cold_ns,
        warm_ns,
        hib_fault_ns,
        hib_reap_ns,
        wokenup_ns,
    }
}

/// Print the figure as a table; returns the rows for assertions.
pub fn run(quick: bool) -> Vec<(String, Fig6Row)> {
    println!("== Figure 6: request-response latency by container state ==");
    println!(
        "{}",
        row(
            "workload",
            &[
                "cold".into(),
                "warm".into(),
                "hib-fault".into(),
                "hib-reap".into(),
                "woken-up".into(),
                "reap/cold".into(),
            ],
        )
    );
    let host_bytes = if quick { 512 << 20 } else { 2 << 30 };
    let mut out = Vec::new();
    for spec in all_workloads() {
        let spec = maybe_scale(spec, quick);
        let r = measure(&spec, host_bytes);
        println!(
            "{}",
            row(
                &spec.name,
                &[
                    ms(r.cold_ns),
                    ms(r.warm_ns),
                    ms(r.hib_fault_ns),
                    ms(r.hib_reap_ns),
                    ms(r.wokenup_ns),
                    pct(r.hib_reap_ns, r.cold_ns),
                ],
            )
        );
        out.push((spec.name.clone(), r));
    }
    println!();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::functionbench::{nodejs_hello, scaled_for_test};

    #[test]
    fn latency_ordering_holds() {
        // The paper's Fig. 6 shape on a scaled workload. NoopRunner keeps
        // the comparison about the memory mechanism (PJRT compute time under
        // parallel-test CPU contention would add noise to every path).
        let spec = scaled_for_test(nodejs_hello(), 16);
        let r = measure_with(&spec, 256 << 20, std::sync::Arc::new(crate::container::NoopRunner));
        assert!(r.warm_ns < r.hib_reap_ns, "warm {} < reap {}", r.warm_ns, r.hib_reap_ns);
        assert!(
            r.hib_reap_ns <= r.hib_fault_ns,
            "reap {} ≤ fault {}",
            r.hib_reap_ns,
            r.hib_fault_ns
        );
        assert!(
            r.hib_fault_ns < r.cold_ns,
            "hibernate {} ≪ cold {}",
            r.hib_fault_ns,
            r.cold_ns
        );
        // WokenUp within 3× of warm (paper: "almost similar").
        assert!(r.wokenup_ns < r.warm_ns * 3 + 1_000_000);
    }
}
