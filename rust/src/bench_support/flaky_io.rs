//! Shared fault-injecting I/O backend for the failure-injection and
//! stress suites (and anything else that wants a misbehaving disk).
//!
//! Wraps the batched backend; injects batch write/read failures, silent
//! corruption, and wall-clock slowness on demand. When a batch of
//! several runs fails, the first run is landed before the error — a
//! genuinely *partial* batch, the worst case the recovery contracts
//! have to absorb.
//!
//! Corruption modes (each proves a different detection path of the
//! durability ladder):
//! * **transient** — the first N writes fail with the [`TransientIo`]
//!   marker (a flaky-but-recoverable device): the swap layer must retry
//!   with backoff and succeed without invalidating anything.
//! * **bit flip** — the write lands, then one bit of the first slot
//!   rots on the medium: the recorded checksum must catch it at read
//!   time (typed integrity error, never served).
//! * **torn write** — only the first run of the batch reaches the disk
//!   but the device *reports full success* (a lying write cache): the
//!   unlanded slots' checksums must catch it at read time.
//! * **slow I/O** — every write (or read) eats a fixed wall-clock delay
//!   before it is submitted: the real-time analogue of the chaos
//!   engine's virtual-clock `SlowIo` fault, for stressing queueing and
//!   priority behaviour under a degraded device rather than a broken
//!   one.

use crate::platform::io_backend::{
    BatchedBackend, IoBackend, IoClass, IoDir, IoRun, TransientIo,
};
use crate::platform::metrics::IoStats;
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub struct FlakyBackend {
    inner: BatchedBackend,
    fail_writes: AtomicBool,
    fail_reads: AtomicBool,
    /// Fail this many upcoming writes with the transient marker.
    transient_writes: AtomicU64,
    /// Corrupt (bit-flip) the first slot of the next write batch.
    flip_next_write: AtomicBool,
    /// Tear the next write batch: land the first run only, report success.
    tear_next_write: AtomicBool,
    /// Sleep this long before every write submission (0 = off).
    slow_write_ns: AtomicU64,
    /// Sleep this long before every read submission (0 = off).
    slow_read_ns: AtomicU64,
}

impl FlakyBackend {
    /// The failure-injection suite's historical shape: two pool workers,
    /// a 1 MiB in-flight cap, 8-page batches.
    pub fn new() -> Arc<Self> {
        Self::with_inner(2, 1 << 20, 8, Arc::new(IoStats::default()))
    }

    /// Wrap a batched backend with explicit pool parameters, for suites
    /// that need a specific worker count or in-flight budget.
    pub fn with_inner(
        workers: usize,
        inflight_cap: usize,
        batch_pages: usize,
        stats: Arc<IoStats>,
    ) -> Arc<Self> {
        Arc::new(Self {
            inner: BatchedBackend::new(workers, inflight_cap, batch_pages, stats),
            fail_writes: AtomicBool::new(false),
            fail_reads: AtomicBool::new(false),
            transient_writes: AtomicU64::new(0),
            flip_next_write: AtomicBool::new(false),
            tear_next_write: AtomicBool::new(false),
            slow_write_ns: AtomicU64::new(0),
            slow_read_ns: AtomicU64::new(0),
        })
    }

    pub fn fail_writes(&self, on: bool) {
        self.fail_writes.store(on, Ordering::Relaxed);
    }

    pub fn fail_reads(&self, on: bool) {
        self.fail_reads.store(on, Ordering::Relaxed);
    }

    pub fn transient_writes(&self, n: u64) {
        self.transient_writes.store(n, Ordering::Relaxed);
    }

    pub fn flip_next_write(&self) {
        self.flip_next_write.store(true, Ordering::Relaxed);
    }

    pub fn tear_next_write(&self) {
        self.tear_next_write.store(true, Ordering::Relaxed);
    }

    /// Delay every write by `ns` wall-clock nanoseconds (0 disables).
    pub fn slow_writes(&self, ns: u64) {
        self.slow_write_ns.store(ns, Ordering::Relaxed);
    }

    /// Delay every read by `ns` wall-clock nanoseconds (0 disables).
    pub fn slow_reads(&self, ns: u64) {
        self.slow_read_ns.store(ns, Ordering::Relaxed);
    }
}

impl IoBackend for FlakyBackend {
    fn execute(
        &self,
        file: &Arc<File>,
        runs: Vec<IoRun>,
        dir: IoDir,
        class: IoClass,
    ) -> anyhow::Result<u64> {
        let delay = match dir {
            IoDir::Write => self.slow_write_ns.load(Ordering::Relaxed),
            IoDir::Read => self.slow_read_ns.load(Ordering::Relaxed),
        };
        if delay > 0 {
            std::thread::sleep(Duration::from_nanos(delay));
        }
        if dir == IoDir::Write && self.transient_writes.load(Ordering::Relaxed) > 0 {
            self.transient_writes.fetch_sub(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(TransientIo)
                .context("injected transient pwritev failure"));
        }
        let (failing, verb) = match dir {
            IoDir::Write => (self.fail_writes.load(Ordering::Relaxed), "pwritev"),
            IoDir::Read => (self.fail_reads.load(Ordering::Relaxed), "preadv"),
        };
        if failing {
            if runs.len() > 1 {
                // Partial batch: the first run lands, the rest never do.
                let first = runs.into_iter().next().unwrap();
                self.inner.execute(file, vec![first], dir, class)?;
            }
            anyhow::bail!("injected {verb} failure");
        }
        if dir == IoDir::Write && self.tear_next_write.swap(false, Ordering::Relaxed) {
            // Torn (short) write: only the tail of the first run reaches
            // the disk — the head slots stay a sparse hole — but the
            // device claims the whole batch landed (a lying write cache
            // losing power mid-flush). The hole reads back as zeros, so
            // only the recorded checksums can catch it.
            let claimed: u64 = runs.iter().map(|r| r.bytes()).sum();
            let mut first = runs.into_iter().next().unwrap();
            let drop_n = first.pages.len() - first.pages.len() / 2;
            first.offset += (drop_n * crate::PAGE_SIZE) as u64;
            first.pages.drain(..drop_n);
            if !first.pages.is_empty() {
                self.inner.execute(file, vec![first], dir, class)?;
            }
            return Ok(claimed);
        }
        let flip = dir == IoDir::Write && self.flip_next_write.swap(false, Ordering::Relaxed);
        let corrupt_at = flip.then(|| runs[0].offset);
        let n = self.inner.execute(file, runs, dir, class)?;
        if let Some(off) = corrupt_at {
            // Silent media corruption after the write was acknowledged.
            let mut b = [0u8; 1];
            file.read_exact_at(&mut b, off)?;
            b[0] ^= 0x01;
            file.write_all_at(&b, off)?;
        }
        Ok(n)
    }

    fn name(&self) -> &'static str {
        "flaky"
    }

    fn stats(&self) -> &Arc<IoStats> {
        self.inner.stats()
    }
}
