//! Shared experiment drivers: the code that regenerates every table and
//! figure of the paper's evaluation. The `rust/benches/*` binaries and the
//! `repro fig6|fig7|density` CLI commands are thin wrappers over these, so
//! `cargo bench` and the launcher print identical rows.

pub mod density_exp;
pub mod fig6;
pub mod fig7;
pub mod flaky_io;
pub mod replay_scaling;
pub mod server_scaling;

use crate::config::SharingConfig;
use crate::container::sandbox::SandboxServices;
use crate::container::{NoopRunner, PayloadRunner};
use crate::runtime::PjrtRunner;
use crate::simtime::CostModel;
use crate::workloads::functionbench::scaled_for_test;
use crate::workloads::WorkloadSpec;
use std::sync::Arc;

/// Pick the PJRT runner when artifacts exist (the real three-layer stack),
/// otherwise fall back to NoopRunner so memory experiments still run.
pub fn best_runner() -> Arc<dyn PayloadRunner> {
    let dir = std::env::var("QH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    match PjrtRunner::new(&dir) {
        Ok(r) => {
            if r.precompile_all().is_ok() {
                eprintln!("# payloads: PJRT ({} artifacts)", r.manifest().artifacts.len());
                return Arc::new(r);
            }
            eprintln!("# payloads: PJRT manifest loaded but compile failed; using no-op");
            Arc::new(NoopRunner)
        }
        Err(_) => {
            eprintln!("# payloads: no artifacts (run `make artifacts`); using no-op");
            Arc::new(NoopRunner)
        }
    }
}

/// Scale a spec for quick mode.
pub fn maybe_scale(spec: WorkloadSpec, quick: bool) -> WorkloadSpec {
    if quick {
        scaled_for_test(spec, 16)
    } else {
        spec
    }
}

/// A fresh service rig for one measurement (own host region + swap dir).
pub fn rig(
    host_bytes: usize,
    sharing: SharingConfig,
    reap_enabled: bool,
    runner: Arc<dyn PayloadRunner>,
    tag: &str,
) -> Arc<SandboxServices> {
    let svc = SandboxServices::new_local(
        host_bytes,
        CostModel::paper(),
        sharing,
        runner,
        tag,
    )
    .expect("building service rig");
    Arc::new(SandboxServices {
        host: svc.host.clone(),
        heap: svc.heap.clone(),
        cache: svc.cache.clone(),
        registry: svc.registry.clone(),
        cost: svc.cost.clone(),
        sharing: svc.sharing.clone(),
        swap_dir: svc.swap_dir.clone(),
        runner: svc.runner.clone(),
        reap_enabled,
        hostenv: svc.hostenv.clone(),
        io: svc.io.clone(),
        durability: svc.durability.clone(),
        durability_stats: svc.durability_stats.clone(),
        recorder: svc.recorder.clone(),
    })
}

/// Render one table row: label + value columns.
pub fn row(label: &str, cells: &[String]) -> String {
    let mut out = format!("{label:<22}");
    for c in cells {
        out.push_str(&format!(" {c:>14}"));
    }
    out
}

/// ms with 1 decimal.
pub fn ms(ns: u64) -> String {
    format!("{:.1} ms", ns as f64 / 1e6)
}

/// MiB with 1 decimal.
pub fn mib(bytes: u64) -> String {
    format!("{:.1} MiB", bytes as f64 / (1 << 20) as f64)
}

/// percentage with 0 decimals.
pub fn pct(part: u64, whole: u64) -> String {
    if whole == 0 {
        return "-".into();
    }
    format!("{:.0}%", 100.0 * part as f64 / whole as f64)
}
