//! Threaded-server throughput scaling: the acceptance measurement for the
//! sharded control plane. Serves a multi-function workload (one spinning
//! payload per request, so a request occupies a worker for a fixed real
//! compute time) through the threaded server at increasing worker counts
//! and reports requests/second — which must grow with workers now that no
//! global pools lock or shared receiver serializes the data plane.
//!
//! Also home to the **tick-stall** measurement ([`tick_stall`]): how long
//! a policy tick runs when it has to deflate a fat sandbox, synchronously
//! (`pipeline_workers = 0`, the old behavior — the control loop eats the
//! whole swap-out) vs through the off-lock deflation pool (the tick only
//! flips state and submits). The stalled control loop is what delayed
//! hibernate/wake decisions for every co-sharded function.

use crate::config::PlatformConfig;
use crate::container::{NoopRunner, SpinRunner};
use crate::platform::server::{Server, ServerConfig};
use crate::platform::Platform;
use crate::simtime::CostModel;
use crate::workloads::functionbench::{golang_hello, nodejs_hello, scaled_for_test};
use crate::workloads::PayloadSpec;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One measurement row.
#[derive(Debug, Clone)]
pub struct ScalingResult {
    pub workers: usize,
    pub requests: u64,
    pub wall_ns: u64,
}

impl ScalingResult {
    pub fn rps(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.requests as f64 / (self.wall_ns as f64 / 1e9)
    }
}

/// Run the scaling sweep: `funcs` functions × `requests_per_fn` requests at
/// each worker count, every request spinning `spin_ns` of real compute.
pub fn run(
    worker_counts: &[usize],
    funcs: usize,
    requests_per_fn: usize,
    spin_ns: u64,
) -> Vec<ScalingResult> {
    let mut results = Vec::new();
    for &workers in worker_counts {
        let mut cfg = PlatformConfig::default();
        cfg.host_memory = 4 << 30;
        cfg.cost = CostModel::free();
        cfg.shards = funcs.max(1);
        cfg.policy.hibernate_idle_ms = 60_000; // out of the measurement's way
        cfg.policy.predictive_wakeup = false;
        cfg.swap_dir = std::env::temp_dir()
            .join(format!(
                "qh-server-scaling-{workers}-{}",
                std::process::id()
            ))
            .to_string_lossy()
            .into_owned();
        let runner = Arc::new(SpinRunner {
            ns_per_iteration: spin_ns,
        });
        let platform = Arc::new(Platform::new(cfg, runner).expect("platform"));
        for i in 0..funcs {
            let mut spec = scaled_for_test(golang_hello(), 32);
            spec.name = format!("fn-{i}");
            spec.payload = Some(PayloadSpec {
                artifact: "spin".into(),
                iterations: 1,
            });
            platform.deploy(spec).expect("deploy");
        }
        // Pre-warm: one request per function outside the timed window so
        // cold starts don't pollute the throughput number.
        for i in 0..funcs {
            platform
                .request_at(&format!("fn-{i}"), 0)
                .expect("pre-warm request");
        }

        let mut server = Server::start_with(
            platform.clone(),
            ServerConfig {
                workers,
                policy_interval: Duration::from_secs(3600),
                spill_threshold: Some(2),
            },
        );
        let total = (funcs * requests_per_fn) as u64;
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(total as usize);
        for _ in 0..requests_per_fn {
            for i in 0..funcs {
                rxs.push(server.submit(&format!("fn-{i}")).expect("submit"));
            }
        }
        for rx in rxs {
            rx.recv().expect("reply").expect("request");
        }
        let wall_ns = t0.elapsed().as_nanos() as u64;
        server.shutdown();
        results.push(ScalingResult {
            workers,
            requests: total,
            wall_ns,
        });
    }
    results
}

/// One tick-stall measurement row.
#[derive(Debug, Clone)]
pub struct TickStallResult {
    pub pipeline_workers: usize,
    pub cycles: usize,
    /// Worst policy-tick wall time over the cycles.
    pub max_tick_ns: u64,
    /// Mean policy-tick wall time.
    pub mean_tick_ns: u64,
}

/// Measure how long a policy tick stalls when it hibernates a fat
/// sandbox: `cycles` rounds of warm-the-big-function → idle → tick. With
/// `pipeline_workers = 0` the tick performs the whole delta swap-out /
/// file-release pass inline (the pre-pipeline behavior); with a pool the
/// tick returns after the SIGSTOP flip and the I/O runs off-loop. Every
/// cycle drains afterwards so both modes do identical total work.
pub fn tick_stall(pipeline_workers: usize, cycles: usize) -> TickStallResult {
    let mut cfg = PlatformConfig::default();
    cfg.host_memory = 2 << 30;
    cfg.cost = CostModel::paper();
    cfg.shards = 1; // one shard: every function co-sharded with the fat one
    cfg.policy.hibernate_idle_ms = 1;
    cfg.policy.predictive_wakeup = false;
    cfg.policy.pipeline_workers = pipeline_workers;
    cfg.swap_dir = std::env::temp_dir()
        .join(format!(
            "qh-tick-stall-{pipeline_workers}-{}",
            std::process::id()
        ))
        .to_string_lossy()
        .into_owned();
    let platform = Platform::new(cfg, Arc::new(NoopRunner)).expect("platform");
    let mut big = nodejs_hello(); // ~10 MB anon: a real swap-out
    big.name = "big".into();
    big.payload = None;
    platform.deploy(big).expect("deploy");
    for i in 0..4 {
        let mut tiny = scaled_for_test(golang_hello(), 64);
        tiny.name = format!("tiny-{i}");
        tiny.payload = None;
        platform.deploy(tiny).expect("deploy");
    }

    let mut vt: u64 = 0;
    let mut ticks = Vec::with_capacity(cycles);
    for _ in 0..cycles {
        let r = platform.request_at("big", vt).expect("big request");
        vt += r.latency_ns + 10_000_000; // idle well past the 1 ms threshold
        let t0 = Instant::now();
        platform.policy_tick_nowait(vt).expect("tick");
        ticks.push(t0.elapsed().as_nanos() as u64);
        // Co-sharded functions keep serving while the deflation runs.
        for i in 0..4 {
            platform
                .request_at(&format!("tiny-{i}"), vt + 1_000_000)
                .expect("tiny request");
        }
        platform.drain_pipeline().expect("drain");
        vt += 10_000_000;
    }
    TickStallResult {
        pipeline_workers,
        cycles,
        max_tick_ns: ticks.iter().copied().max().unwrap_or(0),
        mean_tick_ns: ticks.iter().sum::<u64>() / ticks.len().max(1) as u64,
    }
}
