//! Parallel-replay scaling: the acceptance measurement for the replay
//! engine. Replays one fixed Azure-shaped scenario at increasing worker
//! counts and reports wall-clock + events/second — which must grow with
//! workers — while asserting the report fingerprints stay **bit-identical**
//! (the determinism contract: worker count is a performance knob, never a
//! results knob).

use crate::config::PlatformConfig;
use crate::replay::{self, scenario};

/// One measurement row.
#[derive(Debug, Clone)]
pub struct ReplayScalingResult {
    pub workers: usize,
    pub events: usize,
    pub wall_ns: u64,
    pub fingerprint: u64,
}

impl ReplayScalingResult {
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.events as f64 / (self.wall_ns as f64 / 1e9)
    }
}

/// Replay the `azure-heavy-tail` scenario (`funcs` functions over
/// `duration_ns` virtual time, fixed `seed`) once per worker count.
pub fn run(
    worker_counts: &[usize],
    funcs: usize,
    duration_ns: u64,
    seed: u64,
) -> Vec<ReplayScalingResult> {
    run_policy("azure-heavy-tail", "hibernate", false, worker_counts, funcs, duration_ns, seed)
}

/// The general form: any scenario under any policy kind, optionally with
/// per-shard budget leases. The CI gate runs this twice — the classic
/// heavy-tail/hibernate leg and a tenant-skewed/tenant-fair leg (leases
/// on), each with its own throughput floor in `bench/baseline.json`.
pub fn run_policy(
    scenario_name: &str,
    policy_kind: &str,
    pressure_leases: bool,
    worker_counts: &[usize],
    funcs: usize,
    duration_ns: u64,
    seed: u64,
) -> Vec<ReplayScalingResult> {
    let scenario_run =
        scenario::build(scenario_name, funcs, duration_ns, seed).expect("scenario");
    eprintln!(
        "# replay_scaling[{scenario_name}/{policy_kind}]: {} functions, {} events",
        scenario_run.specs.len(),
        scenario_run.events.len()
    );
    worker_counts
        .iter()
        .map(|&workers| {
            let mut cfg = PlatformConfig::default();
            cfg.seed = seed;
            // Enough shards that 8 workers all own several, regardless of
            // the bench machine's core count.
            cfg.shards = 32;
            cfg.policy.hibernate_idle_ms = 500;
            cfg.policy.kind = policy_kind.to_string();
            cfg.policy.pressure_leases = pressure_leases;
            cfg.swap_dir = std::env::temp_dir()
                .join(format!(
                    "qh-replay-scaling-{policy_kind}-w{workers}-{}",
                    std::process::id()
                ))
                .to_string_lossy()
                .into_owned();
            let (report, _platform) =
                replay::run_scenario(&cfg, &scenario_run, workers).expect("replay");
            ReplayScalingResult {
                workers: report.workers,
                events: report.events,
                wall_ns: report.wall_ns,
                fingerprint: report.fingerprint(),
            }
        })
        .collect()
}
