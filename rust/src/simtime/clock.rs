//! Per-request virtual clock.
//!
//! A [`Clock`] accumulates charged nanoseconds (device + OS model costs)
//! and measured nanoseconds (real compute through PJRT, real page-content
//! work), kept separately so benches can report both the paper-shaped total
//! and the real-CPU fraction.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Accumulates virtual time. Cloneable handle (`SharedClock`) for use from
/// the fault handlers deep in the memory subsystem.
#[derive(Debug, Default)]
pub struct Clock {
    charged_ns: AtomicU64,
    measured_ns: AtomicU64,
    /// Virtual-time anchor for trace stamps: the position on the global
    /// virtual timeline at which this per-request/per-job clock started
    /// (see [`Self::stamp_ns`]). Zero unless a caller anchors it.
    base_ns: AtomicU64,
}

impl Clock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Anchor this clock at `ns` on the global virtual timeline, so
    /// [`Self::stamp_ns`] yields absolute virtual positions. The platform
    /// sets this to the request/tick virtual time before handing the clock
    /// down; direct callers (tests, benches) can leave it at 0.
    pub fn set_base(&self, ns: u64) {
        self.base_ns.store(ns, Ordering::Relaxed);
    }

    /// Current absolute virtual position: anchor + charged model time.
    /// This is the timestamp hint flight-recorder emissions pass to
    /// [`crate::obs::Recorder::emit`] — used verbatim by the virtual trace
    /// clock, ignored by the wall clock.
    pub fn stamp_ns(&self) -> u64 {
        self.base_ns.load(Ordering::Relaxed) + self.charged_ns()
    }

    /// Charge modeled time (device/OS cost).
    #[inline]
    pub fn charge(&self, ns: u64) {
        self.charged_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record real measured time.
    #[inline]
    pub fn record(&self, ns: u64) {
        self.measured_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Run `f` and attribute its wall-clock to the measured component.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        // lint:allow(wall-clock): this IS the measured-domain attribution point
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed().as_nanos() as u64);
        out
    }

    pub fn charged_ns(&self) -> u64 {
        self.charged_ns.load(Ordering::Relaxed)
    }

    pub fn measured_ns(&self) -> u64 {
        self.measured_ns.load(Ordering::Relaxed)
    }

    /// Total virtual latency: charged model time + real compute time.
    pub fn total_ns(&self) -> u64 {
        self.charged_ns() + self.measured_ns()
    }

    /// Snapshot and reset — used between request phases.
    pub fn take(&self) -> (u64, u64) {
        (
            self.charged_ns.swap(0, Ordering::Relaxed),
            self.measured_ns.swap(0, Ordering::Relaxed),
        )
    }
}

/// Shared handle to a clock.
pub type SharedClock = Arc<Clock>;

/// A scoped split: measures the difference of a clock across a region.
pub struct Span {
    start_charged: u64,
    start_measured: u64,
}

impl Span {
    pub fn begin(clock: &Clock) -> Self {
        Self {
            start_charged: clock.charged_ns(),
            start_measured: clock.measured_ns(),
        }
    }

    /// (charged delta, measured delta) since `begin`.
    pub fn end(&self, clock: &Clock) -> (u64, u64) {
        (
            clock.charged_ns() - self.start_charged,
            clock.measured_ns() - self.start_measured,
        )
    }

    /// Total virtual time elapsed in the span.
    pub fn total(&self, clock: &Clock) -> u64 {
        let (c, m) = self.end(clock);
        c + m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_and_record_accumulate() {
        let c = Clock::new();
        c.charge(100);
        c.charge(50);
        c.record(25);
        assert_eq!(c.charged_ns(), 150);
        assert_eq!(c.measured_ns(), 25);
        assert_eq!(c.total_ns(), 175);
    }

    #[test]
    fn take_resets() {
        let c = Clock::new();
        c.charge(10);
        c.record(20);
        assert_eq!(c.take(), (10, 20));
        assert_eq!(c.total_ns(), 0);
    }

    #[test]
    fn time_measures_real_work() {
        let c = Clock::new();
        c.time(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(c.measured_ns() >= 2_000_000);
    }

    #[test]
    fn span_deltas() {
        let c = Clock::new();
        c.charge(5);
        let span = Span::begin(&c);
        c.charge(7);
        c.record(3);
        assert_eq!(span.end(&c), (7, 3));
        assert_eq!(span.total(&c), 10);
    }
}
