//! Deterministic tick schedules over virtual time.
//!
//! Replay determinism hinges on every policy tick happening at the *same
//! virtual instant* no matter how the trace is partitioned across replay
//! workers. A [`TickSchedule`] pins ticks to fixed multiples of a period
//! (starting at 0) and hands them out one at a time, so a caller can
//! interleave "run every tick due before this event" with event processing
//! and land on an identical tick sequence regardless of batching.

/// Fixed-period tick schedule: ticks at `0, p, 2p, …` in virtual time.
#[derive(Debug, Clone)]
pub struct TickSchedule {
    next: u64,
    period: u64,
}

impl TickSchedule {
    /// Build a schedule with the given period (clamped to ≥ 1 ns).
    pub fn new(period_ns: u64) -> Self {
        Self {
            next: 0,
            period: period_ns.max(1),
        }
    }

    pub fn period_ns(&self) -> u64 {
        self.period
    }

    /// The next tick instant that has not been handed out yet.
    pub fn next_ns(&self) -> u64 {
        self.next
    }

    /// Hand out the next tick due at or before `now` (inclusive), advancing
    /// the schedule; `None` once the schedule is caught up past `now`.
    pub fn pop_due(&mut self, now: u64) -> Option<u64> {
        if self.next <= now {
            let t = self.next;
            self.next += self.period;
            Some(t)
        } else {
            None
        }
    }

    /// Hand out the next tick strictly before `end` (exclusive) — the
    /// epoch-boundary catch-up form.
    pub fn pop_before(&mut self, end: u64) -> Option<u64> {
        if self.next < end {
            let t = self.next;
            self.next += self.period;
            Some(t)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_are_fixed_multiples() {
        let mut s = TickSchedule::new(10);
        let mut got = Vec::new();
        while let Some(t) = s.pop_due(35) {
            got.push(t);
        }
        assert_eq!(got, vec![0, 10, 20, 30]);
        assert_eq!(s.next_ns(), 40);
        assert!(s.pop_due(39).is_none());
        assert_eq!(s.pop_due(40), Some(40));
    }

    #[test]
    fn pop_before_is_exclusive() {
        let mut s = TickSchedule::new(10);
        let mut got = Vec::new();
        while let Some(t) = s.pop_before(30) {
            got.push(t);
        }
        assert_eq!(got, vec![0, 10, 20]);
        assert_eq!(s.pop_before(31), Some(30));
    }

    #[test]
    fn batching_does_not_change_the_sequence() {
        // The determinism property: draining in two different batchings
        // yields the same tick instants.
        let mut a = TickSchedule::new(7);
        let mut b = TickSchedule::new(7);
        let mut ta = Vec::new();
        let mut tb = Vec::new();
        while let Some(t) = a.pop_due(100) {
            ta.push(t);
        }
        for cut in [3u64, 22, 22, 57, 100] {
            while let Some(t) = b.pop_due(cut) {
                tb.push(t);
            }
        }
        assert_eq!(ta, tb);
    }

    #[test]
    fn zero_period_clamped() {
        let mut s = TickSchedule::new(0);
        assert_eq!(s.period_ns(), 1);
        assert_eq!(s.pop_due(0), Some(0));
        assert_eq!(s.pop_due(1), Some(1));
    }
}
