//! Cost model constants, calibrated to the paper's testbed (§3.4.1, §1).
//!
//! | constant | paper evidence |
//! |---|---|
//! | `guest_host_switch_ns` = 15 µs | "We observe about 15 microsecond latency for such a guest/host switch" |
//! | `ssd_random_read_bw` ≈ 100 MB/s | "4K page random read throughput is about 100MB/second" |
//! | `ssd_seq_read_bw` ≈ 1 GB/s | "sequential batch read throughput is more than 1GB/second" |
//! | `sandbox_startup_ns` = 25 ms | §1 "container runtime startup typically takes 100 or so ms"; Quark sits at the fast end of the VM-runtime range |

use crate::PAGE_SIZE;

/// All virtual-time constants in one place. Values are nanoseconds or
/// bytes/second. `CostModel::paper()` is the calibrated default used by the
/// figure benches; tests may build cheaper models.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// One KVM guest↔host mode transition (one direction pair), §3.4.1.
    pub guest_host_switch_ns: u64,
    /// Guest-side page-fault handling (register save/restore, PT walk).
    pub page_fault_handling_ns: u64,
    /// SSD random 4 KiB read bandwidth (bytes/s).
    pub ssd_random_read_bw: u64,
    /// SSD sequential read bandwidth (bytes/s).
    pub ssd_seq_read_bw: u64,
    /// SSD write bandwidth (bytes/s) — swap-out path.
    pub ssd_write_bw: u64,
    /// Per-I/O submission latency (NVMe queue + interrupt), added once per
    /// syscall-visible operation.
    pub ssd_op_latency_ns: u64,
    /// Quark sandbox (container runtime) startup: Cgroup+netns+rootfs+VM.
    pub sandbox_startup_ns: u64,
    /// Cost of waking a parked runtime host thread (futex wake + sched).
    pub thread_wake_ns: u64,
    /// Connection accept / request dispatch overhead on the guest side.
    pub request_dispatch_ns: u64,
    /// madvise(MADV_DONTNEED) per-call fixed cost plus per-page cost.
    pub madvise_call_ns: u64,
    pub madvise_per_page_ns: u64,
    /// Host page-fault commit cost (zero-fill on first touch after reclaim).
    pub host_commit_per_page_ns: u64,
}

impl CostModel {
    /// Calibrated to the paper's testbed (i7-8700K, PM981 NVMe, Ubuntu
    /// 20.04 + KVM). See DESIGN.md §4.
    pub fn paper() -> Self {
        Self {
            guest_host_switch_ns: 15_000,
            page_fault_handling_ns: 3_000,
            ssd_random_read_bw: 100 * 1_000_000,
            ssd_seq_read_bw: 1_000 * 1_000_000,
            ssd_write_bw: 800 * 1_000_000,
            ssd_op_latency_ns: 80_000,
            sandbox_startup_ns: 25_000_000,
            thread_wake_ns: 8_000,
            request_dispatch_ns: 30_000,
            madvise_call_ns: 2_000,
            madvise_per_page_ns: 150,
            host_commit_per_page_ns: 900,
        }
    }

    /// A free model: all charges zero. Useful for unit tests that assert
    /// pure mechanism behaviour.
    pub fn free() -> Self {
        Self {
            guest_host_switch_ns: 0,
            page_fault_handling_ns: 0,
            ssd_random_read_bw: u64::MAX,
            ssd_seq_read_bw: u64::MAX,
            ssd_write_bw: u64::MAX,
            ssd_op_latency_ns: 0,
            sandbox_startup_ns: 0,
            thread_wake_ns: 0,
            request_dispatch_ns: 0,
            madvise_call_ns: 0,
            madvise_per_page_ns: 0,
            host_commit_per_page_ns: 0,
        }
    }

    #[inline]
    fn xfer_ns(bytes: u64, bw: u64) -> u64 {
        if bw == u64::MAX {
            return 0;
        }
        // bytes / (bytes/s) in ns, rounding up.
        ((bytes as u128 * 1_000_000_000).div_ceil(bw as u128)) as u64
    }

    /// Cost of one random 4 KiB page read (page-fault swap-in path):
    /// op latency + transfer at random-read bandwidth.
    pub fn random_page_read_ns(&self) -> u64 {
        self.ssd_op_latency_ns + Self::xfer_ns(PAGE_SIZE as u64, self.ssd_random_read_bw)
    }

    /// Cost of one sequential batched read of `bytes` (REAP prefetch):
    /// a single op latency + transfer at sequential bandwidth.
    pub fn seq_read_ns(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.ssd_op_latency_ns + Self::xfer_ns(bytes, self.ssd_seq_read_bw)
    }

    /// Cost of a demand-paged read of `bytes` of *scattered* file pages
    /// (binary working-set reload after deflation step #4): one submission
    /// plus transfer at random-read bandwidth — the pages are spread across
    /// the binary, so the device sees random traffic, not a stream.
    pub fn scattered_read_ns(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.ssd_op_latency_ns + Self::xfer_ns(bytes, self.ssd_random_read_bw)
    }

    /// Cost of a batched sequential write of `bytes` (swap-out path).
    pub fn seq_write_ns(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.ssd_op_latency_ns + Self::xfer_ns(bytes, self.ssd_write_bw)
    }

    /// Full cost of one page-fault based swap-in of a single page with no
    /// readahead help: guest fault handling + guest→host→guest switch +
    /// random device read. This is the §3.4.1 worst case; the fault path
    /// itself benefits from [`Self::readahead_cluster_ns`] when faults hit
    /// consecutive swap-file slots.
    pub fn pagefault_swapin_ns(&self) -> u64 {
        self.page_fault_handling_ns + self.guest_host_switch_ns + self.random_page_read_ns()
    }

    /// Swap readahead cluster size (pages): the host kernel reads this many
    /// consecutive swap-file pages per miss (Linux `page-cluster`-style),
    /// so in-order fault streams amortize the device cost.
    pub const READAHEAD_PAGES: u64 = 32;

    /// Device cost of one readahead cluster fill (one submission + a
    /// 32-page streaming read).
    pub fn readahead_cluster_ns(&self) -> u64 {
        self.ssd_op_latency_ns
            + Self::xfer_ns(
                Self::READAHEAD_PAGES * PAGE_SIZE as u64,
                self.ssd_seq_read_bw,
            )
    }

    /// Cost of returning `pages` to the host via one madvise call.
    pub fn madvise_ns(&self, pages: u64) -> u64 {
        if pages == 0 {
            return 0;
        }
        self.madvise_call_ns + pages * self.madvise_per_page_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_random_vs_seq_ratio_holds() {
        // §3.4.1: sequential ≈ 10× random throughput. For a 10 MB working
        // set, REAP batch read must be far cheaper than page-by-page random.
        let m = CostModel::paper();
        let pages = 10 * 1024 * 1024 / PAGE_SIZE as u64;
        let random_total = pages * m.pagefault_swapin_ns();
        let reap_total = m.seq_read_ns(pages * PAGE_SIZE as u64);
        assert!(
            random_total > 10 * reap_total,
            "random {random_total} vs reap {reap_total}"
        );
    }

    #[test]
    fn random_read_matches_measured_throughput() {
        // 4K/100MB/s ≈ 40 µs transfer + op latency.
        let m = CostModel::paper();
        let ns = m.random_page_read_ns();
        assert!((100_000..200_000).contains(&ns), "{ns}");
    }

    #[test]
    fn free_model_is_free() {
        let m = CostModel::free();
        assert_eq!(m.pagefault_swapin_ns(), 0);
        assert_eq!(m.seq_read_ns(1 << 30), 0);
        assert_eq!(m.seq_write_ns(1 << 30), 0);
        assert_eq!(m.madvise_ns(1000), 0);
    }

    #[test]
    fn zero_bytes_zero_cost() {
        let m = CostModel::paper();
        assert_eq!(m.seq_read_ns(0), 0);
        assert_eq!(m.seq_write_ns(0), 0);
        assert_eq!(m.madvise_ns(0), 0);
    }
}
