//! Virtual time: the cost model that makes latencies paper-shaped.
//!
//! The paper's evaluation latency is a mix of (a) real compute — request
//! processing, which we run for real through PJRT; (b) OS mechanism costs —
//! page faults, KVM guest/host mode switches; (c) device costs — SSD reads
//! and writes. (b) and (c) cannot be measured meaningfully on this testbed
//! (no KVM guest, and a warm page cache makes random ≈ sequential), so they
//! are *charged* to a per-request virtual clock using the paper's own
//! measured constants (§3.4.1), while the real work (real page writes, real
//! file I/O, real HLO execution) still happens and is verified.
//!
//! Every latency a bench reports is `real compute time + charged model
//! time`; EXPERIMENTS.md §Perf additionally tracks the raw wall-clock of the
//! hot paths, which is what the optimization pass works on.

mod clock;
mod cost;
mod schedule;

pub use clock::{Clock, SharedClock, Span};
pub use cost::CostModel;
pub use schedule::TickSchedule;
