//! # quark-hibernate
//!
//! Reproduction of **"Hibernate Container: A Deflated Container Mode for Fast
//! Startup and High-density Deployment in Serverless Computing"** (Sun, Vij,
//! Li, Guo, Xiong — 2023) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate implements, from scratch:
//!
//! * the Quark-style guest memory substrate the paper's mechanism lives in:
//!   a real-`mmap` host memory region ([`mem::host`]), the reclaim-oriented
//!   **Bitmap Page Allocator** of Fig. 4 ([`mem::bitmap_alloc`]), the binary
//!   buddy baseline it replaces ([`mem::buddy`]), guest page tables with the
//!   paper's custom swap bit #9 ([`mem::page_table`]), VMAs with cross-sandbox
//!   file-page sharing ([`mem::vma`], [`mem::mmap_file`]) and PSS accounting
//!   ([`mem::pss`]);
//! * the **Swapping Manager** of Fig. 5: page-fault based swap-out/in and the
//!   REAP record-and-prefetch batch path, over real per-sandbox swap files
//!   ([`swap`]);
//! * the **container state machine** of Fig. 3 with the three new states
//!   (`Hibernate`, `HibernateRunning`, `WokenUp`) and the 4-step
//!   deflate / 2-trigger inflate orchestration ([`container`]);
//! * a serverless **platform** around it: router, per-function pools, a
//!   pluggable keep-alive policy (`Policy` trait — hibernate, warm-only
//!   baseline, tenant-fair budgets) over a hierarchical host → tenant
//!   memory budget with optional per-shard pressure leases, anticipatory
//!   wake-up predictor with learned per-function wake leads, trace
//!   generation/replay and metrics ([`platform`], `docs/policy.md`);
//! * a **parallel deterministic replay engine** that drives thousand-function
//!   Azure-shaped scenarios through the sharded control plane with
//!   bit-identical results at any worker count ([`replay`]);
//! * the **PJRT runtime** that executes the AOT-compiled JAX/Pallas function
//!   payloads (`artifacts/*.hlo.txt`) on the request path ([`runtime`]);
//! * the paper's **evaluation workloads** (FunctionBench trio + four
//!   language-runtime hello-worlds), calibrated to the paper's testbed
//!   ([`workloads`]).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every figure/table of the paper to a bench target.

pub mod analysis;
pub mod bench_support;
pub mod config;
pub mod container;
pub mod mem;
pub mod obs;
pub mod platform;
pub mod replay;
pub mod runtime;
pub mod simtime;
pub mod swap;
pub mod util;
pub mod workloads;

/// Guest page size, 4 KiB (the only size the Bitmap Page Allocator serves).
pub const PAGE_SIZE: usize = 4096;
/// Bitmap-allocator block size: 4 MiB, 4 MiB-aligned (Fig. 4).
pub const BLOCK_SIZE: usize = 4 << 20;
/// Pages per 4 MiB block (first one is the Control Page).
pub const PAGES_PER_BLOCK: usize = BLOCK_SIZE / PAGE_SIZE;
/// Data pages available per block (all but the Control Page).
pub const DATA_PAGES_PER_BLOCK: usize = PAGES_PER_BLOCK - 1;
