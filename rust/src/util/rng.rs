//! Deterministic pseudo-random number generation (SplitMix64 + xoshiro256**).
//!
//! Used for workload traces, property tests and page-content generation.
//! Deterministic seeding keeps every bench and test reproducible — a
//! requirement for regenerating the paper's figures bit-for-bit.

/// SplitMix64: used to seed the main generator and for cheap one-off streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality; the workhorse RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially-distributed sample with mean `mean` (for Poisson
    /// arrival processes in the trace generator).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Log-normal sample parameterised by the *target* median and sigma —
    /// matches the heavy-tailed inter-arrival fits of the Azure FaaS study
    /// the paper cites for workload shape.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        // Box-Muller from two uniforms.
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        median * (sigma * z).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean = 5.0;
        let sum: f64 = (0..n).map(|_| r.exp(mean)).sum();
        let got = sum / n as f64;
        assert!((got - mean).abs() < 0.2, "mean {got}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
