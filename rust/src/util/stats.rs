//! Latency/size statistics: summaries and fixed-bucket histograms.
//!
//! This powers the in-repo bench harness (the registry has no criterion)
//! and the platform's latency metrics: each bench collects samples, and
//! `Summary` prints mean/p50/p95/p99 rows in the same grouping the paper's
//! figures use, while [`Histogram`] gives HDR-style log-bucketed
//! distributions whose merge is *exact* (merging two histograms is
//! bucket-wise addition over fixed edges, so striped or sharded recording
//! loses nothing relative to recording into one histogram).

/// Online summary over `u64` samples (typically nanoseconds or bytes).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<u64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: u64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn extend(&mut self, vs: impl IntoIterator<Item = u64>) {
        self.samples.extend(vs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|&v| v as f64).sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> u64 {
        self.samples.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_unstable();
            self.sorted = true;
        }
    }

    /// Percentile via nearest-rank on the sorted samples. `q` in `[0,100]`.
    pub fn percentile(&mut self, q: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        self.ensure_sorted();
        let rank = ((q / 100.0) * (self.samples.len() as f64 - 1.0)).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn p50(&mut self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> u64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> u64 {
        self.percentile(99.0)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let var = self
            .samples
            .iter()
            .map(|&v| {
                let d = v as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / (n as f64 - 1.0);
        var.sqrt()
    }

    /// One-line report with a label, in time units.
    pub fn report_ns(&mut self, label: &str) -> String {
        use crate::util::human_ns;
        format!(
            "{label:<40} n={:<6} mean={:>10} p50={:>10} p95={:>10} p99={:>10} max={:>10}",
            self.len(),
            human_ns(self.mean() as u64),
            human_ns(self.p50()),
            human_ns(self.p95()),
            human_ns(self.p99()),
            human_ns(self.max()),
        )
    }
}

/// Number of fixed buckets in a [`Histogram`]: two per octave over the full
/// `u64` range, plus dedicated buckets for 0 and 1.
pub const HIST_BUCKETS: usize = 128;

/// HDR-style log-bucketed histogram with **fixed bucket edges** (two
/// sub-buckets per octave: `[2^o, 1.5·2^o)` and `[1.5·2^o, 2^(o+1))`),
/// giving ≤ 50 % relative bucket width at every magnitude.
///
/// Because the edges are fixed and independent of the data, merging two
/// histograms (bucket-wise add) is *exactly* equivalent to having recorded
/// every sample into one histogram — the property the striped metrics and
/// the sharded replay reports rely on. Percentiles are resolved by
/// nearest-rank over the cumulative bucket counts and reported as the
/// bucket's inclusive upper edge, clamped to the exact observed
/// `[min, max]` so p0/p100 are always exact.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Bucket index for a value: 0 and 1 get their own buckets; otherwise
/// `2·octave + sub` where `sub` is the value's bit below the leading one.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < 2 {
        return v as usize;
    }
    let o = 63 - v.leading_zeros() as usize;
    2 * o + ((v >> (o - 1)) & 1) as usize
}

/// Inclusive lower edge of bucket `i` (the smallest value it can hold).
#[inline]
fn bucket_low(i: usize) -> u64 {
    if i < 2 {
        return i as u64;
    }
    let (o, sub) = (i / 2, (i % 2) as u64);
    (1u64 << o) + sub * (1u64 << (o - 1))
}

/// Inclusive upper edge of bucket `i` (the largest value it can hold).
#[inline]
fn bucket_high(i: usize) -> u64 {
    if i + 1 >= HIST_BUCKETS {
        u64::MAX
    } else {
        bucket_low(i + 1) - 1
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Exact merge: bucket-wise addition over the shared fixed edges.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Percentile via nearest-rank over the cumulative bucket counts.
    /// `q` in `[0,100]`, mirroring [`Summary::percentile`]. The result is
    /// the resolved bucket's inclusive upper edge clamped to the observed
    /// `[min, max]`.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q / 100.0) * (self.count as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                return bucket_high(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    pub fn p999(&self) -> u64 {
        self.percentile(99.9)
    }

    /// Non-empty buckets as `(inclusive low edge, count)`, low to high —
    /// the dump the text/JSON exporters print.
    pub fn nonzero_buckets(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_low(i), c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend([1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(s.len(), 10);
        assert!((s.mean() - 5.5).abs() < 1e-9);
        assert_eq!(s.min(), 1);
        assert_eq!(s.max(), 10);
        assert_eq!(s.p50(), 6); // nearest-rank on 0-indexed
        assert_eq!(s.percentile(0.0), 1);
        assert_eq!(s.percentile(100.0), 10);
    }

    #[test]
    fn summary_empty() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn stddev_known() {
        let mut s = Summary::new();
        s.extend([2, 4, 4, 4, 5, 5, 7, 9]);
        // population stddev is 2; sample stddev = sqrt(32/7)
        assert!((s.stddev() - (32.0f64 / 7.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn bucket_edges_partition_the_range() {
        // Every value maps to exactly the bucket whose [low, high] holds it.
        for v in [0u64, 1, 2, 3, 4, 5, 6, 7, 8, 12, 100, 1000, 1 << 20, u64::MAX] {
            let i = bucket_index(v);
            assert!(bucket_low(i) <= v && v <= bucket_high(i), "v={v} bucket={i}");
        }
        // Edges are contiguous: low(i+1) == high(i) + 1.
        for i in 0..HIST_BUCKETS - 1 {
            assert_eq!(bucket_low(i + 1), bucket_high(i) + 1, "gap at bucket {i}");
        }
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        // Rank 500 (value 501) lands in bucket [384, 512) → upper edge 511.
        assert_eq!(h.p50(), 511);
        assert!(h.p99() >= h.p50());
        assert!(h.p999() >= h.p99());
        // Extremes are exact thanks to the min/max clamp.
        assert_eq!(h.percentile(0.0), 1);
        assert_eq!(h.percentile(100.0), 1000);
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_single_sample_is_exact() {
        let mut h = Histogram::new();
        h.record(12345);
        assert_eq!(h.p50(), 12345);
        assert_eq!(h.p999(), 12345);
        assert_eq!(h.min(), 12345);
        assert_eq!(h.max(), 12345);
    }

    #[test]
    fn histogram_merge_is_exact() {
        // Merging two stripes is bit-for-bit the same histogram as
        // recording every sample into one — the exact-merge contract.
        let mut all = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in 0..2000u64 {
            let x = (v * 2654435761) % 100_000; // deterministic spread
            all.record(x);
            if v % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(a.percentile(q), all.percentile(q), "q={q}");
        }
        let av: Vec<_> = a.nonzero_buckets().collect();
        let allv: Vec<_> = all.nonzero_buckets().collect();
        assert_eq!(av, allv);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p999(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.nonzero_buckets().count(), 0);
    }
}
