//! Latency/size statistics: summaries and fixed-bucket histograms.
//!
//! This powers the in-repo bench harness (the registry has no criterion):
//! each bench collects samples, and `Summary` prints mean/p50/p95/p99 rows
//! in the same grouping the paper's figures use.

/// Online summary over `u64` samples (typically nanoseconds or bytes).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<u64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: u64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn extend(&mut self, vs: impl IntoIterator<Item = u64>) {
        self.samples.extend(vs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|&v| v as f64).sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> u64 {
        self.samples.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_unstable();
            self.sorted = true;
        }
    }

    /// Percentile via nearest-rank on the sorted samples. `q` in `[0,100]`.
    pub fn percentile(&mut self, q: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        self.ensure_sorted();
        let rank = ((q / 100.0) * (self.samples.len() as f64 - 1.0)).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn p50(&mut self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> u64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> u64 {
        self.percentile(99.0)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let var = self
            .samples
            .iter()
            .map(|&v| {
                let d = v as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / (n as f64 - 1.0);
        var.sqrt()
    }

    /// One-line report with a label, in time units.
    pub fn report_ns(&mut self, label: &str) -> String {
        use crate::util::human_ns;
        format!(
            "{label:<40} n={:<6} mean={:>10} p50={:>10} p95={:>10} p99={:>10} max={:>10}",
            self.len(),
            human_ns(self.mean() as u64),
            human_ns(self.p50()),
            human_ns(self.p95()),
            human_ns(self.p99()),
            human_ns(self.max()),
        )
    }
}

/// Log-scaled histogram (powers of two), cheap enough for the hot path.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>, // bucket i counts values in [2^i, 2^(i+1))
    count: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; 64],
            count: 0,
            sum: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        let idx = 64 - v.max(1).leading_zeros() as usize - 1;
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend([1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(s.len(), 10);
        assert!((s.mean() - 5.5).abs() < 1e-9);
        assert_eq!(s.min(), 1);
        assert_eq!(s.max(), 10);
        assert_eq!(s.p50(), 6); // nearest-rank on 0-indexed
        assert_eq!(s.percentile(0.0), 1);
        assert_eq!(s.percentile(100.0), 10);
    }

    #[test]
    fn summary_empty() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn stddev_known() {
        let mut s = Summary::new();
        s.extend([2, 4, 4, 4, 5, 5, 7, 9]);
        // population stddev is 2; sample stddev = sqrt(32/7)
        assert!((s.stddev() - (32.0f64 / 7.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        // p50 of 1..1000 is ~500 → bucket upper bound 512
        assert_eq!(h.quantile(0.5), 512);
        assert!(h.quantile(0.99) >= 512);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
