//! Small shared utilities: deterministic RNG, statistics, byte formatting,
//! a minimal property-testing harness and a hand-rolled JSON emitter.
//!
//! The offline crate registry has no `rand`, `serde`, `proptest` or
//! `criterion`, so these are in-repo (see DESIGN.md §8).

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Format a byte count with binary units (`12.3 MiB`).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[unit])
    }
}

/// Format nanoseconds as an adaptive human duration (`1.25 ms`, `17.3 µs`).
pub fn human_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1} µs", ns as f64 / 1e3)
    } else {
        format!("{} ns", ns)
    }
}

/// FNV-1a 64-bit string hash. Deterministic across runs, processes and
/// platforms — which is what the control plane needs for stable placement
/// (workload → shard, workload → affinity worker). `std`'s `DefaultHasher`
/// makes no cross-release stability promise, so placement-sensitive code
/// uses this instead.
pub fn fnv1a(s: &str) -> u64 {
    fnv1a_bytes(s.as_bytes())
}

/// FNV-1a 64-bit over raw bytes — the page-image checksum the durable
/// swap/REAP slot tables record and verify (see `docs/durability.md`).
/// Same function as [`fnv1a`], exposed for non-UTF-8 payloads.
pub fn fnv1a_bytes(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in data {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Round `v` up to the next multiple of `align` (power-of-two not required).
pub fn align_up(v: u64, align: u64) -> u64 {
    debug_assert!(align > 0);
    v.div_ceil(align) * align
}

/// Round `v` down to a multiple of `align`.
pub fn align_down(v: u64, align: u64) -> u64 {
    debug_assert!(align > 0);
    v - v % align
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(1023), "1023 B");
        assert_eq!(human_bytes(1024), "1.0 KiB");
        assert_eq!(human_bytes(4 << 20), "4.0 MiB");
        assert_eq!(human_bytes(5 * (1 << 30)), "5.0 GiB");
    }

    #[test]
    fn human_ns_units() {
        assert_eq!(human_ns(12), "12 ns");
        assert_eq!(human_ns(1500), "1.5 µs");
        assert_eq!(human_ns(2_500_000), "2.50 ms");
        assert_eq!(human_ns(3_000_000_000), "3.00 s");
    }

    #[test]
    fn fnv1a_known_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a("foobar"), 0x85944171f73967e8);
        // Deterministic and spread-out enough to place shards.
        assert_eq!(fnv1a("nodejs-hello"), fnv1a("nodejs-hello"));
        assert_ne!(fnv1a("nodejs-hello") % 8, fnv1a("golang-hello") % 8);
    }

    #[test]
    fn fnv1a_bytes_matches_str_and_detects_flips() {
        assert_eq!(fnv1a_bytes(b"foobar"), fnv1a("foobar"));
        let page = vec![0xA5u8; 4096];
        let mut flipped = page.clone();
        flipped[1234] ^= 0x01;
        assert_ne!(
            fnv1a_bytes(&page),
            fnv1a_bytes(&flipped),
            "a single bit flip must change the page checksum"
        );
    }

    #[test]
    fn align_roundtrip() {
        assert_eq!(align_up(0, 4096), 0);
        assert_eq!(align_up(1, 4096), 4096);
        assert_eq!(align_up(4096, 4096), 4096);
        assert_eq!(align_down(4097, 4096), 4096);
        assert_eq!(align_up(10, 3), 12);
    }
}
