//! Minimal property-testing harness (the registry has no proptest).
//!
//! A property is run over `cases` deterministic RNG-seeded inputs; on
//! failure the harness retries with the failing seed and reports it so the
//! case can be replayed (`PROP_SEED=<n> cargo test ...`).

use crate::util::rng::Rng;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: u32,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { cases: 128, seed }
    }
}

/// Run `prop` over `cfg.cases` cases. Each case gets its own RNG derived
/// from the base seed; a panic is augmented with the case seed.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng),
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property `{name}` failed at case {case} (replay with PROP_SEED={case_seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Shorthand with the default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng),
{
    check(name, PropConfig::default(), prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check_default("tautology", |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic]
    fn reports_failures() {
        check(
            "always-false",
            PropConfig { cases: 4, seed: 1 },
            |_| panic!("boom"),
        );
    }
}
