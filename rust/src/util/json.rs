//! Hand-rolled JSON: a tiny value model, emitter and parser.
//!
//! Used for `artifacts/manifest.json` (written by the python AOT step and
//! read by [`crate::runtime::artifact`]) and for metrics export. The offline
//! registry has no serde, and the manifest is small and trusted, so a
//! straightforward recursive-descent parser is appropriate.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (the manifest only uses integers
/// that fit exactly).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.2e18 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document. Returns an error message with byte offset on
/// malformed input.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, String> {
        Err(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{s}`"))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or("eof in \\u escape")? as char;
                            code = code * 16 + d.to_digit(16).ok_or("bad hex in \\u escape")?;
                        }
                        // Surrogate pairs: manifest never uses them, but be correct.
                        if (0xD800..0xDC00).contains(&code) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let d = self.bump().ok_or("eof in \\u escape")? as char;
                                low = low * 16 + d.to_digit(16).ok_or("bad hex in \\u escape")?;
                            }
                            let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            out.push(char::from_u32(c).ok_or("bad surrogate pair")?);
                        } else {
                            out.push(char::from_u32(code).ok_or("bad codepoint")?);
                        }
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return self.err("bad utf8"),
                        };
                        self.pos = start + width;
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "bad utf8".to_string())?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = obj(vec![
            ("name", Json::Str("tiny_lm".into())),
            ("nums", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
            ("flag", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = v.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a": {"b": [1, 2, {"c": "d"}]}, "e": -1.5e2}"#).unwrap();
        assert_eq!(
            j.get("a").unwrap().get("b").unwrap().as_arr().unwrap()[2]
                .get("c")
                .unwrap()
                .as_str(),
            Some("d")
        );
        assert_eq!(j.get("e").unwrap().as_f64(), Some(-150.0));
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn u64_extraction() {
        let j = parse(r#"{"n": 4096}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(4096));
        let j = parse(r#"{"n": 4096.5}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), None);
    }
}
