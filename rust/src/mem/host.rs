//! The host-kernel side of guest memory: a real anonymous `mmap` region.
//!
//! QKernel's "guest physical memory is the virtual memory of the host Linux
//! OS" (§3.3) — we reproduce that literally: one `mmap(MAP_ANONYMOUS |
//! MAP_NORESERVE)` region per platform is the guest-physical space; pages
//! are committed by the host on first touch, and Hibernate's deflation
//! returns them with a *real* `madvise(MADV_DONTNEED)`, after which reads
//! observe zero-fill-on-demand — the exact behaviour that breaks the buddy
//! allocator's intrusive free list and motivates the Bitmap Page Allocator.
//!
//! Commit accounting is tracked bit-per-page so PSS/footprint metrics are
//! deterministic and cheap (reading smaps would measure the same thing but
//! drag the whole test process into the numbers).

use super::Gpa;
use crate::PAGE_SIZE;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// A real memory region acting as guest-physical memory.
pub struct HostMemory {
    base: *mut u8,
    size: usize,
    /// Bit-per-page commit map (1 = committed / resident).
    committed: Vec<AtomicU64>,
    committed_pages: AtomicU64,
    /// Cumulative counters for metrics.
    total_commits: AtomicU64,
    total_discards: AtomicU64,
}

// SAFETY: the raw region pointer is only dereferenced through the methods
// below, which either take page-granular ownership by protocol (each page is
// owned by exactly one allocator client) or copy in/out.
unsafe impl Send for HostMemory {}
unsafe impl Sync for HostMemory {}

impl HostMemory {
    /// Map a new guest-physical region of `size` bytes (rounded up to 4 MiB
    /// so buddy blocks stay 4 MiB-aligned relative to the base).
    pub fn new(size: usize) -> Result<Self> {
        let size = crate::util::align_up(size as u64, crate::BLOCK_SIZE as u64) as usize;
        // SAFETY: plain anonymous mapping; checked for MAP_FAILED below.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                size,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_NORESERVE,
                -1,
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!(
                "mmap of {size} bytes failed: {}",
                std::io::Error::last_os_error()
            );
        }
        let pages = size / PAGE_SIZE;
        let words = pages.div_ceil(64);
        let mut committed = Vec::with_capacity(words);
        committed.resize_with(words, || AtomicU64::new(0));
        Ok(Self {
            base: ptr as *mut u8,
            size,
            committed,
            committed_pages: AtomicU64::new(0),
            total_commits: AtomicU64::new(0),
            total_discards: AtomicU64::new(0),
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn pages(&self) -> u64 {
        (self.size / PAGE_SIZE) as u64
    }

    fn check(&self, gpa: Gpa) -> Result<()> {
        if !gpa.is_page_aligned() {
            bail!("{gpa:?} not page aligned");
        }
        if gpa.0 as usize + PAGE_SIZE > self.size {
            bail!("{gpa:?} out of range (region {} bytes)", self.size);
        }
        Ok(())
    }

    /// Raw pointer to the backing host page. Caller must own the page per
    /// the allocator protocol.
    #[inline]
    pub fn page_ptr(&self, gpa: Gpa) -> *mut u8 {
        debug_assert!(self.check(gpa).is_ok());
        // SAFETY: bounds checked in debug; offset within the mapping.
        unsafe { self.base.add(gpa.0 as usize) }
    }

    #[inline]
    fn bit(&self, gpa: Gpa) -> (usize, u64) {
        let page = gpa.page_index();
        ((page / 64) as usize, 1u64 << (page % 64))
    }

    /// Mark a page committed (host would do this on the first touch fault).
    /// Returns true if the page transitioned from uncommitted.
    pub fn note_commit(&self, gpa: Gpa) -> bool {
        let (w, m) = self.bit(gpa);
        let prev = self.committed[w].fetch_or(m, Ordering::Relaxed);
        if prev & m == 0 {
            self.committed_pages.fetch_add(1, Ordering::Relaxed);
            self.total_commits.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    pub fn is_committed(&self, gpa: Gpa) -> bool {
        let (w, m) = self.bit(gpa);
        self.committed[w].load(Ordering::Relaxed) & m != 0
    }

    /// Write a full page (commits it).
    pub fn write_page(&self, gpa: Gpa, data: &[u8]) -> Result<()> {
        self.check(gpa)?;
        if data.len() != PAGE_SIZE {
            bail!("write_page needs exactly one page of data");
        }
        // SAFETY: in-bounds per check; page ownership per allocator protocol.
        unsafe {
            std::ptr::copy_nonoverlapping(data.as_ptr(), self.page_ptr(gpa), PAGE_SIZE);
        }
        self.note_commit(gpa);
        Ok(())
    }

    /// Read a full page.
    pub fn read_page(&self, gpa: Gpa, out: &mut [u8]) -> Result<()> {
        self.check(gpa)?;
        if out.len() != PAGE_SIZE {
            bail!("read_page needs exactly one page of buffer");
        }
        // SAFETY: in-bounds per check.
        unsafe {
            std::ptr::copy_nonoverlapping(self.page_ptr(gpa), out.as_mut_ptr(), PAGE_SIZE);
        }
        Ok(())
    }

    /// Fill a page with a deterministic pattern derived from `seed` — the
    /// "application writes its data" stand-in, verifiable after a swap
    /// round-trip via [`Self::checksum_page`].
    pub fn fill_page(&self, gpa: Gpa, seed: u64) -> Result<()> {
        self.check(gpa)?;
        let ptr = self.page_ptr(gpa) as *mut u64;
        let mut x = seed ^ 0x9E37_79B9_7F4A_7C15;
        // SAFETY: page-aligned, in-bounds, u64-aligned (page base).
        unsafe {
            for i in 0..(PAGE_SIZE / 8) {
                x = x
                    .wrapping_mul(0x2545_F491_4F6C_DD1D)
                    .wrapping_add(i as u64);
                ptr.add(i).write(x);
            }
        }
        self.note_commit(gpa);
        Ok(())
    }

    /// Checksum of the page contents (FNV-1a over u64 words).
    pub fn checksum_page(&self, gpa: Gpa) -> Result<u64> {
        self.check(gpa)?;
        let ptr = self.page_ptr(gpa) as *const u64;
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        // SAFETY: in-bounds per check.
        unsafe {
            for i in 0..(PAGE_SIZE / 8) {
                h ^= ptr.add(i).read();
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        Ok(h)
    }

    /// Touch a page lightly (one cache line) — models an access without the
    /// cost of a full-page write. Commits the page.
    pub fn touch_page(&self, gpa: Gpa) -> Result<()> {
        self.check(gpa)?;
        let ptr = self.page_ptr(gpa);
        // SAFETY: in-bounds per check.
        unsafe {
            let v = ptr.read_volatile();
            ptr.write_volatile(v.wrapping_add(1));
        }
        self.note_commit(gpa);
        Ok(())
    }

    /// Return pages to the host with a **real** `madvise(MADV_DONTNEED)`.
    /// Subsequent access observes zero-fill-on-demand, exactly as §3.3
    /// describes. `pages` need not be contiguous; contiguous runs are
    /// coalesced into single madvise calls.
    pub fn discard_pages(&self, pages: &[Gpa]) -> Result<u64> {
        if pages.is_empty() {
            return Ok(0);
        }
        let mut sorted: Vec<Gpa> = pages.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        let mut discarded = 0u64;
        let mut run_start = sorted[0];
        let mut run_len = 1usize;
        let flush = |start: Gpa, len: usize| -> Result<()> {
            self.check(start)?;
            // SAFETY: range checked; DONTNEED on our own anonymous mapping.
            let rc = unsafe {
                libc::madvise(
                    self.page_ptr(start) as *mut libc::c_void,
                    len * PAGE_SIZE,
                    libc::MADV_DONTNEED,
                )
            };
            if rc != 0 {
                bail!("madvise failed: {}", std::io::Error::last_os_error());
            }
            Ok(())
        };
        for &gpa in &sorted[1..] {
            if gpa.0 == run_start.0 + (run_len * PAGE_SIZE) as u64 {
                run_len += 1;
            } else {
                flush(run_start, run_len)?;
                discarded += self.clear_committed_run(run_start, run_len);
                run_start = gpa;
                run_len = 1;
            }
        }
        flush(run_start, run_len)?;
        discarded += self.clear_committed_run(run_start, run_len);
        self.total_discards.fetch_add(discarded, Ordering::Relaxed);
        Ok(discarded)
    }

    fn clear_committed_run(&self, start: Gpa, len: usize) -> u64 {
        let mut cleared = 0;
        for i in 0..len {
            let gpa = Gpa(start.0 + (i * PAGE_SIZE) as u64);
            let (w, m) = self.bit(gpa);
            let prev = self.committed[w].fetch_and(!m, Ordering::Relaxed);
            if prev & m != 0 {
                cleared += 1;
            }
        }
        self.committed_pages.fetch_sub(cleared, Ordering::Relaxed);
        cleared
    }

    /// Currently committed bytes (the host-resident footprint).
    pub fn committed_bytes(&self) -> u64 {
        self.committed_pages.load(Ordering::Relaxed) * PAGE_SIZE as u64
    }

    pub fn committed_pages(&self) -> u64 {
        self.committed_pages.load(Ordering::Relaxed)
    }

    /// (cumulative commits, cumulative discards) — metrics counters.
    pub fn commit_stats(&self) -> (u64, u64) {
        (
            self.total_commits.load(Ordering::Relaxed),
            self.total_discards.load(Ordering::Relaxed),
        )
    }

    /// Resident-set size of a page range as the *real* kernel sees it, via
    /// `mincore(2)`. Used by an integration test to cross-check our commit
    /// accounting against the actual host kernel.
    pub fn mincore_resident_pages(&self, start: Gpa, pages: usize) -> Result<u64> {
        self.check(start)?;
        let mut vec = vec![0u8; pages];
        // SAFETY: range is in-bounds; vec sized to `pages`.
        let rc = unsafe {
            libc::mincore(
                self.page_ptr(start) as *mut libc::c_void,
                pages * PAGE_SIZE,
                vec.as_mut_ptr(),
            )
        };
        if rc != 0 {
            bail!("mincore failed: {}", std::io::Error::last_os_error());
        }
        Ok(vec.iter().filter(|&&b| b & 1 != 0).count() as u64)
    }
}

impl Drop for HostMemory {
    fn drop(&mut self) {
        // SAFETY: exact mapping created in `new`.
        unsafe {
            libc::munmap(self.base as *mut libc::c_void, self.size);
        }
    }
}

/// Convenience: build a small region for tests.
pub fn test_region(mib: usize) -> HostMemory {
    HostMemory::new(mib << 20).context("test region").unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_accounting() {
        let m = test_region(8);
        assert_eq!(m.committed_bytes(), 0);
        m.fill_page(Gpa(0), 1).unwrap();
        m.fill_page(Gpa(4096), 2).unwrap();
        m.fill_page(Gpa(4096), 3).unwrap(); // re-commit is idempotent
        assert_eq!(m.committed_pages(), 2);
    }

    #[test]
    fn fill_checksum_deterministic() {
        let m = test_region(8);
        m.fill_page(Gpa(0), 42).unwrap();
        m.fill_page(Gpa(4096), 42).unwrap();
        assert_eq!(
            m.checksum_page(Gpa(0)).unwrap(),
            m.checksum_page(Gpa(4096)).unwrap()
        );
        m.fill_page(Gpa(4096), 43).unwrap();
        assert_ne!(
            m.checksum_page(Gpa(0)).unwrap(),
            m.checksum_page(Gpa(4096)).unwrap()
        );
    }

    #[test]
    fn discard_zero_fills() {
        let m = test_region(8);
        m.fill_page(Gpa(0), 7).unwrap();
        let zero_sum = {
            let z = test_region(4);
            z.touch_page(Gpa(0)).unwrap();
            // a page of zeros with one increment at byte 0
            z.checksum_page(Gpa(0)).unwrap()
        };
        m.discard_pages(&[Gpa(0)]).unwrap();
        assert_eq!(m.committed_pages(), 0);
        // Reading the discarded page sees zeros (zero-fill-on-demand).
        m.touch_page(Gpa(0)).unwrap();
        assert_eq!(m.checksum_page(Gpa(0)).unwrap(), zero_sum);
        assert_eq!(m.committed_pages(), 1);
    }

    #[test]
    fn discard_coalesces_runs_and_dedups() {
        let m = test_region(16);
        let pages: Vec<Gpa> = (0..100).map(|i| Gpa(i * 4096)).collect();
        for &p in &pages {
            m.fill_page(p, p.0).unwrap();
        }
        let mut with_dup = pages.clone();
        with_dup.push(Gpa(0));
        let n = m.discard_pages(&with_dup).unwrap();
        assert_eq!(n, 100);
        assert_eq!(m.committed_pages(), 0);
    }

    #[test]
    fn round_trip_page_io() {
        let m = test_region(4);
        let data = vec![0xABu8; PAGE_SIZE];
        m.write_page(Gpa(8192), &data).unwrap();
        let mut out = vec![0u8; PAGE_SIZE];
        m.read_page(Gpa(8192), &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn out_of_range_rejected() {
        let m = HostMemory::new(4 << 20).unwrap();
        assert!(m.fill_page(Gpa((4 << 20) as u64), 0).is_err());
        assert!(m.fill_page(Gpa(123), 0).is_err()); // unaligned
    }

    #[test]
    fn mincore_matches_after_touch() {
        let m = test_region(8);
        for i in 0..10 {
            m.fill_page(Gpa(i * 4096), i).unwrap();
        }
        let resident = m.mincore_resident_pages(Gpa(0), 10).unwrap();
        assert_eq!(resident, 10);
        m.discard_pages(&(0..10).map(|i| Gpa(i * 4096)).collect::<Vec<_>>())
            .unwrap();
        let resident = m.mincore_resident_pages(Gpa(0), 10).unwrap();
        assert_eq!(resident, 0, "madvise(DONTNEED) must drop residency");
    }
}
