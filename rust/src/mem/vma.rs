//! Guest virtual address space: VMAs + the per-process page table.
//!
//! Mirrors the paper's split (§3.3): `sys_brk`/`sys_mmap` only create
//! address ranges; pages are committed lazily by the page-fault handler
//! (which allocates from the Bitmap Page Allocator). The fault *policy*
//! lives in the container layer; this module owns the address-space
//! bookkeeping.

use super::mmap_file::FileId;
use super::page_table::PageTable;
use super::Gva;
use crate::PAGE_SIZE;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// What backs a VMA.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VmaKind {
    /// Anonymous memory (heap, stacks, arenas).
    Anon,
    /// File-backed mapping; `shared` follows the §3.5 sharing policy.
    File {
        file: FileId,
        /// File offset (bytes) of the mapping start.
        offset: u64,
        shared: bool,
    },
}

/// A virtual memory area.
#[derive(Clone, Debug)]
pub struct Vma {
    pub start: u64,
    pub len: u64,
    pub kind: VmaKind,
    /// Debug label ("heap", "node-binary", ...).
    pub name: String,
}

impl Vma {
    pub fn end(&self) -> u64 {
        self.start + self.len
    }

    pub fn contains(&self, gva: Gva) -> bool {
        (self.start..self.end()).contains(&gva.0)
    }

    pub fn pages(&self) -> u64 {
        self.len / PAGE_SIZE as u64
    }

    /// File page number backing `gva` (for file VMAs).
    pub fn file_page(&self, gva: Gva) -> Option<(FileId, u64)> {
        match &self.kind {
            VmaKind::File { file, offset, .. } => {
                Some((*file, (offset + (gva.0 - self.start)) / PAGE_SIZE as u64))
            }
            VmaKind::Anon => None,
        }
    }
}

/// Base of the mmap arena (leaves low addresses for brk-style heaps).
const MMAP_BASE: u64 = 0x10_0000_0000; // 64 GiB
/// Guard gap between mappings.
const GUARD: u64 = 16 * PAGE_SIZE as u64;

/// A guest process's address space: VMAs + page table.
pub struct AddressSpace {
    vmas: BTreeMap<u64, Vma>,
    next_mmap: u64,
    pub pt: PageTable,
}

impl Default for AddressSpace {
    fn default() -> Self {
        Self::new()
    }
}

impl AddressSpace {
    pub fn new() -> Self {
        Self {
            vmas: BTreeMap::new(),
            next_mmap: MMAP_BASE,
            pt: PageTable::new(),
        }
    }

    fn place(&mut self, len: u64) -> u64 {
        let start = self.next_mmap;
        self.next_mmap += len + GUARD;
        start
    }

    /// `sys_mmap(MAP_ANONYMOUS)`: reserve address space only.
    pub fn mmap_anon(&mut self, len: u64, name: &str) -> Result<Gva> {
        if len == 0 || len % PAGE_SIZE as u64 != 0 {
            bail!("anon mmap length must be a positive multiple of the page size");
        }
        let start = self.place(len);
        self.vmas.insert(
            start,
            Vma {
                start,
                len,
                kind: VmaKind::Anon,
                name: name.to_string(),
            },
        );
        Ok(Gva(start))
    }

    /// `sys_mmap(fd)`: map `len` bytes of `file` at `offset`.
    pub fn mmap_file(
        &mut self,
        file: FileId,
        offset: u64,
        len: u64,
        shared: bool,
        name: &str,
    ) -> Result<Gva> {
        if len == 0 || len % PAGE_SIZE as u64 != 0 || offset % PAGE_SIZE as u64 != 0 {
            bail!("file mmap length/offset must be page aligned, len > 0");
        }
        let start = self.place(len);
        self.vmas.insert(
            start,
            Vma {
                start,
                len,
                kind: VmaKind::File {
                    file,
                    offset,
                    shared,
                },
                name: name.to_string(),
            },
        );
        Ok(Gva(start))
    }

    /// Find the VMA containing `gva`.
    pub fn find_vma(&self, gva: Gva) -> Option<&Vma> {
        self.vmas
            .range(..=gva.0)
            .next_back()
            .map(|(_, v)| v)
            .filter(|v| v.contains(gva))
    }

    /// Remove a VMA by start address, returning it. PTEs for its range must
    /// be torn down by the caller (which owns the physical-page policy).
    pub fn remove_vma(&mut self, start: Gva) -> Option<Vma> {
        self.vmas.remove(&start.0)
    }

    pub fn iter_vmas(&self) -> impl Iterator<Item = &Vma> {
        self.vmas.values()
    }

    pub fn vma_count(&self) -> usize {
        self.vmas.len()
    }

    /// Total reserved address space (bytes).
    pub fn reserved_bytes(&self) -> u64 {
        self.vmas.values().map(|v| v.len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mmap_places_disjoint_regions() {
        let mut asp = AddressSpace::new();
        let a = asp.mmap_anon(64 * 4096, "heap").unwrap();
        let b = asp.mmap_anon(64 * 4096, "arena").unwrap();
        assert!(b.0 >= a.0 + 64 * 4096 + GUARD);
        assert_eq!(asp.vma_count(), 2);
        assert_eq!(asp.reserved_bytes(), 2 * 64 * 4096);
    }

    #[test]
    fn find_vma_hits_and_misses() {
        let mut asp = AddressSpace::new();
        let a = asp.mmap_anon(4 * 4096, "x").unwrap();
        assert!(asp.find_vma(a).is_some());
        assert!(asp.find_vma(Gva(a.0 + 3 * 4096)).is_some());
        assert!(asp.find_vma(Gva(a.0 + 4 * 4096)).is_none(), "end exclusive");
        assert!(asp.find_vma(Gva(0)).is_none());
    }

    #[test]
    fn file_page_mapping() {
        let mut asp = AddressSpace::new();
        let f = FileId(3);
        let base = asp
            .mmap_file(f, 8 * 4096, 4 * 4096, true, "bin")
            .unwrap();
        let vma = asp.find_vma(base).unwrap().clone();
        assert_eq!(vma.file_page(base), Some((f, 8)));
        assert_eq!(vma.file_page(Gva(base.0 + 2 * 4096)), Some((f, 10)));
    }

    #[test]
    fn rejects_unaligned() {
        let mut asp = AddressSpace::new();
        assert!(asp.mmap_anon(100, "bad").is_err());
        assert!(asp.mmap_file(FileId(0), 1, 4096, true, "bad").is_err());
        assert!(asp.mmap_anon(0, "zero").is_err());
    }

    #[test]
    fn remove_vma() {
        let mut asp = AddressSpace::new();
        let a = asp.mmap_anon(4096, "x").unwrap();
        assert!(asp.remove_vma(a).is_some());
        assert!(asp.find_vma(a).is_none());
        assert!(asp.remove_vma(a).is_none());
    }
}
