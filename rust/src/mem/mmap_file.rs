//! File-backed mmap memory and cross-sandbox sharing (§3.5).
//!
//! The paper distinguishes two classes of shareable file-backed memory:
//! the **secure-container-runtime binary** (safe to share; RunD does this in
//! production) and **language-runtime binaries** (cross-tenant side-channel
//! risk — not shared by default; the §3.5 ablation shows sharing Node.js
//! pages cuts hibernate wake latency 25 ms → 11 ms).
//!
//! [`FileRegistry`] models the container image's files (name, size, content
//! seed, class). [`FilePageCache`] is the host page cache: file pages are
//! materialized once, shared by every sandbox whose policy allows it, and
//! kept (mapcount 0) after unmap until the reclaim manager trims them —
//! which is what makes re-mapping warm and deflation step #4 meaningful.

use super::bitmap_alloc::BitmapPageAllocator;
use super::Gpa;
use crate::PAGE_SIZE;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Identifies a registered virtual file.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct FileId(pub u32);

/// Security class of a file-backed mapping (§3.5).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum FileClass {
    /// Secure container runtime binary (qkernel/qvisor) — shared.
    QuarkRuntime,
    /// Language runtime binary (node, python, JVM...) — private by default.
    LanguageRuntime,
    /// Application data files.
    AppData,
}

/// A file in the (virtual) container image.
#[derive(Clone, Debug)]
pub struct VirtualFile {
    pub id: FileId,
    pub name: String,
    pub size: u64,
    /// Deterministic content generator seed (content = f(seed, page_no)).
    pub content_seed: u64,
    pub class: FileClass,
}

impl VirtualFile {
    pub fn pages(&self) -> u64 {
        self.size.div_ceil(PAGE_SIZE as u64)
    }
}

/// Registry of all virtual files known to the platform.
#[derive(Default)]
pub struct FileRegistry {
    files: Mutex<Vec<VirtualFile>>,
}

impl FileRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, name: &str, size: u64, class: FileClass) -> FileId {
        let mut files = self.files.lock().unwrap();
        let id = FileId(files.len() as u32);
        // Content seed derives from the name so identical images share bytes.
        let mut seed = 0xcbf2_9ce4_8422_2325u64;
        for b in name.bytes() {
            seed ^= b as u64;
            seed = seed.wrapping_mul(0x1000_0000_01b3);
        }
        files.push(VirtualFile {
            id,
            name: name.to_string(),
            size,
            content_seed: seed,
            class,
        });
        id
    }

    pub fn get(&self, id: FileId) -> VirtualFile {
        self.files.lock().unwrap()[id.0 as usize].clone()
    }

    /// Look up a file by name (images of the same language share binaries).
    pub fn find_by_name(&self, name: &str) -> Option<VirtualFile> {
        self.files
            .lock()
            .unwrap()
            .iter()
            .find(|f| f.name == name)
            .cloned()
    }

    /// Register if absent, return the existing file otherwise.
    pub fn get_or_register(&self, name: &str, size: u64, class: FileClass) -> FileId {
        if let Some(f) = self.find_by_name(name) {
            return f.id;
        }
        self.register(name, size, class)
    }
}

struct CachedPage {
    gpa: Gpa,
    /// Number of sandboxes currently mapping this page.
    mappers: u32,
}

/// Host page-cache stats.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub cached_pages: u64,
    pub mapped_pages: u64,
    pub hits: u64,
    pub misses: u64,
}

/// The host page cache for file-backed mappings.
pub struct FilePageCache {
    alloc: Arc<BitmapPageAllocator>,
    inner: Mutex<CacheInner>,
}

#[derive(Default)]
struct CacheInner {
    pages: HashMap<(FileId, u64), CachedPage>,
    hits: u64,
    misses: u64,
}

impl FilePageCache {
    pub fn new(alloc: Arc<BitmapPageAllocator>) -> Self {
        Self {
            alloc,
            inner: Mutex::new(CacheInner::default()),
        }
    }

    /// Map one page of `file` shared: returns (gpa, hit) where `hit` means
    /// the page was already resident (no disk load, no content fill).
    pub fn map_shared(&self, file: &VirtualFile, page_no: u64) -> Result<(Gpa, bool)> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(p) = inner.pages.get_mut(&(file.id, page_no)) {
            p.mappers += 1;
            let gpa = p.gpa;
            inner.hits += 1;
            return Ok((gpa, true));
        }
        let gpa = self.alloc.alloc_page()?;
        self.fill(file, page_no, gpa)?;
        inner.pages.insert(
            (file.id, page_no),
            CachedPage { gpa, mappers: 1 },
        );
        inner.misses += 1;
        Ok((gpa, false))
    }

    /// Map one page privately (sharing disallowed by policy): always a fresh
    /// copy owned by the caller, never cached.
    pub fn map_private(&self, file: &VirtualFile, page_no: u64) -> Result<Gpa> {
        self.map_private_for(file, page_no, &self.alloc)
    }

    /// Private copy allocated from the *caller's* allocator (a sandbox's own
    /// QKernel allocator), so the page is reclaimed with the sandbox.
    pub fn map_private_for(
        &self,
        file: &VirtualFile,
        page_no: u64,
        alloc: &BitmapPageAllocator,
    ) -> Result<Gpa> {
        let gpa = alloc.alloc_page()?;
        alloc
            .host()
            .fill_page(gpa, file.content_seed ^ page_no.wrapping_mul(0x9E37_79B9))?;
        self.inner.lock().unwrap().misses += 1;
        Ok(gpa)
    }

    fn fill(&self, file: &VirtualFile, page_no: u64, gpa: Gpa) -> Result<()> {
        // Deterministic, verifiable "file contents".
        self.alloc
            .host()
            .fill_page(gpa, file.content_seed ^ page_no.wrapping_mul(0x9E37_79B9))
    }

    /// Drop one sandbox's shared mapping. The page stays cached (mapcount 0)
    /// until [`Self::trim_unmapped`] — this is what keeps re-warm fast.
    pub fn unmap_shared(&self, file_id: FileId, page_no: u64) {
        let mut inner = self.inner.lock().unwrap();
        let p = inner
            .pages
            .get_mut(&(file_id, page_no))
            .expect("unmap of unmapped file page");
        assert!(p.mappers > 0, "mapcount underflow");
        p.mappers -= 1;
    }

    /// How many sandboxes map this page right now (PSS denominator).
    pub fn mapcount(&self, file_id: FileId, page_no: u64) -> u32 {
        self.inner
            .lock()
            .unwrap()
            .pages
            .get(&(file_id, page_no))
            .map(|p| p.mappers)
            .unwrap_or(0)
    }

    /// Reverse lookup for PSS: gpa → mapcount. O(n) over cache; PSS is an
    /// offline metric so a scan is fine.
    pub fn mapcount_by_gpa(&self, gpa: Gpa) -> Option<u32> {
        let inner = self.inner.lock().unwrap();
        inner
            .pages
            .values()
            .find(|p| p.gpa == gpa)
            .map(|p| p.mappers)
    }

    /// Deflation step #4 support / memory pressure: free every cached page
    /// no sandbox maps. Returns pages freed (their host memory is reclaimed
    /// by the allocator's madvise pass).
    pub fn trim_unmapped(&self) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let victims: Vec<(FileId, u64)> = inner
            .pages
            .iter()
            .filter(|(_, p)| p.mappers == 0)
            .map(|(&k, _)| k)
            .collect();
        let n = victims.len() as u64;
        for k in victims {
            let p = inner.pages.remove(&k).unwrap();
            self.alloc.dec_ref(p.gpa);
        }
        n
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            cached_pages: inner.pages.len() as u64,
            mapped_pages: inner.pages.values().filter(|p| p.mappers > 0).count() as u64,
            hits: inner.hits,
            misses: inner.misses,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::buddy::BuddyAllocator;
    use crate::mem::host::test_region;
    use crate::mem::host::HostMemory;

    fn mk() -> (Arc<HostMemory>, Arc<BitmapPageAllocator>, FilePageCache, FileRegistry) {
        let host = Arc::new(test_region(32));
        let len = host.size() as u64;
        let heap = Arc::new(BuddyAllocator::new(host.clone(), 0, len).unwrap());
        let alloc = Arc::new(BitmapPageAllocator::new(host.clone(), heap));
        let cache = FilePageCache::new(alloc.clone());
        (host, alloc, cache, FileRegistry::new())
    }

    #[test]
    fn shared_mapping_reuses_page() {
        let (_h, _a, cache, reg) = mk();
        let f = reg.get(reg.register("node", 1 << 20, FileClass::LanguageRuntime));
        let (g1, hit1) = cache.map_shared(&f, 0).unwrap();
        let (g2, hit2) = cache.map_shared(&f, 0).unwrap();
        assert_eq!(g1, g2);
        assert!(!hit1);
        assert!(hit2);
        assert_eq!(cache.mapcount(f.id, 0), 2);
    }

    #[test]
    fn private_mapping_copies() {
        let (host, _a, cache, reg) = mk();
        let f = reg.get(reg.register("python", 1 << 20, FileClass::LanguageRuntime));
        let g1 = cache.map_private(&f, 3).unwrap();
        let g2 = cache.map_private(&f, 3).unwrap();
        assert_ne!(g1, g2, "private mappings are distinct pages");
        // ... with identical contents.
        assert_eq!(
            host.checksum_page(g1).unwrap(),
            host.checksum_page(g2).unwrap()
        );
        assert_eq!(cache.mapcount(f.id, 3), 0);
    }

    #[test]
    fn unmap_keeps_page_cached_until_trim() {
        let (_h, alloc, cache, reg) = mk();
        let f = reg.get(reg.register("quark", 1 << 20, FileClass::QuarkRuntime));
        let (g1, _) = cache.map_shared(&f, 5).unwrap();
        cache.unmap_shared(f.id, 5);
        assert_eq!(cache.mapcount(f.id, 5), 0);
        assert_eq!(cache.stats().cached_pages, 1, "still cached");
        // Re-map is a hit on the same page.
        let (g2, hit) = cache.map_shared(&f, 5).unwrap();
        assert_eq!(g1, g2);
        assert!(hit);
        cache.unmap_shared(f.id, 5);
        let trimmed = cache.trim_unmapped();
        assert_eq!(trimmed, 1);
        assert_eq!(cache.stats().cached_pages, 0);
        assert_eq!(alloc.stats().allocated_pages, 0, "page returned to allocator");
    }

    #[test]
    fn trim_spares_mapped_pages() {
        let (_h, _a, cache, reg) = mk();
        let f = reg.get(reg.register("quark", 1 << 20, FileClass::QuarkRuntime));
        cache.map_shared(&f, 0).unwrap();
        cache.map_shared(&f, 1).unwrap();
        cache.unmap_shared(f.id, 1);
        assert_eq!(cache.trim_unmapped(), 1);
        assert_eq!(cache.mapcount(f.id, 0), 1);
        assert_eq!(cache.stats().cached_pages, 1);
    }

    #[test]
    fn file_content_deterministic_across_caches() {
        let (h1, _a1, c1, r1) = mk();
        let (h2, _a2, c2, r2) = mk();
        let f1 = r1.get(r1.register("same-name", 1 << 20, FileClass::AppData));
        let f2 = r2.get(r2.register("same-name", 1 << 20, FileClass::AppData));
        let (g1, _) = c1.map_shared(&f1, 9).unwrap();
        let (g2, _) = c2.map_shared(&f2, 9).unwrap();
        assert_eq!(
            h1.checksum_page(g1).unwrap(),
            h2.checksum_page(g2).unwrap(),
            "same file name+page → same bytes"
        );
    }

    #[test]
    fn registry_pages_rounding() {
        let reg = FileRegistry::new();
        let f = reg.get(reg.register("x", 4097, FileClass::AppData));
        assert_eq!(f.pages(), 2);
    }
}
