//! Binary buddy allocator — Quark's original global heap and the baseline
//! the Bitmap Page Allocator replaces (§3.3).
//!
//! Faithful to the property the paper's argument hinges on: the free lists
//! are **intrusive** — each free chunk stores `{magic|order, next}` in its
//! own first 16 bytes. That is exactly why naive `madvise(MADV_DONTNEED)`
//! reclamation corrupts it: the kernel zero-fills the page, the "next"
//! pointer is gone, the list is broken (demonstrated by
//! `reclaim_breaks_intrusive_free_list` below and benchmarked in
//! `micro_allocator`).
//!
//! The allocator serves two roles here:
//! 1. the **global heap** that hands 4 MiB blocks to the Bitmap Page
//!    Allocator ("the Bitmap Page Allocator allocates another 4MB memory
//!    block from the global heap, i.e. the global binary buddy allocator");
//! 2. the **baseline** in the reclamation comparison bench.

use super::{host::HostMemory, Gpa};
use crate::PAGE_SIZE;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::sync::Mutex;

/// Magic tag stored in free-chunk headers (low byte carries the order).
const MAGIC: u64 = 0xB0DD_1E5F_EE11_5700;
const MAGIC_MASK: u64 = 0xFFFF_FFFF_FFFF_FF00;
/// Null link.
const NIL: u64 = u64::MAX;

/// Order of a 4 MiB block when the unit is a 4 KiB page: 2^10 pages.
pub const BLOCK_ORDER: usize = 10;

#[derive(Debug, thiserror::Error)]
pub enum BuddyError {
    #[error("buddy free list corrupted at {gpa:#x}: header {found:#x} (expected magic {expected:#x}) — \
             this is the §3.3 failure mode: zero-fill reclaim destroyed an intrusive free-list node")]
    Corrupted { gpa: u64, found: u64, expected: u64 },
    #[error("out of memory: no free chunk of order {0}")]
    OutOfMemory(usize),
    #[error("free of unallocated chunk {0:#x}")]
    BadFree(u64),
}

struct Inner {
    /// Head gpa of the intrusive free list per order.
    free_heads: Vec<u64>,
    /// Merge index: free chunk gpa → order. (The kernel keeps equivalent
    /// state in struct page; the intrusive list alone cannot support O(1)
    /// buddy lookup.)
    free_index: HashMap<u64, u8>,
    /// Allocated chunk gpa → order, for free() validation.
    allocated: HashMap<u64, u8>,
    allocated_bytes: u64,
}

/// The buddy allocator over a `[base, base+len)` slice of the host region.
pub struct BuddyAllocator {
    host: Arc<HostMemory>,
    base: u64,
    #[allow(dead_code)] // part of the managed-range contract; used in asserts
    len: u64,
    max_order: usize,
    inner: Mutex<Inner>,
}

impl BuddyAllocator {
    /// Manage `[base, base+len)` of `host`. `base` must be 4 MiB-aligned so
    /// that order-10 chunks are 4 MiB-aligned (the Bitmap Page Allocator
    /// relies on block alignment for control-page masking).
    pub fn new(host: Arc<HostMemory>, base: u64, len: u64) -> Result<Self> {
        if base % crate::BLOCK_SIZE as u64 != 0 {
            bail!("buddy base must be 4MiB-aligned");
        }
        if base + len > host.size() as u64 {
            bail!("buddy range exceeds host region");
        }
        let max_order = (63 - (len / PAGE_SIZE as u64).leading_zeros() as usize).max(BLOCK_ORDER);
        let alloc = Self {
            host,
            base,
            len,
            max_order,
            inner: Mutex::new(Inner {
                free_heads: vec![NIL; max_order + 1],
                free_index: HashMap::new(),
                allocated: HashMap::new(),
                allocated_bytes: 0,
            }),
        };
        {
            // Carve the region greedily into maximal power-of-two chunks.
            let mut inner = alloc.inner.lock().unwrap();
            let mut off = base;
            let end = base + crate::util::align_down(len, PAGE_SIZE as u64);
            while off < end {
                let align_order = if off == 0 {
                    alloc.max_order
                } else {
                    ((off / PAGE_SIZE as u64).trailing_zeros() as usize).min(alloc.max_order)
                };
                let mut order = align_order;
                while off + Self::order_bytes(order) > end {
                    order -= 1;
                }
                alloc.push_free(&mut inner, Gpa(off), order);
                off += Self::order_bytes(order);
            }
        }
        Ok(alloc)
    }

    #[inline]
    pub fn order_bytes(order: usize) -> u64 {
        (PAGE_SIZE as u64) << order
    }

    /// Smallest order whose chunk holds `bytes`.
    pub fn order_for(bytes: u64) -> usize {
        let pages = bytes.div_ceil(PAGE_SIZE as u64).max(1);
        (64 - (pages - 1).leading_zeros() as usize).min(63)
    }

    fn read_header(&self, gpa: Gpa) -> (u64, u64) {
        let p = self.host.page_ptr(gpa) as *const u64;
        // SAFETY: chunk is owned by the allocator; header is in-bounds.
        unsafe { (p.read(), p.add(1).read()) }
    }

    fn write_header(&self, gpa: Gpa, order: usize, next: u64) {
        let p = self.host.page_ptr(gpa) as *mut u64;
        // SAFETY: chunk owned by the allocator.
        unsafe {
            p.write(MAGIC | order as u64);
            p.add(1).write(next);
        }
        // Writing the header commits the page — the kernel-heap metadata
        // footprint the paper's design keeps out of the data pages.
        self.host.note_commit(gpa);
    }

    fn push_free(&self, inner: &mut Inner, gpa: Gpa, order: usize) {
        self.write_header(gpa, order, inner.free_heads[order]);
        inner.free_heads[order] = gpa.0;
        inner.free_index.insert(gpa.0, order as u8);
    }

    /// Pop the head of the order's free list, verifying the intrusive header.
    fn pop_free(&self, inner: &mut Inner, order: usize) -> Result<Option<Gpa>, BuddyError> {
        let head = inner.free_heads[order];
        if head == NIL {
            return Ok(None);
        }
        let gpa = Gpa(head);
        let (tag, next) = self.read_header(gpa);
        if tag & MAGIC_MASK != MAGIC || (tag & 0xFF) as usize != order {
            return Err(BuddyError::Corrupted {
                gpa: head,
                found: tag,
                expected: MAGIC | order as u64,
            });
        }
        inner.free_heads[order] = next;
        inner.free_index.remove(&head);
        Ok(Some(gpa))
    }

    /// Unlink a specific chunk (buddy merge path) by walking the list.
    fn unlink(&self, inner: &mut Inner, gpa: Gpa, order: usize) -> Result<(), BuddyError> {
        let mut prev: Option<u64> = None;
        let mut cur = inner.free_heads[order];
        while cur != NIL {
            let (tag, next) = self.read_header(Gpa(cur));
            if tag & MAGIC_MASK != MAGIC || (tag & 0xFF) as usize != order {
                return Err(BuddyError::Corrupted {
                    gpa: cur,
                    found: tag,
                    expected: MAGIC | order as u64,
                });
            }
            if cur == gpa.0 {
                match prev {
                    None => inner.free_heads[order] = next,
                    Some(p) => {
                        let (ptag, _) = self.read_header(Gpa(p));
                        debug_assert_eq!(ptag & MAGIC_MASK, MAGIC);
                        let ptr = self.host.page_ptr(Gpa(p)) as *mut u64;
                        // SAFETY: owned free chunk header.
                        unsafe { ptr.add(1).write(next) };
                    }
                }
                inner.free_index.remove(&gpa.0);
                return Ok(());
            }
            prev = Some(cur);
            cur = next;
        }
        Err(BuddyError::BadFree(gpa.0))
    }

    /// Allocate a chunk of the given order.
    pub fn alloc_order(&self, order: usize) -> Result<Gpa, BuddyError> {
        let mut inner = self.inner.lock().unwrap();
        if order > self.max_order {
            return Err(BuddyError::OutOfMemory(order));
        }
        // Find the smallest populated order ≥ requested.
        let mut o = order;
        let gpa = loop {
            if o > self.max_order {
                return Err(BuddyError::OutOfMemory(order));
            }
            if let Some(gpa) = self.pop_free(&mut inner, o)? {
                break gpa;
            }
            o += 1;
        };
        // Split down, freeing the upper halves.
        while o > order {
            o -= 1;
            let upper = Gpa(gpa.0 + Self::order_bytes(o));
            self.push_free(&mut inner, upper, o);
        }
        inner.allocated.insert(gpa.0, order as u8);
        inner.allocated_bytes += Self::order_bytes(order);
        Ok(gpa)
    }

    /// Allocate at least `bytes`.
    pub fn alloc_bytes(&self, bytes: u64) -> Result<Gpa, BuddyError> {
        self.alloc_order(Self::order_for(bytes))
    }

    /// Allocate one 4 MiB block (the Bitmap Page Allocator's grow path).
    pub fn alloc_block(&self) -> Result<Gpa, BuddyError> {
        let gpa = self.alloc_order(BLOCK_ORDER)?;
        debug_assert_eq!(gpa.control_page(), gpa, "block not 4MiB-aligned");
        Ok(gpa)
    }

    /// Free a previously allocated chunk, coalescing with free buddies.
    pub fn free(&self, gpa: Gpa) -> Result<(), BuddyError> {
        let mut inner = self.inner.lock().unwrap();
        let Some(order) = inner.allocated.remove(&gpa.0) else {
            return Err(BuddyError::BadFree(gpa.0));
        };
        let mut order = order as usize;
        inner.allocated_bytes -= Self::order_bytes(order);
        let mut gpa = gpa;
        while order < self.max_order {
            let rel = gpa.0 - self.base;
            let buddy = Gpa(self.base + (rel ^ Self::order_bytes(order)));
            if inner.free_index.get(&buddy.0) != Some(&(order as u8)) {
                break;
            }
            self.unlink(&mut inner, buddy, order)?;
            gpa = Gpa(gpa.0.min(buddy.0));
            order += 1;
        }
        self.push_free(&mut inner, gpa, order);
        Ok(())
    }

    /// Walk every free list and verify each intrusive header. After a naive
    /// zero-fill reclaim of free chunks this fails with
    /// [`BuddyError::Corrupted`] — the paper's §3.3 argument, executable.
    pub fn validate_free_lists(&self) -> Result<(), BuddyError> {
        let inner = self.inner.lock().unwrap();
        for order in 0..=self.max_order {
            let mut cur = inner.free_heads[order];
            let mut hops = 0u64;
            while cur != NIL {
                let (tag, next) = self.read_header(Gpa(cur));
                if tag & MAGIC_MASK != MAGIC || (tag & 0xFF) as usize != order {
                    return Err(BuddyError::Corrupted {
                        gpa: cur,
                        found: tag,
                        expected: MAGIC | order as u64,
                    });
                }
                cur = next;
                hops += 1;
                if hops > inner.free_index.len() as u64 + 1 {
                    return Err(BuddyError::Corrupted {
                        gpa: cur,
                        found: 0,
                        expected: MAGIC,
                    });
                }
            }
        }
        Ok(())
    }

    /// The gpas of all free chunks (used by the naive-reclaim demo).
    pub fn free_chunks(&self) -> Vec<(Gpa, usize)> {
        let inner = self.inner.lock().unwrap();
        inner
            .free_index
            .iter()
            .map(|(&g, &o)| (Gpa(g), o as usize))
            .collect()
    }

    pub fn allocated_bytes(&self) -> u64 {
        self.inner.lock().unwrap().allocated_bytes
    }

    pub fn free_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .free_index
            .values()
            .map(|&o| Self::order_bytes(o as usize))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::host::test_region;

    fn mk(mib: usize) -> (Arc<HostMemory>, BuddyAllocator) {
        let host = Arc::new(test_region(mib));
        let len = host.size() as u64;
        let b = BuddyAllocator::new(host.clone(), 0, len).unwrap();
        (host, b)
    }

    #[test]
    fn order_math() {
        assert_eq!(BuddyAllocator::order_for(1), 0);
        assert_eq!(BuddyAllocator::order_for(4096), 0);
        assert_eq!(BuddyAllocator::order_for(4097), 1);
        assert_eq!(BuddyAllocator::order_for(4 << 20), BLOCK_ORDER);
        assert_eq!(BuddyAllocator::order_bytes(BLOCK_ORDER), 4 << 20);
    }

    #[test]
    fn alloc_free_roundtrip() {
        let (_h, b) = mk(16);
        let total_free = b.free_bytes();
        let a = b.alloc_bytes(8192).unwrap();
        let c = b.alloc_bytes(4096).unwrap();
        assert_ne!(a, c);
        assert_eq!(b.allocated_bytes(), 8192 + 4096);
        b.free(a).unwrap();
        b.free(c).unwrap();
        assert_eq!(b.allocated_bytes(), 0);
        assert_eq!(b.free_bytes(), total_free, "coalescing must restore the pool");
        b.validate_free_lists().unwrap();
    }

    #[test]
    fn blocks_are_4mib_aligned() {
        let (_h, b) = mk(32);
        for _ in 0..4 {
            let blk = b.alloc_block().unwrap();
            assert_eq!(blk.0 % (4 << 20), 0);
        }
    }

    #[test]
    fn exhaustion_reports_oom() {
        let (_h, b) = mk(8);
        let mut got = Vec::new();
        loop {
            match b.alloc_block() {
                Ok(g) => got.push(g),
                Err(BuddyError::OutOfMemory(_)) => break,
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        assert_eq!(got.len(), 2, "8 MiB region holds two 4 MiB blocks");
        for g in got {
            b.free(g).unwrap();
        }
    }

    #[test]
    fn double_free_rejected() {
        let (_h, b) = mk(8);
        let a = b.alloc_bytes(4096).unwrap();
        b.free(a).unwrap();
        assert!(matches!(b.free(a), Err(BuddyError::BadFree(_))));
    }

    #[test]
    fn coalesce_merges_buddies() {
        let (_h, b) = mk(8);
        // Allocate two order-0 buddies by splitting, then free both: they
        // must merge back so a block-size alloc succeeds again.
        let a = b.alloc_order(0).unwrap();
        let c = b.alloc_order(0).unwrap();
        let blk1 = b.alloc_block().unwrap(); // consumes one full block
        b.free(a).unwrap();
        b.free(c).unwrap();
        let blk2 = b.alloc_block().unwrap(); // only works if merge happened
        b.free(blk1).unwrap();
        b.free(blk2).unwrap();
        b.validate_free_lists().unwrap();
    }

    #[test]
    fn reclaim_breaks_intrusive_free_list() {
        // §3.3, executable: madvise the free chunks (naive reclamation) →
        // zero-fill destroys the intrusive headers → the allocator detects
        // corruption. This is why the Bitmap Page Allocator exists.
        let (host, b) = mk(16);
        let a = b.alloc_bytes(4096).unwrap();
        b.free(a).unwrap();
        b.validate_free_lists().unwrap();
        let free_pages: Vec<Gpa> = b.free_chunks().iter().map(|&(g, _)| g).collect();
        host.discard_pages(&free_pages).unwrap();
        let err = b.validate_free_lists().unwrap_err();
        assert!(matches!(err, BuddyError::Corrupted { .. }), "{err}");
        // And allocation through the corrupted list fails loudly, not silently.
        assert!(b.alloc_bytes(4096).is_err());
    }

    #[test]
    fn split_and_refill_many_sizes() {
        let (_h, b) = mk(64);
        let mut chunks = Vec::new();
        for i in 0..100 {
            let bytes = 4096u64 << (i % 5);
            chunks.push(b.alloc_bytes(bytes).unwrap());
        }
        let before = b.allocated_bytes();
        assert!(before > 0);
        for g in chunks {
            b.free(g).unwrap();
        }
        assert_eq!(b.allocated_bytes(), 0);
        b.validate_free_lists().unwrap();
    }
}
