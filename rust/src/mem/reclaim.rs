//! The Memory Reclaim Manager — deflation steps #2 and #4 (§3.2, §3.3).
//!
//! Coordinates the two reclamation sources the paper identifies:
//! 1. **freed guest-application pages** sitting in the Bitmap Page
//!    Allocator's bitmaps → returned to the host via `madvise` (step #2,
//!    "avoids need for a complex Ballooning technique");
//! 2. **file-backed mmap pages** whose mapcount dropped to zero after the
//!    hibernating sandbox unmapped them (step #4) — shared pages still used
//!    by other sandboxes are spared, exactly as §3.5 requires.

use super::bitmap_alloc::BitmapPageAllocator;
use super::mmap_file::FilePageCache;
use crate::simtime::{Clock, CostModel};
use std::sync::Arc;

/// Outcome of a reclamation pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReclaimReport {
    /// Free allocator pages whose host commitment was dropped.
    pub free_pages_reclaimed: u64,
    /// Unmapped file-cache pages freed (then reclaimed with the above).
    pub file_pages_trimmed: u64,
}

impl ReclaimReport {
    pub fn total_pages(&self) -> u64 {
        self.free_pages_reclaimed + self.file_pages_trimmed
    }
}

/// Reclaim coordinator shared by all sandboxes on a host.
pub struct ReclaimManager {
    alloc: Arc<BitmapPageAllocator>,
    cache: Arc<FilePageCache>,
    cost: CostModel,
}

impl ReclaimManager {
    pub fn new(alloc: Arc<BitmapPageAllocator>, cache: Arc<FilePageCache>, cost: CostModel) -> Self {
        Self { alloc, cache, cost }
    }

    /// Full reclamation pass: trim unmapped file pages into the allocator's
    /// free bitmaps, then madvise every free page back to the host. Charges
    /// the madvise cost to `clock`.
    pub fn reclaim(&self, clock: &Clock) -> anyhow::Result<ReclaimReport> {
        let file_pages_trimmed = self.cache.trim_unmapped();
        let free_pages_reclaimed = self.alloc.reclaim_free_pages()?;
        clock.charge(self.cost.madvise_ns(free_pages_reclaimed));
        Ok(ReclaimReport {
            free_pages_reclaimed,
            file_pages_trimmed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::buddy::BuddyAllocator;
    use crate::mem::host::{test_region, HostMemory};
    use crate::mem::mmap_file::{FileClass, FileRegistry};

    fn rig() -> (Arc<HostMemory>, Arc<BitmapPageAllocator>, Arc<FilePageCache>, ReclaimManager) {
        let host = Arc::new(test_region(32));
        let len = host.size() as u64;
        let heap = Arc::new(BuddyAllocator::new(host.clone(), 0, len).unwrap());
        let alloc = Arc::new(BitmapPageAllocator::new(host.clone(), heap));
        let cache = Arc::new(FilePageCache::new(alloc.clone()));
        let mgr = ReclaimManager::new(alloc.clone(), cache.clone(), CostModel::paper());
        (host, alloc, cache, mgr)
    }

    #[test]
    fn reclaims_freed_and_trimmed_pages() {
        let (host, alloc, cache, mgr) = rig();
        let reg = FileRegistry::new();
        let f = reg.get(reg.register("bin", 1 << 20, FileClass::QuarkRuntime));
        // An anchor allocation keeps the block owned (a fully-free block
        // would return to the global heap and be discarded there instead).
        let _anchor = alloc.alloc_page().unwrap();
        // 5 distinct anon pages freed by the guest, 3 file pages unmapped.
        let anon: Vec<_> = (0..5u64)
            .map(|i| {
                let g = alloc.alloc_page().unwrap();
                host.fill_page(g, i).unwrap();
                g
            })
            .collect();
        for g in anon {
            alloc.dec_ref(g);
        }
        for p in 0..3 {
            cache.map_shared(&f, p).unwrap();
            cache.unmap_shared(f.id, p);
        }
        let clock = Clock::new();
        let rpt = mgr.reclaim(&clock).unwrap();
        assert_eq!(rpt.file_pages_trimmed, 3);
        // First-fit reuse: the 3 file pages landed on 3 of the 5 freed anon
        // frames, so 5 distinct committed frames go back to the host.
        assert_eq!(rpt.free_pages_reclaimed, 5);
        assert!(clock.charged_ns() > 0, "madvise cost charged");
    }

    #[test]
    fn shared_file_pages_spared() {
        let (_host, _alloc, cache, mgr) = rig();
        let reg = FileRegistry::new();
        let f = reg.get(reg.register("bin", 1 << 20, FileClass::QuarkRuntime));
        cache.map_shared(&f, 0).unwrap(); // sandbox A
        cache.map_shared(&f, 0).unwrap(); // sandbox B
        cache.unmap_shared(f.id, 0); // A hibernates
        let clock = Clock::new();
        let rpt = mgr.reclaim(&clock).unwrap();
        assert_eq!(rpt.file_pages_trimmed, 0, "B still maps the page");
        assert_eq!(cache.mapcount(f.id, 0), 1);
    }
}
