//! The **Bitmap Page Allocator** (§3.3, Fig. 4): Quark's third allocator,
//! purpose-built so guest-application pages can be reclaimed with
//! `madvise(MADV_DONTNEED)` without corrupting allocator metadata.
//!
//! * serves only fixed-size 4 KiB pages (the page-fault handler's
//!   allocation for guest applications);
//! * grows by 4 MiB blocks taken from the global binary buddy heap;
//! * keeps all metadata in each block's Control Page
//!   ([`super::bitmap_block::ControlPage`]);
//! * allocation takes the global lock ("The memory allocation needs to take
//!   a global lock to avoid race conditions"), while refcount traffic is
//!   lock-free atomics;
//! * blocks with free pages are linked through the control pages' `next`
//!   pointers (a linear free list of *blocks*, not of pages).

use super::bitmap_block::{page_gpa, page_idx, ControlPage, NEXT_NULL};
use super::buddy::{BuddyAllocator, BuddyError};
use super::host::HostMemory;
use super::Gpa;
use crate::{DATA_PAGES_PER_BLOCK, PAGE_SIZE};
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Debug, thiserror::Error)]
pub enum AllocError {
    #[error("global heap exhausted: {0}")]
    Heap(#[from] BuddyError),
}

struct Inner {
    /// Head of the block free list (gpa of a control page) or NEXT_NULL.
    free_head: u64,
    /// All blocks currently owned by this allocator (for the reclaim walk).
    blocks: BTreeSet<u64>,
}

/// Snapshot of allocator occupancy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocStats {
    pub blocks: usize,
    pub allocated_pages: u64,
    pub free_pages: u64,
}

/// The reclaim-oriented page allocator.
pub struct BitmapPageAllocator {
    host: Arc<HostMemory>,
    heap: Arc<BuddyAllocator>,
    inner: Mutex<Inner>,
    allocated_pages: AtomicU64,
}

impl BitmapPageAllocator {
    pub fn new(host: Arc<HostMemory>, heap: Arc<BuddyAllocator>) -> Self {
        Self {
            host,
            heap,
            inner: Mutex::new(Inner {
                free_head: NEXT_NULL,
                blocks: BTreeSet::new(),
            }),
            allocated_pages: AtomicU64::new(0),
        }
    }

    pub fn host(&self) -> &Arc<HostMemory> {
        &self.host
    }

    fn cp(&self, block: Gpa) -> &ControlPage {
        ControlPage::at(&self.host, block)
    }

    /// Allocate one 4 KiB page (refcount = 1). The page is *not* committed —
    /// the host commits it when the guest first touches it, exactly like a
    /// fresh anonymous page.
    pub fn alloc_page(&self) -> Result<Gpa, AllocError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.free_head == NEXT_NULL {
            // Grow: take a 4 MiB block from the global heap (§3.3).
            let block = self.heap.alloc_block()?;
            let cp = self.cp(block);
            cp.init();
            self.host.note_commit(block); // the control page is real metadata
            inner.free_head = block.0;
            inner.blocks.insert(block.0);
        }
        let block = Gpa(inner.free_head);
        let cp = self.cp(block);
        let (idx, now_full) = cp
            .alloc_page()
            .expect("block on free list must have a free page");
        // "If there is no more free page in the first 4MB memory block, it
        // gets removed from the free list."
        if now_full {
            inner.free_head = cp.next.load(Ordering::Relaxed);
            cp.next.store(NEXT_NULL, Ordering::Relaxed);
        }
        self.allocated_pages.fetch_add(1, Ordering::Relaxed);
        Ok(page_gpa(block, idx))
    }

    /// Lock-free refcount increment (guest clone / COW share).
    pub fn inc_ref(&self, gpa: Gpa) -> u16 {
        self.cp(gpa.control_page()).inc_ref(page_idx(gpa))
    }

    pub fn refcount(&self, gpa: Gpa) -> u16 {
        self.cp(gpa.control_page()).refcount(page_idx(gpa))
    }

    /// Lock-free refcount decrement; frees the page on reaching zero.
    /// Returns `true` if the page was freed.
    pub fn dec_ref(&self, gpa: Gpa) -> bool {
        let block = gpa.control_page();
        let idx = page_idx(gpa);
        let remaining = self.cp(block).dec_ref(idx);
        if remaining > 0 {
            return false;
        }
        self.free_page_locked(block, idx);
        true
    }

    fn free_page_locked(&self, block: Gpa, idx: usize) {
        let mut inner = self.inner.lock().unwrap();
        let cp = self.cp(block);
        let was_empty = cp.is_full();
        let now_free = cp.free_page(idx);
        self.allocated_pages.fetch_sub(1, Ordering::Relaxed);
        if was_empty {
            // "If the 4MB memory block's free page count was zero when there
            // is a new free page, the memory block is put back to the free
            // list."
            cp.next.store(inner.free_head, Ordering::Relaxed);
            inner.free_head = block.0;
        }
        if now_free == DATA_PAGES_PER_BLOCK {
            // "When the free page count [reaches] 1023, the 4MB memory block
            // can be returned to the global heap." The data pages go back to
            // the host right away: heap free chunks keep only their header
            // page committed (one contiguous madvise — coalesced below).
            self.unlink_block(&mut inner, block);
            inner.blocks.remove(&block.0);
            let pages: Vec<Gpa> = (1..crate::PAGES_PER_BLOCK)
                .map(|i| page_gpa(block, i))
                .collect();
            self.host
                .discard_pages(&pages)
                .expect("discarding returned block");
            self.heap.free(block).expect("returning block to heap");
        }
    }

    /// Remove `block` from the free list (walks the list; reclaim path only).
    fn unlink_block(&self, inner: &mut Inner, block: Gpa) {
        if inner.free_head == block.0 {
            inner.free_head = self.cp(block).next.load(Ordering::Relaxed);
            return;
        }
        let mut cur = inner.free_head;
        while cur != NEXT_NULL {
            let cp = self.cp(Gpa(cur));
            let next = cp.next.load(Ordering::Relaxed);
            if next == block.0 {
                cp.next
                    .store(self.cp(block).next.load(Ordering::Relaxed), Ordering::Relaxed);
                return;
            }
            cur = next;
        }
        panic!("block {block:?} not on free list");
    }

    /// Deflation step #2 (§3.3): return every *free* data page to the host
    /// via real `madvise(MADV_DONTNEED)`. Control pages are kept (they hold
    /// the metadata that makes this safe). Returns the number of pages whose
    /// host commitment was actually dropped.
    pub fn reclaim_free_pages(&self) -> anyhow::Result<u64> {
        let inner = self.inner.lock().unwrap();
        let mut victims: Vec<Gpa> = Vec::new();
        for &b in &inner.blocks {
            let block = Gpa(b);
            let cp = self.cp(block);
            for idx in cp.free_pages() {
                victims.push(page_gpa(block, idx));
            }
        }
        drop(inner);
        self.host.discard_pages(&victims)
    }

    pub fn stats(&self) -> AllocStats {
        let inner = self.inner.lock().unwrap();
        let allocated = self.allocated_pages.load(Ordering::Relaxed);
        let free: u64 = inner
            .blocks
            .iter()
            .map(|&b| self.cp(Gpa(b)).free_count() as u64)
            .sum();
        AllocStats {
            blocks: inner.blocks.len(),
            allocated_pages: allocated,
            free_pages: free,
        }
    }

    /// Committed bytes attributable to allocator metadata (control pages).
    pub fn metadata_bytes(&self) -> u64 {
        (self.inner.lock().unwrap().blocks.len() * PAGE_SIZE) as u64
    }

    /// Validate cross-block invariants (test/debug aid).
    pub fn check_invariants(&self) -> Result<(), String> {
        let inner = self.inner.lock().unwrap();
        // Free list must only contain owned blocks with free pages, no cycles.
        let mut seen = BTreeSet::new();
        let mut cur = inner.free_head;
        while cur != NEXT_NULL {
            if !seen.insert(cur) {
                return Err(format!("free-list cycle at {cur:#x}"));
            }
            if !inner.blocks.contains(&cur) {
                return Err(format!("free-list block {cur:#x} not owned"));
            }
            let cp = self.cp(Gpa(cur));
            cp.check_invariants()?;
            if cp.free_count() == 0 {
                return Err(format!("full block {cur:#x} on free list"));
            }
            cur = cp.next.load(Ordering::Relaxed);
        }
        // Every owned block with free pages must be on the free list.
        for &b in &inner.blocks {
            let cp = self.cp(Gpa(b));
            cp.check_invariants()?;
            if cp.free_count() > 0 && !seen.contains(&b) {
                return Err(format!("block {b:#x} has free pages but is off-list"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::host::test_region;

    fn mk(mib: usize) -> (Arc<HostMemory>, Arc<BuddyAllocator>, BitmapPageAllocator) {
        let host = Arc::new(test_region(mib));
        let len = host.size() as u64;
        let heap = Arc::new(BuddyAllocator::new(host.clone(), 0, len).unwrap());
        let alloc = BitmapPageAllocator::new(host.clone(), heap.clone());
        (host, heap, alloc)
    }

    #[test]
    fn alloc_many_pages_unique() {
        let (_h, _b, a) = mk(16);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..2000 {
            let g = a.alloc_page().unwrap();
            assert!(g.is_page_aligned());
            assert!(seen.insert(g.0), "duplicate {g:?}");
            assert_ne!(page_idx(g), 0, "control page must never be handed out");
        }
        assert_eq!(a.stats().allocated_pages, 2000);
        assert_eq!(a.stats().blocks, 2, "1023 pages per block → 2000 needs 2 blocks");
        a.check_invariants().unwrap();
    }

    #[test]
    fn dec_ref_frees_and_block_returns_to_heap() {
        let (_h, heap, a) = mk(16);
        let heap_free_before = heap.free_bytes();
        let pages: Vec<Gpa> = (0..100).map(|_| a.alloc_page().unwrap()).collect();
        assert!(heap.free_bytes() < heap_free_before);
        for &g in &pages {
            assert!(a.dec_ref(g));
        }
        assert_eq!(a.stats().allocated_pages, 0);
        assert_eq!(a.stats().blocks, 0, "empty block must return to the heap");
        assert_eq!(heap.free_bytes(), heap_free_before);
        a.check_invariants().unwrap();
    }

    #[test]
    fn refcount_sharing_defers_free() {
        let (_h, _b, a) = mk(16);
        let g = a.alloc_page().unwrap();
        assert_eq!(a.inc_ref(g), 2); // COW clone
        assert!(!a.dec_ref(g), "still shared");
        assert_eq!(a.stats().allocated_pages, 1);
        assert!(a.dec_ref(g), "last owner frees");
        assert_eq!(a.stats().allocated_pages, 0);
    }

    #[test]
    fn reclaim_returns_free_pages_to_host() {
        let (host, _b, a) = mk(16);
        let pages: Vec<Gpa> = (0..500).map(|_| a.alloc_page().unwrap()).collect();
        for &g in &pages {
            host.fill_page(g, g.0).unwrap();
        }
        let committed_full = host.committed_pages();
        // Free half of them (even indices) — commitment unchanged until reclaim.
        for (i, &g) in pages.iter().enumerate() {
            if i % 2 == 0 {
                a.dec_ref(g);
            }
        }
        assert_eq!(host.committed_pages(), committed_full);
        let reclaimed = a.reclaim_free_pages().unwrap();
        assert_eq!(reclaimed, 250);
        assert_eq!(host.committed_pages(), committed_full - 250);
        // Surviving pages' contents intact.
        for (i, &g) in pages.iter().enumerate() {
            if i % 2 == 1 {
                let mut buf = vec![0u8; PAGE_SIZE];
                host.read_page(g, &mut buf).unwrap();
                assert!(buf.iter().any(|&x| x != 0));
            }
        }
        a.check_invariants().unwrap();
    }

    #[test]
    fn allocator_survives_reclaim_and_reuses_pages() {
        // The §3.3 property the buddy allocator lacks, end to end.
        let (host, _b, a) = mk(16);
        let pages: Vec<Gpa> = (0..50).map(|_| a.alloc_page().unwrap()).collect();
        for &g in &pages {
            host.fill_page(g, 7).unwrap();
            a.dec_ref(g);
        }
        a.reclaim_free_pages().unwrap();
        a.check_invariants().unwrap();
        // Allocate again: must succeed and hand out (zero-filled) pages.
        for _ in 0..50 {
            let g = a.alloc_page().unwrap();
            host.touch_page(g).unwrap();
        }
        a.check_invariants().unwrap();
    }

    #[test]
    fn fills_block_before_growing() {
        let (_h, _b, a) = mk(16);
        let mut last_block = None;
        for i in 0..(DATA_PAGES_PER_BLOCK + 1) {
            let g = a.alloc_page().unwrap();
            let blk = g.control_page();
            if i < DATA_PAGES_PER_BLOCK {
                if let Some(lb) = last_block {
                    assert_eq!(lb, blk, "must exhaust block before growing");
                }
                last_block = Some(blk);
            } else {
                assert_ne!(Some(blk), last_block, "1024th page needs a new block");
            }
        }
    }

    #[test]
    fn concurrent_alloc_dec_ref() {
        use std::sync::atomic::AtomicUsize;
        let (_h, _b, a) = mk(64);
        let a = Arc::new(a);
        let freed = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for t in 0..8 {
            let a = a.clone();
            let freed = freed.clone();
            handles.push(std::thread::spawn(move || {
                let mut mine = Vec::new();
                for _ in 0..500 {
                    mine.push(a.alloc_page().unwrap());
                }
                if t % 2 == 0 {
                    for g in mine {
                        a.dec_ref(g);
                        freed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = a.stats();
        assert_eq!(
            stats.allocated_pages,
            (8 * 500 - freed.load(Ordering::Relaxed)) as u64
        );
        a.check_invariants().unwrap();
    }
}
