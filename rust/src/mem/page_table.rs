//! Guest application page tables: a 4-level radix tree of 64-bit PTEs,
//! x86-64-shaped, with the paper's **custom flag bit #9** marking
//! swapped-out pages (§3.4.1: "Set the page table entry's flags bit#9,
//! which is a customer bit, to indicate the page fault is due to page
//! swap-out").
//!
//! The Swapping Manager walks these tables during deflation (marking anon
//! pages Not-Present + bit9) and the fault path consults them on every
//! guest access.

use super::{Gpa, Gva};
use crate::PAGE_SIZE;

/// Page-table entry. Bit layout (subset of x86-64 plus the paper's bit):
///
/// | bit | meaning |
/// |-----|---------|
/// | 0   | PRESENT |
/// | 1   | WRITABLE |
/// | 5   | ACCESSED |
/// | 6   | DIRTY |
/// | 9   | **SWAPPED** (paper's custom bit: fault = swap-in) |
/// | 10  | FILE (file-backed mapping) |
/// | 11  | COW (write fault must copy) |
/// | 12–51 | frame (guest-physical page number) |
#[derive(Copy, Clone, PartialEq, Eq)]
pub struct Pte(pub u64);

impl Pte {
    pub const PRESENT: u64 = 1 << 0;
    pub const WRITABLE: u64 = 1 << 1;
    pub const ACCESSED: u64 = 1 << 5;
    pub const DIRTY: u64 = 1 << 6;
    /// The paper's custom swap marker.
    pub const SWAPPED: u64 = 1 << 9;
    pub const FILE: u64 = 1 << 10;
    pub const COW: u64 = 1 << 11;
    const ADDR_MASK: u64 = 0x000F_FFFF_FFFF_F000;

    pub const EMPTY: Pte = Pte(0);

    pub fn new_present(gpa: Gpa, extra_flags: u64) -> Pte {
        debug_assert!(gpa.is_page_aligned());
        Pte((gpa.0 & Self::ADDR_MASK) | Self::PRESENT | extra_flags)
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    #[inline]
    pub fn present(self) -> bool {
        self.0 & Self::PRESENT != 0
    }

    #[inline]
    pub fn swapped(self) -> bool {
        self.0 & Self::SWAPPED != 0
    }

    #[inline]
    pub fn writable(self) -> bool {
        self.0 & Self::WRITABLE != 0
    }

    #[inline]
    pub fn is_file(self) -> bool {
        self.0 & Self::FILE != 0
    }

    #[inline]
    pub fn is_cow(self) -> bool {
        self.0 & Self::COW != 0
    }

    #[inline]
    pub fn dirty(self) -> bool {
        self.0 & Self::DIRTY != 0
    }

    /// Frame address. Valid when present **or** swapped (the swap path keeps
    /// the gpa in the entry so the dedup hash table can key on it).
    #[inline]
    pub fn gpa(self) -> Gpa {
        Gpa(self.0 & Self::ADDR_MASK)
    }

    /// Mark swapped-out: clear PRESENT, set bit #9, keep the frame bits.
    ///
    /// Also clears DIRTY: the image just written to the swap slot *is* the
    /// page's content, so the entry restarts clean. The next write access
    /// (the MMU in hardware; [`Pte::with`]`(Pte::DIRTY)` in callers that
    /// emulate it) re-marks it, which is what lets the delta swap-out skip
    /// rewriting pages whose slot image is still current.
    #[inline]
    pub fn to_swapped(self) -> Pte {
        Pte((self.0 & !(Self::PRESENT | Self::DIRTY)) | Self::SWAPPED)
    }

    /// Complete a swap-in: set PRESENT, clear bit #9. DIRTY is left as-is
    /// (it was cleared at swap-out, so a faulted-in page starts clean).
    #[inline]
    pub fn to_present(self) -> Pte {
        Pte((self.0 | Self::PRESENT) & !Self::SWAPPED)
    }

    #[inline]
    pub fn with(self, flags: u64) -> Pte {
        Pte(self.0 | flags)
    }

    #[inline]
    pub fn without(self, flags: u64) -> Pte {
        Pte(self.0 & !flags)
    }
}

impl std::fmt::Debug for Pte {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Pte({:?}{}{}{}{}{}{})",
            self.gpa(),
            if self.present() { " P" } else { "" },
            if self.writable() { " W" } else { "" },
            if self.swapped() { " SWP" } else { "" },
            if self.is_file() { " FILE" } else { "" },
            if self.is_cow() { " COW" } else { "" },
            if self.dirty() { " D" } else { "" },
        )
    }
}

const FANOUT: usize = 512;
const LEVELS: usize = 4;
/// Max virtual address covered: 512^4 * 4KiB = 256 TiB (48-bit).
pub const MAX_GVA: u64 = (FANOUT as u64).pow(LEVELS as u32) * PAGE_SIZE as u64;

enum Node {
    Dir(Box<[Option<Node>; FANOUT]>),
    Leaf(Box<[u64; FANOUT]>),
}

impl Node {
    fn new_dir() -> Node {
        Node::Dir(Box::new(std::array::from_fn(|_| None)))
    }

    fn new_leaf() -> Node {
        Node::Leaf(Box::new([0u64; FANOUT]))
    }
}

/// A guest process's page table.
pub struct PageTable {
    root: Node,
    present: u64,
    swapped: u64,
}

#[inline]
fn indices(gva: Gva) -> [usize; LEVELS] {
    let page = gva.page_index();
    [
        ((page >> 27) & 0x1FF) as usize,
        ((page >> 18) & 0x1FF) as usize,
        ((page >> 9) & 0x1FF) as usize,
        (page & 0x1FF) as usize,
    ]
}

impl Default for PageTable {
    fn default() -> Self {
        Self::new()
    }
}

impl PageTable {
    pub fn new() -> Self {
        Self {
            root: Node::new_dir(),
            present: 0,
            swapped: 0,
        }
    }

    pub fn present_count(&self) -> u64 {
        self.present
    }

    pub fn swapped_count(&self) -> u64 {
        self.swapped
    }

    fn leaf_slot(&mut self, gva: Gva, create: bool) -> Option<&mut u64> {
        assert!(gva.0 < MAX_GVA, "gva out of range");
        let idx = indices(gva);
        let mut node = &mut self.root;
        for (level, &i) in idx.iter().enumerate().take(LEVELS - 1) {
            let Node::Dir(children) = node else {
                unreachable!("leaf at non-terminal level");
            };
            if children[i].is_none() {
                if !create {
                    return None;
                }
                children[i] = Some(if level == LEVELS - 2 {
                    Node::new_leaf()
                } else {
                    Node::new_dir()
                });
            }
            node = children[i].as_mut().unwrap();
        }
        let Node::Leaf(ptes) = node else {
            unreachable!("dir at terminal level");
        };
        Some(&mut ptes[idx[LEVELS - 1]])
    }

    /// Read the PTE for `gva` (page-aligned-down).
    pub fn get(&self, gva: Gva) -> Pte {
        assert!(gva.0 < MAX_GVA, "gva out of range");
        let idx = indices(gva);
        let mut node = &self.root;
        for &i in idx.iter().take(LEVELS - 1) {
            let Node::Dir(children) = node else {
                unreachable!()
            };
            match &children[i] {
                None => return Pte::EMPTY,
                Some(n) => node = n,
            }
        }
        let Node::Leaf(ptes) = node else { unreachable!() };
        Pte(ptes[idx[LEVELS - 1]])
    }

    fn book_delta(&mut self, old: Pte, new: Pte) {
        if old.present() {
            self.present -= 1;
        }
        if new.present() {
            self.present += 1;
        }
        if old.swapped() {
            self.swapped -= 1;
        }
        if new.swapped() {
            self.swapped += 1;
        }
    }

    /// Install a PTE (overwrites any previous mapping).
    pub fn map(&mut self, gva: Gva, pte: Pte) {
        let slot = self.leaf_slot(gva, true).unwrap();
        let old = Pte(*slot);
        *slot = pte.0;
        self.book_delta(old, pte);
    }

    /// Remove a mapping, returning the previous PTE.
    pub fn unmap(&mut self, gva: Gva) -> Pte {
        match self.leaf_slot(gva, false) {
            None => Pte::EMPTY,
            Some(slot) => {
                let old = Pte(*slot);
                *slot = 0;
                self.book_delta(old, Pte::EMPTY);
                old
            }
        }
    }

    /// Apply `f` to the PTE if one exists; returns the new value.
    pub fn update(&mut self, gva: Gva, f: impl FnOnce(Pte) -> Pte) -> Option<Pte> {
        let slot = self.leaf_slot(gva, false)?;
        let old = Pte(*slot);
        if old.is_empty() {
            return None;
        }
        let new = f(old);
        *slot = new.0;
        let (o, n) = (old, new);
        self.book_delta(o, n);
        Some(new)
    }

    /// Visit every non-empty PTE: `f(gva, pte)`. This is the "walk through
    /// all the guest application page tables" of the swap-out process.
    pub fn for_each(&self, mut f: impl FnMut(Gva, Pte)) {
        Self::walk(&self.root, 0, 0, &mut f);
    }

    fn walk(node: &Node, level: usize, base_page: u64, f: &mut impl FnMut(Gva, Pte)) {
        match node {
            Node::Dir(children) => {
                for (i, c) in children.iter().enumerate() {
                    if let Some(c) = c {
                        let shift = 9 * (LEVELS - 1 - level);
                        Self::walk(c, level + 1, base_page | ((i as u64) << shift), f);
                    }
                }
            }
            Node::Leaf(ptes) => {
                for (i, &p) in ptes.iter().enumerate() {
                    if p != 0 {
                        let page = base_page | i as u64;
                        f(Gva(page * PAGE_SIZE as u64), Pte(p));
                    }
                }
            }
        }
    }

    /// Mutating visit: `f` returns the replacement PTE (possibly unchanged).
    pub fn for_each_mut(&mut self, mut f: impl FnMut(Gva, Pte) -> Pte) {
        let mut present = self.present;
        let mut swapped = self.swapped;
        Self::walk_mut(&mut self.root, 0, 0, &mut |gva, old| {
            let new = f(gva, old);
            if old.present() {
                present -= 1;
            }
            if new.present() {
                present += 1;
            }
            if old.swapped() {
                swapped -= 1;
            }
            if new.swapped() {
                swapped += 1;
            }
            new
        });
        self.present = present;
        self.swapped = swapped;
    }

    fn walk_mut(
        node: &mut Node,
        level: usize,
        base_page: u64,
        f: &mut impl FnMut(Gva, Pte) -> Pte,
    ) {
        match node {
            Node::Dir(children) => {
                for (i, c) in children.iter_mut().enumerate() {
                    if let Some(c) = c {
                        let shift = 9 * (LEVELS - 1 - level);
                        Self::walk_mut(c, level + 1, base_page | ((i as u64) << shift), f);
                    }
                }
            }
            Node::Leaf(ptes) => {
                for (i, p) in ptes.iter_mut().enumerate() {
                    if *p != 0 {
                        let page = base_page | i as u64;
                        *p = f(Gva(page * PAGE_SIZE as u64), Pte(*p)).0;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pte_flag_round_trips() {
        let gpa = Gpa(0x12345000);
        let pte = Pte::new_present(gpa, Pte::WRITABLE);
        assert!(pte.present() && pte.writable() && !pte.swapped());
        assert_eq!(pte.gpa(), gpa);
        let swapped = pte.to_swapped();
        assert!(!swapped.present() && swapped.swapped());
        assert_eq!(swapped.gpa(), gpa, "frame must survive the swap marker");
        let back = swapped.to_present();
        assert!(back.present() && !back.swapped());
        assert_eq!(back, pte);
    }

    #[test]
    fn dirty_bit_cleared_at_swap_restored_clean() {
        let gpa = Gpa(0x5000);
        let dirty = Pte::new_present(gpa, Pte::WRITABLE | Pte::DIRTY);
        assert!(dirty.dirty());
        let swapped = dirty.to_swapped();
        assert!(
            !swapped.dirty(),
            "swap-out writes the image, so the entry restarts clean"
        );
        let back = swapped.to_present();
        assert!(back.present() && !back.dirty(), "fault-in restores clean");
        // A write access re-marks it (callers emulate the MMU).
        let rewritten = back.with(Pte::DIRTY);
        assert!(rewritten.dirty());
        assert_eq!(rewritten.gpa(), gpa);
    }

    #[test]
    fn map_get_unmap() {
        let mut pt = PageTable::new();
        let gva = Gva(0x7000_0000);
        assert!(pt.get(gva).is_empty());
        pt.map(gva, Pte::new_present(Gpa(0x1000), Pte::WRITABLE));
        assert_eq!(pt.get(gva).gpa(), Gpa(0x1000));
        assert_eq!(pt.present_count(), 1);
        let old = pt.unmap(gva);
        assert!(old.present());
        assert!(pt.get(gva).is_empty());
        assert_eq!(pt.present_count(), 0);
    }

    #[test]
    fn sparse_addresses_dont_collide() {
        let mut pt = PageTable::new();
        // Addresses chosen to hit distinct top-level slots.
        let gvas = [
            Gva(0x0000_0000_1000),
            Gva(0x0000_4000_0000),
            Gva(0x0080_0000_0000),
            Gva(0x7F00_0000_0000),
        ];
        for (i, &gva) in gvas.iter().enumerate() {
            pt.map(gva, Pte::new_present(Gpa((i as u64 + 1) * 0x1000), 0));
        }
        for (i, &gva) in gvas.iter().enumerate() {
            assert_eq!(pt.get(gva).gpa(), Gpa((i as u64 + 1) * 0x1000));
        }
    }

    #[test]
    fn walk_enumerates_everything_in_order() {
        let mut pt = PageTable::new();
        let mut expect = Vec::new();
        for i in 0..1000u64 {
            let gva = Gva(i * 0x1000 * 37); // strided
            pt.map(gva, Pte::new_present(Gpa(i * 0x1000), 0));
            expect.push(gva.0);
        }
        expect.sort_unstable();
        let mut got = Vec::new();
        pt.for_each(|gva, pte| {
            assert!(pte.present());
            got.push(gva.0);
        });
        assert_eq!(got, expect, "walk must be sorted and complete");
    }

    #[test]
    fn for_each_mut_swaps_all_and_fixes_counts() {
        let mut pt = PageTable::new();
        for i in 0..100u64 {
            pt.map(Gva(i * 0x1000), Pte::new_present(Gpa(i * 0x1000), Pte::WRITABLE));
        }
        pt.for_each_mut(|_gva, pte| pte.to_swapped());
        assert_eq!(pt.present_count(), 0);
        assert_eq!(pt.swapped_count(), 100);
        pt.for_each(|_, pte| {
            assert!(pte.swapped());
            assert!(!pte.present());
        });
        pt.for_each_mut(|_gva, pte| pte.to_present());
        assert_eq!(pt.present_count(), 100);
        assert_eq!(pt.swapped_count(), 0);
    }

    #[test]
    fn update_counts() {
        let mut pt = PageTable::new();
        pt.map(Gva(0), Pte::new_present(Gpa(0x1000), 0));
        assert!(pt.update(Gva(0x9999_000), |p| p).is_none(), "no entry there");
        pt.update(Gva(0), |p| p.to_swapped()).unwrap();
        assert_eq!(pt.present_count(), 0);
        assert_eq!(pt.swapped_count(), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range() {
        let pt = PageTable::new();
        pt.get(Gva(MAX_GVA));
    }
}
