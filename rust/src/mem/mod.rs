//! Guest memory substrate: everything §2.2/§3.3 of the paper depends on.
//!
//! * [`host`] — the "host Linux kernel" view: a real `mmap` region acting as
//!   guest-physical memory, commit-on-touch accounting and real
//!   `madvise(MADV_DONTNEED)` reclaim.
//! * [`bitmap_alloc`] / [`bitmap_block`] — the paper's reclaim-oriented
//!   **Bitmap Page Allocator** (Fig. 4), with the control-page layout kept
//!   *inside the block's first page*, exactly as published.
//! * [`buddy`] — the binary buddy allocator the paper replaces; its free
//!   list is intrusive (next pointers live in the free memory), which is
//!   precisely why zero-fill reclaim breaks it (§3.3).
//! * [`page_table`] — guest page tables with the Present bit and the
//!   paper's custom swap marker **bit #9**.
//! * [`vma`] — guest virtual address space (anonymous + file-backed VMAs).
//! * [`mmap_file`] — cross-sandbox file-backed page sharing (§3.5).
//! * [`pss`] — Proportional Set Size accounting (the Fig. 7 metric).
//! * [`reclaim`] — the Memory Reclaim Manager (deflation step #2).

pub mod bitmap_alloc;
pub mod bitmap_block;
pub mod buddy;
pub mod host;
pub mod mmap_file;
pub mod page_table;
pub mod pss;
pub mod reclaim;
pub mod vma;

use crate::PAGE_SIZE;

/// Guest-physical address: byte offset into the [`host::HostMemory`] region.
/// The host virtual address of the backing page is `base + gpa`, so — as in
/// the paper — guest-physical memory *is* host virtual memory.
#[derive(Copy, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Gpa(pub u64);

impl Gpa {
    pub const NULL: Gpa = Gpa(u64::MAX);

    #[inline]
    pub fn page_index(self) -> u64 {
        self.0 / PAGE_SIZE as u64
    }

    #[inline]
    pub fn is_page_aligned(self) -> bool {
        self.0 % PAGE_SIZE as u64 == 0
    }

    /// Control page of the 4 MiB block containing this address — "clearing
    /// its address's least 22 bits" (§3.3), no lookup table needed.
    #[inline]
    pub fn control_page(self) -> Gpa {
        Gpa(self.0 & !((crate::BLOCK_SIZE as u64) - 1))
    }
}

impl std::fmt::Debug for Gpa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Gpa({:#x})", self.0)
    }
}

/// Guest-virtual address (what guest application page tables translate).
#[derive(Copy, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Gva(pub u64);

impl Gva {
    #[inline]
    pub fn page_aligned_down(self) -> Gva {
        Gva(self.0 & !(PAGE_SIZE as u64 - 1))
    }

    #[inline]
    pub fn page_index(self) -> u64 {
        self.0 / PAGE_SIZE as u64
    }
}

impl std::fmt::Debug for Gva {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Gva({:#x})", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_page_masking() {
        assert_eq!(Gpa(0).control_page(), Gpa(0));
        assert_eq!(Gpa(0x3F_FFFF).control_page(), Gpa(0));
        assert_eq!(Gpa(0x40_0000).control_page(), Gpa(0x40_0000));
        assert_eq!(Gpa(0x40_1000).control_page(), Gpa(0x40_0000));
        assert_eq!(Gpa(0x7F_F000).control_page(), Gpa(0x40_0000));
    }

    #[test]
    fn page_indexing() {
        assert_eq!(Gpa(0x1000).page_index(), 1);
        assert_eq!(Gva(0x1FFF).page_aligned_down(), Gva(0x1000));
    }
}
